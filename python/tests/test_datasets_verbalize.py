"""Dataset generators (Table 1 statistics) and the canonical verbalizer."""

import numpy as np
import pytest

from compile import config, verbalize
from compile.datasets import gen_oag, gen_scene_graph
from compile.tokenizer import split_text


@pytest.fixture(scope="module")
def scene():
    return gen_scene_graph()


@pytest.fixture(scope="module")
def oag():
    return gen_oag()


# ---- Table 1 statistics -----------------------------------------------------

def test_scene_graph_stats(scene):
    assert len(scene["nodes"]) == 22
    assert len(scene["edges"]) == 147
    assert len(scene["queries"]) == 426


def test_oag_stats(oag):
    assert len(oag["nodes"]) == 1071
    assert len(oag["edges"]) == 2022
    assert len(oag["queries"]) == 3434


def test_scene_split_sizes(scene):
    splits = [q["split"] for q in scene["queries"]]
    assert splits.count("train") == 113
    assert splits.count("val") == 113
    assert splits.count("test") == 200


def test_oag_split_sizes(oag):
    splits = [q["split"] for q in oag["queries"]]
    assert splits.count("train") == 1617
    assert splits.count("val") == 1617
    assert splits.count("test") == 200


def test_generators_deterministic(scene):
    again = gen_scene_graph()
    assert again == scene


# ---- structural sanity ------------------------------------------------------

def test_scene_edges_are_valid_and_unique(scene):
    seen = set()
    n = len(scene["nodes"])
    for e in scene["edges"]:
        assert 0 <= e["src"] < n and 0 <= e["dst"] < n and e["src"] != e["dst"]
        assert (e["src"], e["dst"]) not in seen
        seen.add((e["src"], e["dst"]))


def test_oag_edge_relations(oag):
    rels = {e["text"] for e in oag["edges"]}
    assert rels == {"written by", "focuses on", "cites", "has member"}


def test_node_ids_contiguous(scene, oag):
    for ds in (scene, oag):
        assert [n["id"] for n in ds["nodes"]] == list(range(len(ds["nodes"])))


# ---- answerability: support subgraph contains the answer --------------------

def test_scene_queries_answerable(scene):
    for q in scene["queries"][:80]:
        support_text = " ".join(
            scene["nodes"][i]["text"] for i in q["support_nodes"]
        ) + " " + " ".join(scene["edges"][i]["text"] for i in q["support_edges"])
        for w in split_text(q["answer"]):
            assert w in split_text(support_text), (q, support_text)


def test_oag_queries_answer_is_edge_relation(oag):
    for q in oag["queries"][:80]:
        e = oag["edges"][q["support_edges"][0]]
        assert q["answer"] == e["text"]
        assert set(q["support_nodes"]) == {e["src"], e["dst"]}


def test_answers_fit_budget(scene, oag):
    for ds in (scene, oag):
        for q in ds["queries"]:
            assert len(split_text(q["answer"])) <= 5


# ---- verbalizer -------------------------------------------------------------

def test_prefix_format(scene):
    text = verbalize.prefix_text(scene, [0, 1], [0])
    assert text.startswith("graph :")
    assert text.endswith(";")
    e = scene["edges"][0]
    names = {n["id"]: n["name"] for n in scene["nodes"]}
    assert f"{names[e['src']]} {e['text']} {names[e['dst']]}" in text


def test_prefix_sorted_and_deduped(scene):
    a = verbalize.prefix_text(scene, [2, 0, 2, 1], [3, 1, 3])
    b = verbalize.prefix_text(scene, [0, 1, 2], [1, 3])
    assert a == b


def test_prefix_token_budget(scene):
    full = verbalize.prefix_text(scene, range(22), range(147))
    capped = verbalize.prefix_text(scene, range(22), range(147), max_tokens=100)
    assert len(split_text(capped)) <= 100
    assert len(split_text(capped)) < len(split_text(full))
    assert capped.startswith("graph :")


def test_prefix_budget_drops_whole_clauses(scene):
    capped = verbalize.prefix_text(scene, range(22), range(147), max_tokens=50)
    # every clause between ';' separators must be a complete node/edge clause
    body = capped[len("graph :"):].strip()
    clauses = [c.strip() for c in body.split(";") if c.strip()]
    names = {n["name"] for n in scene["nodes"]}
    texts = {n["text"] for n in scene["nodes"]}
    for c in clauses:
        ok = c in texts or any(c.startswith(nm + " ") for nm in names)
        assert ok, c


def test_full_prompt_contains_question(scene):
    p = verbalize.full_prompt(scene, [0], [], "what color is the laptop ?")
    assert p.endswith(" question : what color is the laptop ? answer :")


def test_question_text_format():
    assert verbalize.question_text("x ?") == " question : x ? answer :"
