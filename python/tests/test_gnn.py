"""GNN encoder tests: contract + the property clustering relies on —
overlapping subgraphs embed closer than disjoint ones."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config, gnn
from compile.hashembed import embed_text

N, F = config.N_MAX, config.FEAT_DIM
RNG = np.random.default_rng(5)


def _pack(texts, edges):
    """Build (x, adj, mask) from node texts + edge index pairs."""
    x = np.zeros((N, F), np.float32)
    adj = np.zeros((N, N), np.float32)
    mask = np.zeros((N,), np.float32)
    for i, t in enumerate(texts):
        x[i] = embed_text(t)
        mask[i] = 1.0
    for a, b in edges:
        adj[a, b] = 1.0
        adj[b, a] = 1.0
    return jnp.asarray(x), jnp.asarray(adj), jnp.asarray(mask)


@pytest.fixture(scope="module", params=list(gnn.ENCODERS))
def encoder(request):
    init, encode = gnn.ENCODERS[request.param]
    return init(), jax.jit(encode)


def test_output_shape_finite(encoder):
    params, encode = encoder
    x, adj, mask = _pack(["red laptop", "blue cords", "gray table"],
                         [(0, 1), (1, 2)])
    emb = np.asarray(encode(params, x, adj, mask))
    assert emb.shape == (config.GNN_EMB,)
    assert np.isfinite(emb).all()


def test_padded_nodes_do_not_affect_embedding(encoder):
    """Garbage features in masked-out slots must be invisible."""
    params, encode = encoder
    x, adj, mask = _pack(["red laptop", "blue cords"], [(0, 1)])
    e1 = np.asarray(encode(params, x, adj, mask))
    x2 = x.at[10:].set(99.0)  # masked slots
    e2 = np.asarray(encode(params, x2, adj, mask))
    np.testing.assert_allclose(e1, e2, atol=1e-5)


def test_structure_sensitivity(encoder):
    """Same node set, different topology ⇒ different embedding."""
    params, encode = encoder
    texts = ["a b", "c d", "e f", "g h"]
    x, adj1, mask = _pack(texts, [(0, 1), (2, 3)])
    _, adj2, _ = _pack(texts, [(0, 2), (1, 3)])
    e1 = np.asarray(encode(params, x, adj1, mask))
    e2 = np.asarray(encode(params, x, adj2, mask))
    assert np.abs(e1 - e2).max() > 1e-6


def test_overlap_embeds_closer_than_disjoint(encoder):
    """The clustering premise: high node/edge overlap ⇒ small distance."""
    params, encode = encoder
    base_texts = ["red laptop", "blue cords", "gray screen", "black camera"]
    base_edges = [(0, 1), (1, 2), (2, 3)]
    x0, a0, m0 = _pack(base_texts, base_edges)
    # near-duplicate: one extra node
    x1, a1, m1 = _pack(base_texts + ["white door"], base_edges + [(3, 4)])
    # disjoint content
    x2, a2, m2 = _pack(["graph neural networks", "retrieval augmented",
                        "batch query processing", "kv cache reuse"],
                       [(0, 1), (1, 2), (2, 3)])
    e0 = np.asarray(encode(params, x0, a0, m0))
    e1 = np.asarray(encode(params, x1, a1, m1))
    e2 = np.asarray(encode(params, x2, a2, m2))
    d_overlap = np.linalg.norm(e0 - e1)
    d_disjoint = np.linalg.norm(e0 - e2)
    assert d_overlap < d_disjoint


def test_encode_deterministic(encoder):
    params, encode = encoder
    x, adj, mask = _pack(["x y", "z w"], [(0, 1)])
    np.testing.assert_array_equal(np.asarray(encode(params, x, adj, mask)),
                                  np.asarray(encode(params, x, adj, mask)))


def test_empty_graph_is_finite(encoder):
    params, encode = encoder
    x, adj, mask = _pack([], [])
    emb = np.asarray(encode(params, x, adj, mask))
    assert np.isfinite(emb).all()


def test_encoders_differ():
    """The two baselines must not share an encoder (paper uses GT vs GAT)."""
    pgt, egt = gnn.ENCODERS["graph_transformer"][0](), gnn.ENCODERS["graph_transformer"][1]
    pga, ega = gnn.ENCODERS["gat"][0](), gnn.ENCODERS["gat"][1]
    x, adj, mask = _pack(["red laptop", "blue cords"], [(0, 1)])
    a = np.asarray(egt(pgt, x, adj, mask))
    b = np.asarray(ega(pga, x, adj, mask))
    assert np.abs(a - b).max() > 1e-6
