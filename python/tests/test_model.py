"""L2 model tests: entry-point contracts and the SubGCache correctness core.

The decisive property: serving from a cached prefix (prefill(p) → extend(q))
must match monolithic prefill(p ⊕ q) — this is exactly what lets SubGCache
reuse a representative-subgraph KV cache across queries.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config, model

DIMS = model.ModelDims(vocab=128, d_model=32, n_layers=2, n_heads=2, d_head=8,
                       d_ff=64, max_seq=96)
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def params():
    return model.init_params(DIMS, seed=3)


@pytest.fixture(scope="module")
def entries(params):
    return model.make_entries(DIMS, use_kernel=True)


def _tokens(n, total):
    t = np.full(total, config.PAD_ID, np.int32)
    t[:n] = RNG.integers(4, DIMS.vocab, size=n)
    return t


def test_param_count_and_shapes(params):
    leaves = jax.tree_util.tree_leaves(params)
    assert len(leaves) == 2 + DIMS.n_layers * 9
    assert params["embed"].shape == (DIMS.vocab, DIMS.d_model)


def test_init_deterministic():
    a = model.init_params(DIMS, seed=3)
    b = model.init_params(DIMS, seed=3)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_prefill_shapes(params, entries):
    prefill = entries[0]
    kv_k, kv_v, logits = jax.jit(prefill)(params, jnp.asarray(_tokens(10, DIMS.max_seq)), jnp.int32(10))
    assert kv_k.shape == (DIMS.n_layers, DIMS.max_seq, DIMS.n_heads, DIMS.d_head)
    assert kv_v.shape == kv_k.shape


def test_cached_extend_matches_full_prefill(params, entries):
    """prefill(p) ⊕ extend(q) == prefill(p ⊕ q) on the written KV slots."""
    prefill, extend, _ = entries
    plen, qlen = 20, 5
    p = _tokens(plen, DIMS.max_seq)
    q_part = RNG.integers(4, DIMS.vocab, size=qlen).astype(np.int32)
    q_tok = np.full(config.MAX_Q, config.PAD_ID, np.int32)
    q_tok[:qlen] = q_part

    kv_k, kv_v, _ = jax.jit(prefill)(params, jnp.asarray(p), jnp.int32(plen))
    kv_k2, kv_v2, logits_split = jax.jit(extend)(
        params, kv_k, kv_v, jnp.int32(plen), jnp.asarray(q_tok))

    full = p.copy()
    full[plen: plen + qlen] = q_part
    kk_full, vv_full, _ = jax.jit(prefill)(params, jnp.asarray(full), jnp.int32(plen + qlen))

    n = plen + qlen
    np.testing.assert_allclose(np.asarray(kv_k2[:, :n]), np.asarray(kk_full[:, :n]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(kv_v2[:, :n]), np.asarray(vv_full[:, :n]),
                               atol=1e-4, rtol=1e-4)


def test_extend_logits_match_full_forward(params, entries):
    """Next-token distribution from the cached path equals the monolithic one."""
    prefill, extend, _ = entries
    plen, qlen = 16, 4
    p = _tokens(plen, DIMS.max_seq)
    q_part = RNG.integers(4, DIMS.vocab, size=qlen).astype(np.int32)
    q_tok = np.full(config.MAX_Q, config.PAD_ID, np.int32)
    q_tok[:qlen] = q_part

    kv_k, kv_v, _ = jax.jit(prefill)(params, jnp.asarray(p), jnp.int32(plen))
    _, _, logits_split = jax.jit(extend)(params, kv_k, kv_v, jnp.int32(plen),
                                         jnp.asarray(q_tok))

    full = np.concatenate([p[:plen], q_part]).astype(np.int32)
    kv0 = jnp.zeros((DIMS.n_layers, DIMS.max_seq, DIMS.n_heads, DIMS.d_head),
                    jnp.float32)
    logits_full, _, _ = model.forward_tokens(params, jnp.asarray(full),
                                             jnp.int32(0), kv0, kv0, DIMS)
    np.testing.assert_allclose(np.asarray(logits_split[qlen - 1]),
                               np.asarray(logits_full[plen + qlen - 1]),
                               atol=1e-3, rtol=1e-3)


def test_generate_stops_at_eos_and_pads_with_eos(params, entries):
    prefill, extend, generate = entries
    p = _tokens(8, DIMS.max_seq)
    kv_k, kv_v, _ = jax.jit(prefill)(params, jnp.asarray(p), jnp.int32(8))
    gen = jax.jit(generate)(params, kv_k, kv_v, jnp.int32(8),
                            jnp.int32(config.EOS_ID))
    gen = np.asarray(gen)
    assert gen.shape == (config.MAX_GEN,)
    np.testing.assert_array_equal(gen, config.EOS_ID)


def test_generate_deterministic(params, entries):
    prefill, _, generate = entries
    p = _tokens(12, DIMS.max_seq)
    kv_k, kv_v, _ = jax.jit(prefill)(params, jnp.asarray(p), jnp.int32(12))
    g1 = np.asarray(jax.jit(generate)(params, kv_k, kv_v, jnp.int32(12), jnp.int32(5)))
    g2 = np.asarray(jax.jit(generate)(params, kv_k, kv_v, jnp.int32(12), jnp.int32(5)))
    np.testing.assert_array_equal(g1, g2)
    assert g1[0] == 5


def test_generate_matches_manual_decode(params, entries):
    """The in-HLO scan decode equals a step-by-step python decode."""
    prefill, _, generate = entries
    plen = 10
    p = _tokens(plen, DIMS.max_seq)
    kv_k, kv_v, _ = jax.jit(prefill)(params, jnp.asarray(p), jnp.int32(plen))
    first = 7
    gen = np.asarray(jax.jit(generate)(params, kv_k, kv_v, jnp.int32(plen),
                                       jnp.int32(first)))

    # manual loop on the same cache
    kk, vv = kv_k, kv_v
    toks = [first]
    pos, tok, done = plen, first, False
    for _ in range(config.MAX_GEN - 1):
        logits, kk, vv = model.forward_tokens(params, jnp.asarray([tok], jnp.int32),
                                              jnp.int32(pos), kk, vv, DIMS)
        nxt = int(jnp.argmax(logits[0]))
        if done:
            nxt = config.EOS_ID
        done = done or nxt == config.EOS_ID
        toks.append(nxt)
        pos += 1
        tok = nxt
    np.testing.assert_array_equal(gen, np.asarray(toks, np.int32))


def test_rope_position_dependence():
    x = jnp.asarray(RNG.normal(size=(4, 2, 8)), jnp.float32)
    a = model.rope(x, jnp.arange(4, dtype=jnp.int32))
    b = model.rope(x, 10 + jnp.arange(4, dtype=jnp.int32))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    # position 0 is the identity rotation
    c = model.rope(x[:1], jnp.zeros(1, jnp.int32))
    np.testing.assert_allclose(np.asarray(c), np.asarray(x[:1]), atol=1e-6)


def test_rope_preserves_norm():
    x = jnp.asarray(RNG.normal(size=(6, 2, 8)), jnp.float32)
    y = model.rope(x, jnp.arange(6, dtype=jnp.int32) * 37)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1), atol=1e-4)


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(RNG.normal(size=(3, 16)), jnp.float32)
    y1 = np.asarray(model.rmsnorm(x, jnp.ones(16)))
    y2 = np.asarray(model.rmsnorm(x * 100.0, jnp.ones(16)))
    np.testing.assert_allclose(y1, y2, atol=1e-4)


def test_lm_loss_decreases_with_training_signal(params):
    """One gradient step on a repeated batch lowers the loss."""
    toks = np.tile(_tokens(24, 48), (4, 1))
    mask = np.zeros_like(toks)
    mask[:, 10:20] = 1
    toks_j, mask_j = jnp.asarray(toks), jnp.asarray(mask)
    loss0, grads = jax.value_and_grad(model.lm_loss)(params, toks_j, mask_j, DIMS)
    stepped = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    loss1 = model.lm_loss(stepped, toks_j, mask_j, DIMS)
    assert float(loss1) < float(loss0)


def test_forward_train_matches_forward_tokens(params):
    """Batched training forward (ref attention) equals the serving forward."""
    toks = _tokens(14, 32)
    logits_b = model.forward_train(params, jnp.asarray(toks[None]),
                                   DIMS._replace(max_seq=32))
    kv0 = jnp.zeros((DIMS.n_layers, 32, DIMS.n_heads, DIMS.d_head), jnp.float32)
    logits_s, _, _ = model.forward_tokens(params, jnp.asarray(toks), jnp.int32(0),
                                          kv0, kv0, DIMS._replace(max_seq=32))
    np.testing.assert_allclose(np.asarray(logits_b[0]), np.asarray(logits_s),
                               atol=2e-4, rtol=2e-4)
