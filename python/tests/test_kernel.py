"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes/dtypes/offsets; fixed cases pin the serving-shaped
configurations used by the AOT entries (prefill T=S, extend T=32, decode T=1).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import cached_attention, vmem_footprint_bytes
from compile.kernels.ref import cached_attention_ref

RNG = np.random.default_rng(42)


def _mk(T, S, H, D, dtype=jnp.float32, scale=1.0):
    q = jnp.asarray(RNG.normal(size=(T, H, D)) * scale, dtype)
    k = jnp.asarray(RNG.normal(size=(S, H, D)) * scale, dtype)
    v = jnp.asarray(RNG.normal(size=(S, H, D)) * scale, dtype)
    return q, k, v


def _check(T, S, H, D, off, dtype=jnp.float32, tol=2e-5):
    q, k, v = _mk(T, S, H, D, dtype)
    out = cached_attention(q, k, v, off)
    ref = cached_attention_ref(q, k, v, off)
    assert out.shape == (T, H, D)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ---- serving-shaped fixed cases -------------------------------------------

@pytest.mark.parametrize("T,S,H,D,off", [
    (768, 768, 3, 32, 0),    # prefill (primary backbone geometry)
    (32, 768, 3, 32, 401),   # extend
    (1, 768, 3, 32, 433),    # decode step
    (1, 768, 3, 32, 766),    # decode at the end of the budget
    (32, 768, 4, 28, 100),   # mistral-sim head geometry (non-pow2 D)
    (32, 768, 4, 20, 100),   # falcon-sim head geometry
])
def test_serving_shapes(T, S, H, D, off):
    _check(T, S, H, D, off)


def test_offset_zero_single_token():
    _check(1, 128, 2, 16, 0)


def test_full_causal_equals_ref_tril():
    """At q_offset=0, T==S, the kernel must equal plain causal attention."""
    T = S = 64
    q, k, v = _mk(T, S, 2, 16)
    out = np.asarray(cached_attention(q, k, v, 0), np.float32)
    # dense reference with tril mask
    qf, kf, vf = (np.asarray(a, np.float32) for a in (q, k, v))
    scores = np.einsum("thd,shd->hts", qf, kf) / np.sqrt(16)
    mask = np.tril(np.ones((T, S), bool))
    scores = np.where(mask[None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("hts,shd->thd", p, vf)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_garbage_cache_beyond_frontier_is_ignored():
    """Slots > q_offset+i may hold arbitrary garbage without changing output."""
    T, S, H, D, off = 4, 64, 2, 16, 10
    q, k, v = _mk(T, S, H, D)
    out1 = cached_attention(q, k, v, off)
    k2 = k.at[off + T:].set(1e6)
    v2 = v.at[off + T:].set(-1e6)
    out2 = cached_attention(q, k2, v2, off)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=0, rtol=0)


def test_bf16_inputs():
    _check(8, 128, 2, 16, 5, dtype=jnp.bfloat16, tol=2e-2)


def test_large_magnitude_stability():
    """Online softmax must not overflow with large score magnitudes."""
    q, k, v = _mk(8, 128, 2, 16, scale=30.0)
    out = np.asarray(cached_attention(q, k, v, 64))
    assert np.isfinite(out).all()


# ---- hypothesis sweep ------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    T=st.sampled_from([1, 2, 3, 8, 17, 32]),
    S=st.sampled_from([32, 48, 64, 96, 128, 256]),
    H=st.integers(1, 4),
    D=st.sampled_from([4, 8, 16, 20, 28, 32]),
    off_frac=st.floats(0.0, 1.0),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_hypothesis_sweep(T, S, H, D, off_frac, dtype):
    off = int(off_frac * max(S - T, 0))
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    _check(T, S, H, D, off, dtype, tol)


# ---- VMEM accounting -------------------------------------------------------

def test_vmem_footprint_under_budget():
    """The default tiling must fit a TPU core's VMEM with double-buffer room."""
    from compile import config
    fp = vmem_footprint_bytes(config.BLK_T, config.BLK_S, 32)
    assert fp < 2 * 1024 * 1024, f"VMEM/step {fp} too large"


def test_vmem_footprint_formula():
    assert vmem_footprint_bytes(1, 1, 1) == (1 + 2 + 1 + 3) * 4
