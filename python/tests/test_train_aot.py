"""Trainer + AOT lowering tests (tiny dims — fast)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config, model, train
from compile.aot import abstract, to_hlo_text
from compile.datasets import gen_scene_graph

DIMS = model.ModelDims(vocab=704, d_model=16, n_layers=1, n_heads=2, d_head=8,
                       d_ff=32, max_seq=64)


@pytest.fixture(scope="module")
def scene():
    return gen_scene_graph()


@pytest.fixture(scope="module")
def tok(scene):
    return train.build_tokenizer([scene])


def test_tokenizer_covers_dataset(tok, scene):
    """No dataset token may be <unk> — answers must be generatable."""
    for q in scene["queries"][:50]:
        assert config.UNK_ID not in tok.encode(q["text"])
        assert config.UNK_ID not in tok.encode(q["answer"])
    for n in scene["nodes"]:
        assert config.UNK_ID not in tok.encode(n["text"])


def test_make_examples_shapes_and_masks(tok, scene):
    rng = np.random.default_rng(0)
    toks, masks = train.make_examples(scene, tok, rng, seq_len=160)
    n_train = sum(q["split"] == "train" for q in scene["queries"])
    assert toks.shape == (2 * n_train, 160)
    assert masks.shape == toks.shape
    assert toks[0][0] == config.BOS_ID
    # every example has a supervised answer span ending in EOS
    for t, m in zip(toks[:20], masks[:20]):
        span = np.where(m > 0)[0]
        assert len(span) >= 2
        assert t[span[-1]] == config.EOS_ID
        assert (np.diff(span) == 1).all()


def test_examples_answer_inside_prompt(tok, scene):
    """Extractive QA: the answer tokens must appear inside the prompt span."""
    rng = np.random.default_rng(0)
    toks, masks = train.make_examples(scene, tok, rng, seq_len=200)
    hits = 0
    for t, m in zip(toks[:40], masks[:40]):
        span = np.where(m > 0)[0]
        ans = [x for x in t[span] if x != config.EOS_ID]
        prompt = list(t[: span[0]])
        if all(a in prompt for a in ans):
            hits += 1
    assert hits >= 36  # a few relation words may be split across clauses


def test_adamw_reduces_loss():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adamw_init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    losses = []
    for _ in range(50):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt = train.adamw_update(params, g, opt, lr=0.1)
        losses.append(float(loss))
    assert losses[-1] < 0.1 * losses[0]
    assert int(opt["step"]) == 50


def test_adamw_weight_decay_pulls_to_zero():
    params = {"w": jnp.asarray([1.0])}
    opt = train.adamw_init(params)
    for _ in range(20):
        # zero gradient: only decay acts
        params, opt = train.adamw_update(params, {"w": jnp.zeros(1)}, opt, lr=0.1)
    assert float(params["w"][0]) < 1.0


def test_save_load_weights_roundtrip(tmp_path):
    params = model.init_params(DIMS, seed=9)
    spec = train.save_weights(params, str(tmp_path / "w.npz"))
    assert [e["key"] for e in spec] == [f"p{i:03d}" for i in range(len(spec))]
    data = np.load(tmp_path / "w.npz")
    flat, _ = jax.tree_util.tree_flatten(params)
    assert len(flat) == len(spec)
    for e, leaf in zip(spec, flat):
        np.testing.assert_array_equal(data[e["key"]], np.asarray(leaf))
        assert e["shape"] == list(np.shape(leaf))


def test_flatten_order_matches_jit_parameter_order():
    """The npz order must equal the HLO parameter order (rust feeds by index)."""
    params = model.init_params(DIMS, seed=1)
    names, arrays = train.flatten_with_names(params)
    # jit flattens (params, extra...) depth-first in the same pytree order
    flat, _ = jax.tree_util.tree_flatten(params)
    for a, b in zip(arrays, flat):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_lowering_produces_parseable_hlo():
    params = model.init_params(DIMS, seed=2)
    prefill, extend, generate = model.make_entries(DIMS, use_kernel=True)
    txt = to_hlo_text(prefill, abstract(params),
                      jax.ShapeDtypeStruct((DIMS.max_seq,), jnp.int32),
                      jax.ShapeDtypeStruct((), jnp.int32))
    assert "HloModule" in txt
    assert "ENTRY" in txt


def test_entry_arg_map_is_complete_for_all_entries():
    """Every flattened argument must stay live in the lowered entry — the
    Rust runtime feeds weights positionally through arg_map."""
    from compile.aot import entry_arg_map
    params = model.init_params(DIMS, seed=2)
    n_params = len(jax.tree_util.tree_leaves(params))
    prefill, extend, generate = model.make_entries(DIMS, use_kernel=True)
    kv = jax.ShapeDtypeStruct((DIMS.n_layers, DIMS.max_seq, DIMS.n_heads,
                               DIMS.d_head), jnp.float32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    toks_s = jax.ShapeDtypeStruct((DIMS.max_seq,), jnp.int32)
    toks_q = jax.ShapeDtypeStruct((config.MAX_Q,), jnp.int32)
    cases = [
        (prefill, (abstract(params), toks_s, i32), n_params + 2),
        (extend, (abstract(params), kv, kv, i32, toks_q), n_params + 4),
        (generate, (abstract(params), kv, kv, i32, i32), n_params + 4),
    ]
    for fn, args, want in cases:
        amap = entry_arg_map(to_hlo_text(fn, *args))
        assert len(amap) == want, (fn, len(amap), want)
        assert sorted(amap) == list(range(want))


def test_entry_arg_map_detects_dead_args():
    """A function with an unused argument must yield a *shorter* map (jax
    renumbers surviving args, so the build asserts on length, not indices)."""
    from compile.aot import entry_arg_map

    def f(a, b, c):
        return a + c  # b is dead

    s = jax.ShapeDtypeStruct((4,), jnp.float32)
    amap = entry_arg_map(to_hlo_text(f, s, s, s))
    assert len(amap) == 2  # build() would reject this entry (wants 3)
