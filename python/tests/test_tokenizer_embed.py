"""Tokenizer + hash-embedder tests (the Rust side re-runs the same goldens)."""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import config
from compile.hashembed import cosine, embed_text, fnv1a
from compile.tokenizer import SPECIALS, Tokenizer, split_text


# ---- splitting -------------------------------------------------------------

def test_split_lowercases_and_separates_punct():
    assert split_text("What is the COLOR, of x_1?") == \
        ["what", "is", "the", "color", ",", "of", "x_1", "?"]


def test_split_empty_and_whitespace():
    assert split_text("") == []
    assert split_text(" \t\n ") == []


def test_split_quotes():
    assert split_text('how is " a b " connected') == \
        ["how", "is", '"', "a", "b", '"', "connected"]


@settings(max_examples=50, deadline=None)
@given(st.text(max_size=80))
def test_split_total_and_reconstructible(s):
    toks = split_text(s)
    for t in toks:
        assert t  # non-empty
        assert t == t.lower()
        # each token is either a word-run or a single symbol
        if len(t) > 1:
            assert all(c.isalnum() or c == "_" for c in t)


# ---- vocab / encode / decode ------------------------------------------------

@pytest.fixture(scope="module")
def tok():
    return Tokenizer.build(["what is the color of the cords ?",
                            "blue laptop screen graph : ; answer question"])


def test_specials_fixed(tok):
    for i, sp in enumerate(SPECIALS):
        assert tok.vocab[sp] == i
    assert config.PAD_ID == 0 and config.BOS_ID == 1
    assert config.EOS_ID == 2 and config.UNK_ID == 3


def test_encode_decode_roundtrip(tok):
    ids = tok.encode("what is the color of the cords ?")
    assert config.UNK_ID not in ids
    assert tok.decode(ids) == "what is the color of the cords ?"


def test_unknown_maps_to_unk(tok):
    assert tok.encode("zebra") == [config.UNK_ID]


def test_decode_stops_at_eos(tok):
    ids = tok.encode("blue laptop") + [config.EOS_ID] + tok.encode("screen")
    assert tok.decode(ids) == "blue laptop"


def test_build_deterministic():
    a = Tokenizer.build(["b a c", "d a"]).vocab
    b = Tokenizer.build(["d a", "b a c"]).vocab
    assert a == b


def test_padded_size(tok):
    assert tok.padded_size % 64 == 0
    assert tok.padded_size >= len(tok)


def test_save_load_roundtrip(tok, tmp_path):
    p = tmp_path / "vocab.json"
    tok.save(str(p))
    tok2 = Tokenizer.load(str(p))
    assert tok2.vocab == tok.vocab


# ---- hash embedder ----------------------------------------------------------

def test_fnv1a_known_vectors():
    # standard FNV-1a test vectors (64-bit)
    assert fnv1a(b"") == 0xCBF29CE484222325
    assert fnv1a(b"a") == 0xAF63DC4C8601EC8C
    assert fnv1a(b"foobar") == 0x85944171F73967E8


def test_embed_unit_norm():
    v = embed_text("what is the color of the cords ?")
    assert abs(float(np.linalg.norm(v)) - 1.0) < 1e-5


def test_embed_empty_is_zero():
    assert np.all(embed_text("") == 0)


def test_embed_similarity_tracks_overlap():
    a = embed_text("the red laptop on the table")
    b = embed_text("the red laptop near the chair")
    c = embed_text("graph neural network caching inference")
    assert cosine(a, b) > cosine(a, c)


def test_embed_deterministic():
    np.testing.assert_array_equal(embed_text("alpha beta"), embed_text("alpha beta"))


def test_embed_case_insensitive():
    np.testing.assert_array_equal(embed_text("Alpha BETA"), embed_text("alpha beta"))


@settings(max_examples=30, deadline=None)
@given(st.text(alphabet="abcdefgh ", max_size=60))
def test_embed_norm_property(s):
    v = embed_text(s)
    n = float(np.linalg.norm(v))
    assert n == 0.0 or abs(n - 1.0) < 1e-5
