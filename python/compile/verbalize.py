"""Canonical subgraph→prompt verbalization, mirrored by ``rust/src/graph``.

The exact byte layout matters twice: (1) the trainer teaches the LM this
format, (2) the Rust serving path reconstructs it at request time for both
baseline prompts and representative-subgraph prefixes. Golden tests pin the
two implementations together.

Format::

    graph : <node text> ; <node text> ; ... ; <src name> <rel> <dst name> ; ... ;
     question : <query text> answer :

Nodes are sorted by id, edges by (src, dst). When a token budget is given,
whole node/edge clauses are dropped from the tail (the paper likewise caps
prompt length at 1024 tokens).
"""

from typing import Dict, Iterable, List, Optional

from .tokenizer import split_text


def node_clauses(graph: Dict, node_ids: Iterable[int]) -> List[str]:
    by_id = {n["id"]: n for n in graph["nodes"]}
    return [by_id[i]["text"] for i in sorted(set(node_ids))]


def edge_clauses(graph: Dict, edge_ids: Iterable[int]) -> List[str]:
    name_of = {n["id"]: n["name"] for n in graph["nodes"]}
    picked = [graph["edges"][i] for i in sorted(set(edge_ids))]
    picked.sort(key=lambda e: (e["src"], e["dst"]))
    return [f"{name_of[e['src']]} {e['text']} {name_of[e['dst']]}" for e in picked]


def prefix_text(graph: Dict, node_ids: Iterable[int], edge_ids: Iterable[int],
                max_tokens: Optional[int] = None) -> str:
    """Verbalize a subgraph. ``max_tokens`` counts word tokens including the
    leading "graph :" and each trailing ";" (but not BOS)."""
    clauses = node_clauses(graph, node_ids) + edge_clauses(graph, edge_ids)
    out = "graph :"
    used = 2  # "graph", ":"
    for c in clauses:
        cost = len(split_text(c)) + 1  # clause + ";"
        if max_tokens is not None and used + cost > max_tokens:
            break
        out += f" {c} ;"
        used += cost
    return out


def question_text(query_text: str) -> str:
    return f" question : {query_text} answer :"


def full_prompt(graph: Dict, node_ids: Iterable[int], edge_ids: Iterable[int],
                query_text: str, max_prefix_tokens: Optional[int] = None) -> str:
    return prefix_text(graph, node_ids, edge_ids, max_prefix_tokens) + question_text(query_text)
