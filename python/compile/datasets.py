"""Synthetic dataset generators reproducing the paper's Table 1 statistics.

The originals (a GQA scene graph subset and an OAG sample) are not
redistributable here, so we generate graphs with identical statistics and the
same query styles (DESIGN.md §4):

* **Scene Graph** — 22 nodes, 147 edges, 426 queries; attribute questions
  ("what is the color of the cords ?") and spatial-relation questions,
  including the unique-source multi-hop form. Split 113/113/200.
* **OAG** — 1071 nodes, 2022 edges, 3434 link-relation-prediction queries
  ('how is "<a>" connected to "<b>" ?' → relation). Split 1617/1617/200.

Everything is seeded and deterministic; the JSON schema is consumed by both
the Python trainer and the Rust runtime.
"""

import json
import os
from typing import Dict, List

import numpy as np

from . import config

# ---------------------------------------------------------------------------
# Scene Graph
# ---------------------------------------------------------------------------

_OBJECTS = [
    "eye glasses", "laptop", "cords", "windows", "man", "woman", "jeans",
    "sweater", "screen", "pants", "shirt", "building", "camera", "jacket",
    "table", "chair", "phone", "cup", "bag", "door", "shoes", "hat",
]
_COLORS = ["black", "blue", "orange", "red", "gray", "green", "white", "brown"]
_MATERIALS = ["glass", "wood", "metal", "plastic", "leather"]
_RELATIONS = [
    "left of", "right of", "above", "below", "behind", "in front of",
    "near", "on", "wearing", "holding", "under", "beside",
]


def _node_text(name: str, color: str = "", material: str = "") -> str:
    parts = [name]
    if color:
        parts += ["color", color]
    if material:
        parts += ["material", material]
    return " ".join(parts)


def gen_scene_graph(seed: int = config.SCENE_GRAPH_SEED) -> Dict:
    rng = np.random.default_rng(seed)
    n = 22
    names = list(_OBJECTS[:n])

    nodes = []
    colors: Dict[int, str] = {}
    materials: Dict[int, str] = {}
    for i, name in enumerate(names):
        color = _COLORS[rng.integers(len(_COLORS))] if rng.random() < 0.65 else ""
        material = _MATERIALS[rng.integers(len(_MATERIALS))] if rng.random() < 0.3 else ""
        if i in (2, 4):  # the paper's example entities keep their attributes
            color = "blue" if i == 2 else color
        if color:
            colors[i] = color
        if material:
            materials[i] = material
        nodes.append({"id": i, "name": name, "text": _node_text(name, color, material)})

    # 147 distinct directed edges over 22 nodes, one relation per ordered pair.
    pairs = [(a, b) for a in range(n) for b in range(n) if a != b]
    idx = rng.permutation(len(pairs))[:147]
    edges = []
    rel_of: Dict[tuple, str] = {}
    for k in sorted(idx.tolist()):
        a, b = pairs[k]
        rel = _RELATIONS[rng.integers(len(_RELATIONS))]
        rel_of[(a, b)] = rel
        edges.append({"src": a, "dst": b, "text": rel})

    # Query pool: attribute, relation, and unique-source (multi-hop) styles.
    pool = []

    def support_edges_of(node_id: int) -> List[int]:
        return [ei for ei, e in enumerate(edges) if e["src"] == node_id or e["dst"] == node_id][:4]

    for i, c in sorted(colors.items()):
        pool.append({"text": f"what is the color of the {names[i]} ?", "answer": c,
                     "support_nodes": [i], "support_edges": support_edges_of(i)[:2]})
        pool.append({"text": f"what color is the {names[i]} ?", "answer": c,
                     "support_nodes": [i], "support_edges": support_edges_of(i)[:2]})
    for i, m in sorted(materials.items()):
        pool.append({"text": f"what is the material of the {names[i]} ?", "answer": m,
                     "support_nodes": [i], "support_edges": support_edges_of(i)[:2]})
    for ei, e in enumerate(edges):
        a, b = e["src"], e["dst"]
        pool.append({"text": f"what is the relation between the {names[a]} and the {names[b]} ?",
                     "answer": e["text"], "support_nodes": [a, b], "support_edges": [ei]})
        pool.append({"text": f"how is the {names[a]} related to the {names[b]} ?",
                     "answer": e["text"], "support_nodes": [a, b], "support_edges": [ei]})
    # unique-source: exactly one edge (x, rel, b) -> answer x.
    from collections import defaultdict
    by_rel_dst = defaultdict(list)
    for ei, e in enumerate(edges):
        by_rel_dst[(e["text"], e["dst"])].append(ei)
    for (rel, b), eis in sorted(by_rel_dst.items()):
        if len(eis) == 1:
            a = edges[eis[0]]["src"]
            pool.append({"text": f"what is {rel} the {names[b]} ?", "answer": names[a],
                         "support_nodes": [a, b], "support_edges": eis})
            pool.append({"text": f"which object is {rel} the {names[b]} ?", "answer": names[a],
                         "support_nodes": [a, b], "support_edges": eis})

    order = rng.permutation(len(pool))[:426]
    queries = []
    for qid, k in enumerate(order.tolist()):
        q = dict(pool[k])
        q["id"] = qid
        q["split"] = "train" if qid < 113 else ("val" if qid < 226 else "test")
        queries.append(q)
    assert len(queries) == 426
    return {"name": "scene_graph", "nodes": nodes, "edges": edges, "queries": queries}


# ---------------------------------------------------------------------------
# OAG
# ---------------------------------------------------------------------------

_TOPICS = [
    "graph", "neural", "networks", "retrieval", "augmented", "generation",
    "language", "models", "caching", "inference", "latency", "attention",
    "transformer", "knowledge", "reasoning", "clustering", "embedding",
    "scene", "understanding", "video", "surveillance", "tabletops",
    "interface", "learning", "systems", "databases", "query", "processing",
    "batch", "spatial", "indexing", "vision", "detection", "segmentation",
    "recommendation", "ranking", "search", "hashing", "distributed",
    "scheduling", "memory", "compression", "pruning", "alignment",
]
_FIRST = ["wei", "li", "ana", "jose", "emma", "noah", "olivia", "liam", "mia",
          "lucas", "sofia", "ethan", "nina", "omar", "ivan", "yuki", "chen",
          "raj", "zoe", "marco"]
_LAST = ["zhang", "smith", "garcia", "kumar", "tanaka", "mueller", "rossi",
         "novak", "silva", "khan", "lee", "brown", "wilson", "martin",
         "lopez", "dubois", "ivanov", "yamamoto", "olsen", "costa"]
_CITIES = ["castilla", "copenhagen", "london", "singapore", "toronto",
           "zurich", "melbourne", "austin", "kyoto", "munich", "lyon",
           "oslo", "porto", "seoul", "taipei", "delhi", "cairo", "quito",
           "lima", "bergen"]
_FIELDS = [
    "artificial intelligence", "computer vision", "machine learning",
    "natural language processing", "information retrieval", "data mining",
    "computer graphics", "human computer interaction", "databases",
    "distributed systems", "computer networks", "software engineering",
    "operating systems", "computer security", "computational biology",
    "robotics", "speech processing", "computer architecture",
    "programming languages", "theory of computation", "graph mining",
    "recommender systems", "knowledge graphs", "computer science",
]

N_FIELDS, N_AFFILS, N_AUTHORS, N_PAPERS = 24, 40, 400, 607  # = 1071 nodes
OAG_EDGES = 2022


def gen_oag(seed: int = config.OAG_SEED) -> Dict:
    rng = np.random.default_rng(seed)
    nodes = []
    # fields, affiliations, authors, papers — contiguous id ranges.
    for f in _FIELDS[:N_FIELDS]:
        nodes.append({"id": len(nodes), "name": f, "text": f})
    for i in range(N_AFFILS):
        name = f"university of {_CITIES[i % len(_CITIES)]}" if i < len(_CITIES) \
            else f"{_CITIES[i % len(_CITIES)]} institute of technology"
        nodes.append({"id": len(nodes), "name": name, "text": name})
    author_names = set()
    while len(author_names) < N_AUTHORS:
        author_names.add(f"{_FIRST[rng.integers(len(_FIRST))]} {_LAST[rng.integers(len(_LAST))]}"
                         f" {rng.integers(10)}")
    for name in sorted(author_names):
        nodes.append({"id": len(nodes), "name": name, "text": name})
    for _ in range(N_PAPERS):
        k = int(rng.integers(4, 7))
        words = [_TOPICS[rng.integers(len(_TOPICS))] for _ in range(k)]
        title = " ".join(words)
        nodes.append({"id": len(nodes), "name": title, "text": title})
    assert len(nodes) == 1071

    field_ids = range(0, N_FIELDS)
    affil_ids = range(N_FIELDS, N_FIELDS + N_AFFILS)
    author_ids = range(N_FIELDS + N_AFFILS, N_FIELDS + N_AFFILS + N_AUTHORS)
    paper_ids = range(N_FIELDS + N_AFFILS + N_AUTHORS, 1071)

    edges = []
    seen = set()

    def add(src: int, dst: int, rel: str) -> bool:
        if (src, dst) in seen or src == dst:
            return False
        seen.add((src, dst))
        edges.append({"src": int(src), "dst": int(dst), "text": rel})
        return True

    for p in paper_ids:  # every paper is answerable for written_by/focuses_on
        add(p, int(rng.choice(author_ids)), "written by")
        add(p, int(rng.choice(field_ids)), "focuses on")
    for i, a in enumerate(author_ids):  # affiliation membership
        if i % 2 == 0:
            add(int(rng.choice(affil_ids)), a, "has member")
    extra_writers = 0
    while len(edges) < OAG_EDGES - 300:
        add(int(rng.choice(paper_ids)), int(rng.choice(author_ids)), "written by")
        extra_writers += 1
    while len(edges) < OAG_EDGES:
        add(int(rng.choice(paper_ids)), int(rng.choice(paper_ids)), "cites")
    assert len(edges) == OAG_EDGES

    # 3434 relation-prediction queries over the edges (two phrasings).
    name_of = {nd["id"]: nd["name"] for nd in nodes}
    pool = []
    for ei, e in enumerate(edges):
        a, b = name_of[e["src"]], name_of[e["dst"]]
        pool.append({"text": f'how is " {a} " connected to " {b} " ?', "answer": e["text"],
                     "support_nodes": [e["src"], e["dst"]], "support_edges": [ei]})
        pool.append({"text": f'what is the relation between " {a} " and " {b} " ?',
                     "answer": e["text"],
                     "support_nodes": [e["src"], e["dst"]], "support_edges": [ei]})
    order = rng.permutation(len(pool))[:3434]
    queries = []
    for qid, k in enumerate(order.tolist()):
        q = dict(pool[k])
        q["id"] = qid
        q["split"] = "train" if qid < 1617 else ("val" if qid < 3234 else "test")
        queries.append(q)
    assert len(queries) == 3434
    return {"name": "oag", "nodes": nodes, "edges": edges, "queries": queries}


def write_datasets(out_dir: str) -> List[str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for gen in (gen_scene_graph, gen_oag):
        ds = gen()
        path = os.path.join(out_dir, f"{ds['name']}.json")
        with open(path, "w") as f:
            json.dump(ds, f)
        paths.append(path)
    return paths
