"""L1 Pallas kernel: KV-cache-aware tiled attention (flash-style).

The compute hot-spot of prefill, extend and decode. TPU-shaped even though it
executes here under ``interpret=True`` (the CPU PJRT plugin cannot run Mosaic
custom-calls — see /opt/xla-example/README.md):

* grid = (heads, query tiles, kv tiles), kv innermost so one ``(BLK_T,
  BLK_S)`` score tile is live at a time;
* the BlockSpecs express the HBM↔VMEM schedule a CUDA version would do with
  threadblocks + shared memory: K/V stream through VMEM tile by tile while an
  online-softmax accumulator (m, l, acc) lives in VMEM scratch;
* accumulation is always f32 regardless of input dtype (MXU-style).

VMEM budget per grid step (f32 words): BLK_T·D + 2·BLK_S·D + BLK_T·BLK_S +
scratch (BLK_T·(D+2)) ≈ 82 KB at (64, 128, D=32) — far below the ~16 MB VMEM
of a TPU core, leaving headroom for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import config


def _attention_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                      *, blk_t: int, blk_s: int, n_s_blocks: int, scale: float):
    """One (head, q-tile, kv-tile) grid step of the online-softmax recurrence."""
    s_idx = pl.program_id(2)
    t_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)[:, 0, :]  # [BLK_T, D]
    k = k_ref[...].astype(jnp.float32)[:, 0, :]  # [BLK_S, D]
    v = v_ref[...].astype(jnp.float32)[:, 0, :]  # [BLK_S, D]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [BLK_T, BLK_S]

    # Causal mask in absolute positions: row i (at q_offset + t_idx*BLK_T + i)
    # may attend to cache slot j (at s_idx*BLK_S + j) iff slot <= row position.
    off = off_ref[0, 0]
    rows = t_idx * blk_t + jax.lax.broadcasted_iota(jnp.int32, (blk_t, blk_s), 0)
    cols = s_idx * blk_s + jax.lax.broadcasted_iota(jnp.int32, (blk_t, blk_s), 1)
    scores = jnp.where(cols <= off + rows, scores, -1e30)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(s_idx == n_s_blocks - 1)
    def _flush():
        out = acc_ref[...] / l_ref[...]
        o_ref[...] = out[:, None, :].astype(o_ref.dtype)


@functools.partial(jax.named_call, name="cached_attention")
def cached_attention(q, k, v, q_offset, *, blk_t: int = config.BLK_T,
                     blk_s: int = config.BLK_S):
    """Pallas cached attention; same contract as ``ref.cached_attention_ref``.

    ``T`` and ``S`` need not be tile multiples: the tile sizes are clamped to
    the actual extents (AOT entry points use a handful of static shapes, so
    each lowering picks its own tiling).
    """
    T, H, D = q.shape
    S = k.shape[0]
    blk_t = min(blk_t, T)
    blk_s = min(blk_s, S)
    if T % blk_t:  # fall back to one row per tile rather than padding
        blk_t = 1
    if S % blk_s:
        blk_s = next(b for b in (64, 32, 16, 8, 4, 2, 1) if S % b == 0)
    n_t, n_s = T // blk_t, S // blk_s
    scale = 1.0 / (D ** 0.5)

    off = jnp.asarray(q_offset, jnp.int32).reshape(1, 1)
    kernel = functools.partial(
        _attention_kernel, blk_t=blk_t, blk_s=blk_s, n_s_blocks=n_s, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(H, n_t, n_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, t, s: (0, 0)),  # q_offset scalar
            pl.BlockSpec((blk_t, 1, D), lambda h, t, s: (t, h, 0)),  # q tile
            pl.BlockSpec((blk_s, 1, D), lambda h, t, s: (s, h, 0)),  # k tile
            pl.BlockSpec((blk_s, 1, D), lambda h, t, s: (s, h, 0)),  # v tile
        ],
        out_specs=pl.BlockSpec((blk_t, 1, D), lambda h, t, s: (t, h, 0)),
        out_shape=jax.ShapeDtypeStruct((T, H, D), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY(shape=(blk_t, 1), dtype=jnp.float32),  # m
            pl.MemorySpace.ANY(shape=(blk_t, 1), dtype=jnp.float32),  # l
            pl.MemorySpace.ANY(shape=(blk_t, D), dtype=jnp.float32),  # acc
        ],
        interpret=True,
    )(off, q, k, v)


def vmem_footprint_bytes(blk_t: int, blk_s: int, d: int, elt: int = 4) -> int:
    """Analytic VMEM bytes per grid step (used by the §Perf accounting)."""
    tiles = blk_t * d + 2 * blk_s * d + blk_t * blk_s  # q + k,v + scores
    scratch = blk_t * (d + 2)
    return (tiles + scratch) * elt
