"""Pure-jnp oracle for the cached-attention kernel.

This is the CORE correctness signal: the Pallas kernel (and therefore every
AOT artifact built on it) is validated against this reference by pytest +
hypothesis sweeps.
"""

import jax.numpy as jnp


def cached_attention_ref(q, k, v, q_offset):
    """Masked scaled-dot-product attention over a KV cache.

    Args:
      q: ``[T, H, D]`` query block whose row ``i`` sits at absolute sequence
         position ``q_offset + i``.
      k, v: ``[S, H, D]`` KV cache. Slots ``> q_offset + i`` may hold garbage
         (unwritten cache) — the causal mask guarantees they are ignored.
      q_offset: scalar i32, absolute position of ``q[0]``.

    Returns:
      ``[T, H, D]`` attention output, same dtype as ``q``.
    """
    T, H, D = q.shape
    S = k.shape[0]
    dtype = q.dtype
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    scores = jnp.einsum("thd,shd->hts", qf, kf) * scale  # [H, T, S]
    i = jnp.arange(T)[None, :, None]
    j = jnp.arange(S)[None, None, :]
    mask = j <= (q_offset + i)
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hts,shd->thd", p, vf)
    return out.astype(dtype)
