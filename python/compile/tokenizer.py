"""Word-level tokenizer, mirrored byte-for-byte by ``rust/src/tokenizer``.

The tokenization rule is deliberately trivial so the two implementations can
be proven identical with golden tests: lowercase the text, then emit maximal
runs of ``[a-z0-9_]`` and every other non-whitespace character as its own
token.
"""

import json
import re
from typing import Dict, Iterable, List

from . import config

_TOKEN_RE = re.compile(r"[a-z0-9_]+|[^\sa-z0-9_]")

SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]


def split_text(text: str) -> List[str]:
    """Split ``text`` into word tokens (lowercased)."""
    return _TOKEN_RE.findall(text.lower())


class Tokenizer:
    """Vocabulary-backed word tokenizer."""

    def __init__(self, vocab: Dict[str, int]):
        for i, sp in enumerate(SPECIALS):
            if vocab.get(sp) != i:
                raise ValueError(f"special token {sp} must map to id {i}")
        self.vocab = vocab
        self.inv = {i: t for t, i in vocab.items()}

    @classmethod
    def build(cls, corpus: Iterable[str]) -> "Tokenizer":
        """Build a vocabulary over ``corpus``; ids are assigned in sorted
        token order after the specials, so the mapping is deterministic."""
        tokens = set()
        for text in corpus:
            tokens.update(split_text(text))
        vocab = {sp: i for i, sp in enumerate(SPECIALS)}
        for tok in sorted(tokens):
            vocab[tok] = len(vocab)
        return cls(vocab)

    def __len__(self) -> int:
        return len(self.vocab)

    @property
    def padded_size(self) -> int:
        """Vocab size rounded up to a multiple of 64 (MXU-friendly lm head)."""
        return (len(self.vocab) + 63) // 64 * 64

    def encode(self, text: str) -> List[int]:
        unk = config.UNK_ID
        return [self.vocab.get(tok, unk) for tok in split_text(text)]

    def decode(self, ids: Iterable[int]) -> str:
        words = []
        for i in ids:
            i = int(i)
            if i == config.EOS_ID:
                break
            if i in (config.PAD_ID, config.BOS_ID):
                continue
            words.append(self.inv.get(i, "<unk>"))
        return " ".join(words)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.vocab, f, indent=0, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "Tokenizer":
        with open(path) as f:
            return cls(json.load(f))
