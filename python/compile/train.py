"""Build-time trainer for the simulated LLM backbones.

Trains each toy backbone (config.BACKBONES) on the synthetic datasets' train
splits: extractive graph-QA in the exact verbalization format the Rust
serving path reconstructs at request time. Two prompt styles per query —
a retrieval-sized subgraph and a merged (representative-subgraph-style)
union — so cached-prefix prompts are in-distribution (DESIGN.md §2).

Optimizer: hand-rolled AdamW (optax is not installable offline). The paper
trains its (frozen-LLM) soft prompts with AdamW/1e-5; our from-scratch toy
models need a larger lr — recorded as a substitution in DESIGN.md.
"""

import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import config, model, verbalize
from .tokenizer import Tokenizer

ANS_BUDGET = 6  # answer tokens + <eos>


# ---------------------------------------------------------------------------
# Tokenizer construction
# ---------------------------------------------------------------------------

def build_tokenizer(datasets: List[Dict]) -> Tokenizer:
    from .synth import pool_corpus
    corpus = ["graph : ; question answer ? \"", "which object is related how"]
    corpus += pool_corpus()  # synthetic-sampler coverage
    for ds in datasets:
        corpus += [n["text"] for n in ds["nodes"]]
        corpus += [n["name"] for n in ds["nodes"]]
        corpus += [e["text"] for e in ds["edges"]]
        corpus += [q["text"] for q in ds["queries"]]
        corpus += [q["answer"] for q in ds["queries"]]
    return Tokenizer.build(corpus)


# ---------------------------------------------------------------------------
# Example construction
# ---------------------------------------------------------------------------

def _example_tokens(tok: Tokenizer, graph: Dict, nodes, edges, q: Dict,
                    seq_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """Tokenize one (subgraph, question, answer) example, padded to seq_len."""
    ans_ids = tok.encode(q["answer"])[: ANS_BUDGET - 1] + [config.EOS_ID]
    q_ids = tok.encode(verbalize.question_text(q["text"]))
    max_prefix = seq_len - 1 - len(q_ids) - len(ans_ids)
    prefix = verbalize.prefix_text(graph, nodes, edges, max_tokens=max_prefix)
    ids = [config.BOS_ID] + tok.encode(prefix) + q_ids
    tokens = np.full(seq_len, config.PAD_ID, np.int32)
    mask = np.zeros(seq_len, np.int32)
    n = min(len(ids), seq_len - len(ans_ids))
    tokens[:n] = ids[:n]
    tokens[n: n + len(ans_ids)] = ans_ids
    # loss over the answer span; include the position of the first answer
    # token's *target* by masking from n (predicting tokens[n] uses n-1).
    mask[n: n + len(ans_ids)] = 1
    return tokens, mask


def make_examples(ds: Dict, tok: Tokenizer, rng: np.random.Generator,
                  seq_len: int = config.TRAIN_SEQ) -> Tuple[np.ndarray, np.ndarray]:
    """Two examples per training query: retrieval-sized and merged-style."""
    train_qs = [q for q in ds["queries"] if q["split"] == "train"]
    n_edges = len(ds["edges"])
    toks, masks = [], []
    for q in train_qs:
        for merged in (False, True):
            nodes = set(q["support_nodes"])
            edges = set(q["support_edges"])
            if merged:  # union with other queries' supports (representative style)
                for _ in range(int(rng.integers(1, 4))):
                    other = train_qs[rng.integers(len(train_qs))]
                    nodes.update(other["support_nodes"])
                    edges.update(other["support_edges"])
            # distractor edges + their endpoints
            for _ in range(int(rng.integers(3, 9))):
                ei = int(rng.integers(n_edges))
                edges.add(ei)
            for ei in edges:
                e = ds["edges"][ei]
                nodes.update((e["src"], e["dst"]))
            t, m = _example_tokens(tok, ds, sorted(nodes), sorted(edges), q, seq_len)
            toks.append(t)
            masks.append(m)
    return np.stack(toks), np.stack(masks)


def balance_examples(per_dataset, rng: np.random.Generator):
    """Oversample smaller datasets to parity, then shuffle.

    Without this, Scene Graph (226 examples) is swamped 14:1 by OAG (3234)
    and the model never learns the scene-QA format (observed: 6% vs 90%+
    teacher-forced ACC per dataset).
    """
    target = max(t.shape[0] for t, _ in per_dataset)
    toks, masks = [], []
    for t, m in per_dataset:
        reps = int(np.ceil(target / t.shape[0]))
        toks.append(np.tile(t, (reps, 1))[:target])
        masks.append(np.tile(m, (reps, 1))[:target])
    toks = np.concatenate(toks)
    masks = np.concatenate(masks)
    order = rng.permutation(toks.shape[0])
    return toks[order], masks[order]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, wd=0.05, b1=0.9, b2=0.999, eps=1e-8):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    leaves, treedef = jax.tree_util.tree_flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    new_v = treedef.unflatten([l[2] for l in leaves])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

def train_backbone(backbone: config.Backbone, dims: model.ModelDims,
                   toks: np.ndarray, masks: np.ndarray,
                   steps: int = None, log_every: int = 100) -> Dict:
    steps = steps or backbone.train_steps
    params = model.init_params(dims, backbone.seed)
    opt = adamw_init(params)
    rng = np.random.default_rng(backbone.seed)
    warmup = 30

    @jax.jit
    def train_step(params, opt, batch_t, batch_m, lr):
        loss, grads = jax.value_and_grad(model.lm_loss)(params, batch_t, batch_m, dims)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    n = toks.shape[0]
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, size=config.TRAIN_BATCH)
        # linear warmup then cosine decay to 10% of the base lr
        wu = min(1.0, (s + 1) / warmup)
        cos = 0.55 + 0.45 * np.cos(np.pi * s / steps)
        lr = backbone.lr * wu * cos
        params, opt, loss = train_step(params, opt, jnp.asarray(toks[idx]),
                                       jnp.asarray(masks[idx]), jnp.float32(lr))
        if s % log_every == 0 or s == steps - 1:
            print(f"  [{backbone.name}] step {s:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


def teacher_forced_acc(params, dims, toks: np.ndarray, masks: np.ndarray,
                       limit: int = 64) -> float:
    """Fraction of examples whose entire answer span is argmax-correct."""
    fwd = jax.jit(lambda t: model.forward_train(params, t, dims))
    hits, total = 0, 0
    for i in range(0, min(limit, toks.shape[0]), 8):
        bt = jnp.asarray(toks[i: i + 8])
        bm = masks[i: i + 8]
        logits = np.asarray(fwd(bt))
        pred = logits[:, :-1].argmax(-1)
        tgt = np.asarray(bt)[:, 1:]
        m = bm[:, 1:] > 0
        for b in range(bt.shape[0]):
            if m[b].sum() == 0:
                continue
            hits += int((pred[b][m[b]] == tgt[b][m[b]]).all())
            total += 1
    return hits / max(total, 1)


# ---------------------------------------------------------------------------
# Weight export
# ---------------------------------------------------------------------------

def flatten_with_names(params) -> Tuple[List[str], List[np.ndarray]]:
    """Flatten a pytree in jax order, producing stable path names."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    names, arrays = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        arrays.append(np.asarray(leaf))
    return names, arrays


def save_weights(params, path: str) -> List[Dict]:
    """Save flattened params as p000..pNNN; return the manifest spec."""
    names, arrays = flatten_with_names(params)
    spec = []
    payload = {}
    for i, (name, arr) in enumerate(zip(names, arrays)):
        key = f"p{i:03d}"
        payload[key] = arr
        spec.append({"key": key, "path": name, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **payload)
    return spec
