"""Synthetic graph-QA sampler for training the simulated backbones.

Training only on the benchmark's (fixed-graph) queries lets a small model
memorize the query→answer map instead of learning extraction: we measured
100% teacher-forced ACC on train prompts but 5% on held-out test queries.
The fix is the standard in-context-learning recipe: procedurally sample a
fresh random graph per example, so the same question text has a different
answer depending on the prompt — copy-from-context becomes the only winning
strategy, which then transfers to the real benchmark graphs.

Samplers mirror both benchmark families (scene-style attribute/relation QA
and OAG-style quoted link prediction) and verbalize through the canonical
``verbalize`` code path so formats match serving byte-for-byte.
"""

from typing import Dict, Tuple

import numpy as np

from . import config
from .datasets import (_COLORS, _FIELDS, _FIRST, _LAST, _MATERIALS, _OBJECTS,
                       _RELATIONS, _TOPICS, _CITIES)


def pool_corpus() -> list:
    """Every pool word (tokenizer coverage for synthetic samples)."""
    return [" ".join(_OBJECTS), " ".join(_COLORS), " ".join(_MATERIALS),
            " ".join(_RELATIONS), " ".join(_TOPICS), " ".join(_FIRST),
            " ".join(_LAST), " ".join(_CITIES), " ".join(_FIELDS),
            "university of institute technology written by focuses on cites has member"]


def _mk_graph(nodes, edges) -> Dict:
    return {
        "nodes": [{"id": i, "name": nm, "text": tx} for i, (nm, tx) in enumerate(nodes)],
        "edges": [{"src": a, "dst": b, "text": r} for a, b, r in edges],
    }


def sample_scene(rng: np.random.Generator) -> Tuple[Dict, str, str]:
    """Random scene-style graph + one QA pair. Returns (graph, question, answer)."""
    n = int(rng.integers(4, 11))
    idx = rng.permutation(len(_OBJECTS))[:n]
    names = [_OBJECTS[i] for i in idx]
    colors, materials, nodes = {}, {}, []
    for i, nm in enumerate(names):
        c = _COLORS[rng.integers(len(_COLORS))] if rng.random() < 0.6 else ""
        m = _MATERIALS[rng.integers(len(_MATERIALS))] if rng.random() < 0.3 else ""
        parts = [nm] + (["color", c] if c else []) + (["material", m] if m else [])
        if c:
            colors[i] = c
        if m:
            materials[i] = m
        nodes.append((nm, " ".join(parts)))

    n_edges = int(rng.integers(4, 14))
    seen, edges = set(), []
    for _ in range(n_edges * 3):
        a, b = int(rng.integers(n)), int(rng.integers(n))
        if a == b or (a, b) in seen:
            continue
        seen.add((a, b))
        edges.append((a, b, _RELATIONS[rng.integers(len(_RELATIONS))]))
        if len(edges) >= n_edges:
            break

    # question styles (answer always extractive from the sampled graph)
    styles = []
    if colors:
        styles += ["color"] * 2
    if materials:
        styles.append("material")
    if edges:
        styles += ["rel", "rel2", "src"] * 2
    style = styles[rng.integers(len(styles))]
    if style == "color":
        i = list(colors)[rng.integers(len(colors))]
        qa = (f"what is the color of the {names[i]} ?", colors[i]) if rng.random() < 0.5 \
            else (f"what color is the {names[i]} ?", colors[i])
    elif style == "material":
        i = list(materials)[rng.integers(len(materials))]
        qa = (f"what is the material of the {names[i]} ?", materials[i])
    elif style in ("rel", "rel2"):
        a, b, r = edges[rng.integers(len(edges))]
        qa = (f"what is the relation between the {names[a]} and the {names[b]} ?", r) \
            if style == "rel" else (f"how is the {names[a]} related to the {names[b]} ?", r)
    else:  # unique-source
        from collections import defaultdict
        by = defaultdict(list)
        for a, b, r in edges:
            by[(r, b)].append(a)
        uniq = [(r, b, srcs[0]) for (r, b), srcs in by.items() if len(srcs) == 1]
        if not uniq:
            a, b, r = edges[rng.integers(len(edges))]
            qa = (f"what is the relation between the {names[a]} and the {names[b]} ?", r)
        else:
            r, b, a = uniq[rng.integers(len(uniq))]
            qa = (f"what is {r} the {names[b]} ?", names[a]) if rng.random() < 0.5 \
                else (f"which object is {r} the {names[b]} ?", names[a])
    return _mk_graph(nodes, edges), qa[0], qa[1]


def sample_oag(rng: np.random.Generator) -> Tuple[Dict, str, str]:
    """Random OAG-style graph + one quoted link-prediction QA pair."""
    nodes = []
    kinds = []  # 'p' | 'a' | 'f' | 'u'
    for _ in range(int(rng.integers(2, 5))):  # papers
        k = int(rng.integers(4, 7))
        t = " ".join(_TOPICS[rng.integers(len(_TOPICS))] for _ in range(k))
        nodes.append((t, t))
        kinds.append("p")
    for _ in range(int(rng.integers(1, 4))):  # authors
        nm = f"{_FIRST[rng.integers(len(_FIRST))]} {_LAST[rng.integers(len(_LAST))]} " \
             f"{rng.integers(10)}"
        nodes.append((nm, nm))
        kinds.append("a")
    for _ in range(int(rng.integers(1, 3))):  # fields
        f = _FIELDS[rng.integers(len(_FIELDS))]
        nodes.append((f, f))
        kinds.append("f")
    if rng.random() < 0.5:  # affiliation
        u = f"university of {_CITIES[rng.integers(len(_CITIES))]}"
        nodes.append((u, u))
        kinds.append("u")

    papers = [i for i, k in enumerate(kinds) if k == "p"]
    authors = [i for i, k in enumerate(kinds) if k == "a"]
    fields = [i for i, k in enumerate(kinds) if k == "f"]
    affils = [i for i, k in enumerate(kinds) if k == "u"]

    seen, edges = set(), []

    def add(a, b, r):
        if a != b and (a, b) not in seen:
            seen.add((a, b))
            edges.append((a, b, r))

    for p in papers:
        add(p, authors[rng.integers(len(authors))], "written by")
        if fields and rng.random() < 0.9:
            add(p, fields[rng.integers(len(fields))], "focuses on")
        if len(papers) > 1 and rng.random() < 0.5:
            add(p, papers[rng.integers(len(papers))], "cites")
    for u in affils:
        add(u, authors[rng.integers(len(authors))], "has member")

    a, b, r = edges[rng.integers(len(edges))]
    na, nb = nodes[a][0], nodes[b][0]
    q = f'how is " {na} " connected to " {nb} " ?' if rng.random() < 0.5 \
        else f'what is the relation between " {na} " and " {nb} " ?'
    return _mk_graph(nodes, edges), q, r


def sample_example(rng: np.random.Generator, tok, seq_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """One tokenized training example from either family."""
    from .train import _example_tokens
    g, qtext, ans = (sample_scene if rng.random() < 0.5 else sample_oag)(rng)
    q = {"text": qtext, "answer": ans}
    nodes = range(len(g["nodes"]))
    edges = range(len(g["edges"]))
    return _example_tokens(tok, g, nodes, edges, q, seq_len)


def make_synth_examples(n: int, tok, rng: np.random.Generator,
                        seq_len: int = config.TRAIN_SEQ) -> Tuple[np.ndarray, np.ndarray]:
    toks, masks = [], []
    for _ in range(n):
        t, m = sample_example(rng, tok, seq_len)
        toks.append(t)
        masks.append(m)
    return np.stack(toks), np.stack(masks)
