"""Shared build-time configuration for the SubGCache compile path.

Everything here is baked into the AOT artifacts and mirrored (via
``artifacts/manifest.json``) into the Rust runtime — keep it the single
source of truth for shapes and backbone definitions.
"""

from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Sequence geometry (static — AOT requires fixed shapes).
# ---------------------------------------------------------------------------
MAX_SEQ = 768  # total KV budget: prefix + query + generation
MAX_Q = 32  # query (question) token budget for the `extend` entry
MAX_GEN = 32  # greedy decode budget for the `generate` entry
MAX_PREFIX = MAX_SEQ - MAX_Q - MAX_GEN  # 704

# ---------------------------------------------------------------------------
# Hash embedder / GNN geometry.
# ---------------------------------------------------------------------------
FEAT_DIM = 64  # FNV bag-of-tokens feature dim (SentenceBERT substitute)
GNN_HIDDEN = 64
GNN_LAYERS = 4
GNN_HEADS = 4
GNN_EMB = 64  # subgraph embedding dim used for clustering
N_MAX = 64  # max nodes of a retrieved subgraph fed to the GNN


@dataclass(frozen=True)
class Backbone:
    """A toy decoder-only LM standing in for one of the paper's backbones.

    The paper's latency claims hinge on *where* prefill FLOPs are spent, not
    on model scale, so each simulated backbone keeps the architecture family
    distinct (depth/width/head layout) while staying trainable on CPU.
    """

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ff: int
    seed: int
    train_steps: int
    lr: float = 3e-3

    @property
    def params_note(self) -> str:
        return f"{self.name}: L={self.n_layers} d={self.d_model} H={self.n_heads}"


BACKBONES = {
    "llama-3.2-3b-sim": Backbone("llama-3.2-3b-sim", 96, 3, 3, 32, 192, seed=11, train_steps=1300),
    "llama-2-7b-sim": Backbone("llama-2-7b-sim", 96, 4, 3, 32, 192, seed=23, train_steps=800),
    "mistral-7b-sim": Backbone("mistral-7b-sim", 112, 4, 4, 28, 224, seed=37, train_steps=800),
    "falcon-7b-sim": Backbone("falcon-7b-sim", 80, 3, 4, 20, 160, seed=53, train_steps=800),
}
PRIMARY_BACKBONE = "llama-3.2-3b-sim"

# Pallas attention kernel tiling (VMEM-oriented; see DESIGN.md §5).
BLK_T = 64  # query tile
BLK_S = 128  # key/value tile streamed through VMEM

# Special token ids — fixed, the tokenizer builds vocab around them.
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
UNK_ID = 3

# Dataset generation seeds (Table 1 statistics are reproduced exactly).
SCENE_GRAPH_SEED = 7
OAG_SEED = 13

# Training-time sequence budget (shorter than MAX_SEQ for CPU speed; RoPE +
# extractive answers + merged-prompt augmentation give length generalization).
TRAIN_SEQ = 320
TRAIN_BATCH = 8
