"""L2: decoder-only transformer LM with an explicit KV cache.

Three static-shaped entry points per backbone are AOT-lowered for the Rust
runtime (DESIGN.md §2):

* ``prefill(params, tokens[S])               -> (kv_k, kv_v)``
* ``extend (params, kv_k, kv_v, plen, q[Q])  -> (kv_k', kv_v', logits[Q,V])``
* ``generate(params, kv_k, kv_v, cur, tok)   -> gen[G]`` (greedy scan decode)

Cache-slot invariant: KV slot ``j`` always holds the KV of absolute sequence
position ``j``. Prefill writes slots ``[0,S)`` (garbage beyond the real
prefix length — provably never attended, because the causal mask only admits
slots ``<= position`` and positions never exceed the written frontier).
Extend writes ``[plen, plen+Q)``, decode writes one slot per step at its own
position. This is what makes ``prefill(p) ⊕ extend(q)`` numerically
equivalent to ``prefill(p ⊕ q)`` (up to tiling-order float association) —
the correctness core of SubGCache (tested in
``tests/test_model.py`` and again from Rust).

Training uses the pure-jnp reference attention (fast on CPU); serving
artifacts use the Pallas kernel. Both are pinned together by the kernel
tests, and the prefill/extend consistency tests run on the Pallas path.
"""

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import config
from .kernels.attention import cached_attention
from .kernels.ref import cached_attention_ref

EPS = 1e-6
ROPE_BASE = 10000.0


class ModelDims(NamedTuple):
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ff: int
    max_seq: int = config.MAX_SEQ


def dims_for(backbone: config.Backbone, vocab: int) -> ModelDims:
    return ModelDims(vocab, backbone.d_model, backbone.n_layers,
                     backbone.n_heads, backbone.d_head, backbone.d_ff)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(dims: ModelDims, seed: int) -> Dict:
    """Deterministic init. Layout is a nested dict; the AOT manifest records
    the tree-flatten order so Rust feeds weights positionally."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + dims.n_layers)

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(jnp.float32)

    params = {
        "embed": dense(ks[0], dims.d_model ** 0.5, (dims.vocab, dims.d_model)),
        "ln_f": jnp.ones((dims.d_model,), jnp.float32),
        "layers": [],
    }
    hd = dims.n_heads * dims.d_head
    for l in range(dims.n_layers):
        lk = jax.random.split(ks[2 + l], 7)
        params["layers"].append({
            "ln1": jnp.ones((dims.d_model,), jnp.float32),
            "wq": dense(lk[0], dims.d_model, (dims.d_model, hd)),
            "wk": dense(lk[1], dims.d_model, (dims.d_model, hd)),
            "wv": dense(lk[2], dims.d_model, (dims.d_model, hd)),
            "wo": dense(lk[3], hd, (hd, dims.d_model)),
            "ln2": jnp.ones((dims.d_model,), jnp.float32),
            "w_gate": dense(lk[4], dims.d_model, (dims.d_model, dims.d_ff)),
            "w_up": dense(lk[5], dims.d_model, (dims.d_model, dims.d_ff)),
            "w_down": dense(lk[6], dims.d_ff, (dims.d_ff, dims.d_model)),
        })
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, scale):
    return x * scale * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def rope(x, positions):
    """Rotary embedding; x [T, H, D], positions [T] (absolute)."""
    d = x.shape[-1]
    half = d // 2
    inv_freq = ROPE_BASE ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freqs = positions[:, None].astype(jnp.float32) * inv_freq  # [T, half]
    cos = jnp.cos(freqs)[:, None, :]
    sin = jnp.sin(freqs)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _attend(q, k, v, q_offset, use_kernel: bool):
    return (cached_attention if use_kernel else cached_attention_ref)(q, k, v, q_offset)


def _block(lp, x, kv_k_l, kv_v_l, q_offset, dims: ModelDims, use_kernel: bool):
    """One decoder block over a [T, d] slice with cache update.

    kv_*_l: [S, H, D] cache for this layer; returns the updated cache.
    """
    T = x.shape[0]
    positions = q_offset + jnp.arange(T, dtype=jnp.int32)
    h = rmsnorm(x, lp["ln1"])
    q = (h @ lp["wq"]).reshape(T, dims.n_heads, dims.d_head)
    k = (h @ lp["wk"]).reshape(T, dims.n_heads, dims.d_head)
    v = (h @ lp["wv"]).reshape(T, dims.n_heads, dims.d_head)
    q = rope(q, positions)
    k = rope(k, positions)
    kv_k_l = jax.lax.dynamic_update_slice(kv_k_l, k, (q_offset, 0, 0))
    kv_v_l = jax.lax.dynamic_update_slice(kv_v_l, v, (q_offset, 0, 0))
    att = _attend(q, kv_k_l, kv_v_l, q_offset, use_kernel)
    x = x + att.reshape(T, dims.n_heads * dims.d_head) @ lp["wo"]
    h2 = rmsnorm(x, lp["ln2"])
    x = x + (jax.nn.silu(h2 @ lp["w_gate"]) * (h2 @ lp["w_up"])) @ lp["w_down"]
    return x, kv_k_l, kv_v_l


def forward_tokens(params, tokens, q_offset, kv_k, kv_v, dims: ModelDims,
                   use_kernel: bool = True, logits_at=None):
    """Run T tokens starting at absolute position ``q_offset``.

    tokens [T] i32; kv_[kv] [L, S, H, D]. Returns (logits, kv_k, kv_v) where
    logits is [T, V], or [V] at a single row when ``logits_at`` is given
    (avoids the full [T, V] lm-head matmul in prefill).
    """
    x = params["embed"][tokens]
    new_k, new_v = [], []
    for l, lp in enumerate(params["layers"]):
        x, kk, vv = _block(lp, x, kv_k[l], kv_v[l], q_offset, dims, use_kernel)
        new_k.append(kk)
        new_v.append(vv)
    x = rmsnorm(x, params["ln_f"])
    if logits_at is not None:
        x = jax.lax.dynamic_index_in_dim(x, logits_at, axis=0, keepdims=False)
    logits = x @ params["embed"].T
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def make_entries(dims: ModelDims, use_kernel: bool = True):
    """Build the three serving entry points for a backbone."""
    S, G = dims.max_seq, config.MAX_GEN
    kv_shape = (dims.n_layers, S, dims.n_heads, dims.d_head)

    def prefill(params, tokens, plen):
        """tokens [S] i32 (padded), real length plen -> (kv_k, kv_v, logits[V]).

        ``logits`` is the next-token distribution after position ``plen - 1``
        — the baseline path needs it to emit its first token straight from
        the monolithic prefill (and it keeps every parameter live, so the
        lowered HLO keeps the flatten parameter order; see aot.arg_map).
        """
        kv_k = jnp.zeros(kv_shape, jnp.float32)
        kv_v = jnp.zeros(kv_shape, jnp.float32)
        logits, kv_k, kv_v = forward_tokens(params, tokens, jnp.int32(0), kv_k,
                                            kv_v, dims, use_kernel,
                                            logits_at=plen - 1)
        return kv_k, kv_v, logits

    def extend(params, kv_k, kv_v, plen, q_tokens):
        """Append Q query tokens at position plen -> (kv', logits [Q, V])."""
        logits, kv_k, kv_v = forward_tokens(params, q_tokens, plen, kv_k, kv_v,
                                            dims, use_kernel)
        return kv_k, kv_v, logits

    def generate(params, kv_k, kv_v, cur_len, first_tok):
        """Greedy decode up to G tokens (first_tok included as gen[0]).

        The whole decode loop is a lax.scan inside the HLO: one PJRT call
        produces the full answer — no per-token host round-trips (L3 perf).
        """
        eos = jnp.int32(config.EOS_ID)

        def step(carry, _):
            kv_k, kv_v, pos, tok, done = carry
            logits, kv_k, kv_v = forward_tokens(params, tok[None], pos, kv_k,
                                                kv_v, dims, use_kernel)
            nxt = jnp.argmax(logits[0]).astype(jnp.int32)
            nxt = jnp.where(done, eos, nxt)
            done = done | (nxt == eos)
            return (kv_k, kv_v, pos + 1, nxt, done), nxt

        carry = (kv_k, kv_v, cur_len, first_tok, first_tok == eos)
        _, toks = jax.lax.scan(step, carry, None, length=G - 1)
        return jnp.concatenate([first_tok[None], toks])

    return prefill, extend, generate


# ---------------------------------------------------------------------------
# Training forward (batched, no cache, reference attention for speed)
# ---------------------------------------------------------------------------

def forward_train(params, tokens, dims: ModelDims):
    """Batched causal LM forward: tokens [B, T] -> logits [B, T, V]."""

    def one(tok):
        T = tok.shape[0]
        kv = jnp.zeros((dims.n_layers, T, dims.n_heads, dims.d_head), jnp.float32)
        logits, _, _ = forward_tokens(params, tok, jnp.int32(0), kv, kv, dims,
                                      use_kernel=False)
        return logits

    return jax.vmap(one)(tokens)


def lm_loss(params, tokens, loss_mask, dims: ModelDims):
    """Next-token cross-entropy where loss_mask[b, t] marks target positions."""
    logits = forward_train(params, tokens, dims)  # [B, T, V]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    mask = loss_mask[:, 1:].astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
