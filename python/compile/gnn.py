"""L2: graph encoders producing subgraph embeddings for query clustering.

Two architectures, matching the paper's baselines: a **Graph Transformer**
(G-Retriever; Shi et al. masked-attention message passing) and a **GAT**
(GRAG; Veličković et al.). Both consume FNV-hashed node features, a dense
adjacency mask and a node-validity mask, and mean-pool to a fixed-size
subgraph embedding.

Per DESIGN.md §4 the encoders are deterministically seeded but untrained —
they serve as fixed structure-aware feature maps, which is all the paper's
clustering stage requires. Edge attributes are folded into the adjacency
mask only (documented substitution).

AOT entry per encoder::

    encode(params, x[N,F], adj[N,N], mask[N]) -> emb[GNN_EMB]
"""

from typing import Dict

import jax
import jax.numpy as jnp

from . import config

N = config.N_MAX
F = config.FEAT_DIM
H = config.GNN_HIDDEN
HEADS = config.GNN_HEADS
LAYERS = config.GNN_LAYERS
EMB = config.GNN_EMB
DH = H // HEADS
NEG = jnp.float32(-1e30)


def _dense(k, fan_in, shape):
    return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Graph Transformer (masked multi-head attention along edges)
# ---------------------------------------------------------------------------

def init_graph_transformer(seed: int = 101) -> Dict:
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + LAYERS)
    params = {"w_in": _dense(ks[0], F, (F, H)), "w_out": _dense(ks[1], H, (H, EMB)),
              "layers": []}
    for l in range(LAYERS):
        lk = jax.random.split(ks[2 + l], 5)
        params["layers"].append({
            "wq": _dense(lk[0], H, (H, H)),
            "wk": _dense(lk[1], H, (H, H)),
            "wv": _dense(lk[2], H, (H, H)),
            "wo": _dense(lk[3], H, (H, H)),
            "w_ff": _dense(lk[4], H, (H, H)),
        })
    return params


def graph_transformer_encode(params, x, adj, mask):
    """x [N,F], adj [N,N] (1.0 where edge or self-loop), mask [N] -> emb [EMB]."""
    h = jnp.tanh(x @ params["w_in"])  # [N, H]
    allow = (adj + jnp.eye(N, dtype=adj.dtype)) * mask[None, :] * mask[:, None]
    for lp in params["layers"]:
        q = (h @ lp["wq"]).reshape(N, HEADS, DH)
        k = (h @ lp["wk"]).reshape(N, HEADS, DH)
        v = (h @ lp["wv"]).reshape(N, HEADS, DH)
        scores = jnp.einsum("ihd,jhd->hij", q, k) / jnp.sqrt(jnp.float32(DH))
        scores = jnp.where(allow[None, :, :] > 0, scores, NEG)
        p = jax.nn.softmax(scores, axis=-1)
        # isolated/padded rows have all-masked scores -> uniform p; zero them.
        p = p * (allow.sum(axis=1)[None, :, None] > 0)
        att = jnp.einsum("hij,jhd->ihd", p, v).reshape(N, H)
        h = h + att @ lp["wo"]
        h = h + jnp.tanh(h @ lp["w_ff"])
    pooled = (h * mask[:, None]).sum(axis=0) / jnp.maximum(mask.sum(), 1.0)
    return pooled @ params["w_out"]


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------

def init_gat(seed: int = 211) -> Dict:
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + LAYERS)
    params = {"w_in": _dense(ks[0], F, (F, H)), "w_out": _dense(ks[1], H, (H, EMB)),
              "layers": []}
    for l in range(LAYERS):
        lk = jax.random.split(ks[2 + l], 3)
        params["layers"].append({
            "w": _dense(lk[0], H, (H, H)),
            "a_src": _dense(lk[1], DH, (HEADS, DH)),
            "a_dst": _dense(lk[2], DH, (HEADS, DH)),
        })
    return params


def gat_encode(params, x, adj, mask):
    """GAT with LeakyReLU attention coefficients; same contract as above."""
    h = jnp.tanh(x @ params["w_in"])
    allow = (adj + jnp.eye(N, dtype=adj.dtype)) * mask[None, :] * mask[:, None]
    for lp in params["layers"]:
        wh = (h @ lp["w"]).reshape(N, HEADS, DH)
        e_src = jnp.einsum("ihd,hd->ih", wh, lp["a_src"])  # [N, HEADS]
        e_dst = jnp.einsum("jhd,hd->jh", wh, lp["a_dst"])
        e = jax.nn.leaky_relu(e_src[:, None, :] + e_dst[None, :, :], 0.2)  # [N,N,HEADS]
        e = jnp.where(allow[:, :, None] > 0, e, NEG)
        alpha = jax.nn.softmax(e, axis=1)
        alpha = alpha * (allow.sum(axis=1)[:, None, None] > 0)
        out = jnp.einsum("ijh,jhd->ihd", alpha, wh).reshape(N, H)
        h = h + jax.nn.elu(out)
    pooled = (h * mask[:, None]).sum(axis=0) / jnp.maximum(mask.sum(), 1.0)
    return pooled @ params["w_out"]


ENCODERS = {
    "graph_transformer": (init_graph_transformer, graph_transformer_encode),
    "gat": (init_gat, gat_encode),
}
