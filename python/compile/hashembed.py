"""FNV-1a feature-hashing bag-of-tokens embedder.

Substitute for SentenceBERT (see DESIGN.md §4): both retrieval scoring and
GNN node features only need a *consistent* text→vector map where token
overlap implies vector similarity. Mirrored exactly by ``rust/src/embed``;
golden-tested across the language boundary.
"""

import math
from typing import List

import numpy as np

from . import config
from .tokenizer import split_text

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    """64-bit FNV-1a hash (identical constants on the Rust side)."""
    h = _FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    return h


def embed_text(text: str, dim: int = config.FEAT_DIM) -> np.ndarray:
    """L2-normalized hashed bag-of-tokens embedding.

    Each token contributes ±1 to one bucket: bucket = hash % dim, sign from
    bit 63. The signed variant keeps E[dot] ≈ 0 for disjoint token sets, so
    cosine similarity tracks token overlap.
    """
    v = np.zeros(dim, dtype=np.float64)
    for tok in split_text(text):
        h = fnv1a(tok.encode("utf-8"))
        sign = 1.0 if (h >> 63) == 0 else -1.0
        v[h % dim] += sign
    n = math.sqrt(float(np.dot(v, v)))
    if n > 0:
        v /= n
    return v.astype(np.float32)


def embed_texts(texts: List[str], dim: int = config.FEAT_DIM) -> np.ndarray:
    return np.stack([embed_text(t, dim) for t in texts]) if texts else np.zeros((0, dim), np.float32)


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = float(np.linalg.norm(a)), float(np.linalg.norm(b))
    if na == 0.0 or nb == 0.0:
        return 0.0
    return float(np.dot(a, b) / (na * nb))
