"""AOT compile path: python runs ONCE here, never on the request path.

``python -m compile.aot`` produces everything the Rust runtime needs:

    artifacts/
      data/{scene_graph,oag}.json      synthetic datasets (Table 1 stats)
      vocab.json                       word-level tokenizer vocabulary
      weights/<module>.npz             flattened parameters (p000..pNNN)
      hlo/<module>.<entry>.hlo.txt     HLO *text* per entry point
      manifest.json                    shapes, param order, constants
      golden/*.json                    cross-language golden vectors

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Incremental: training is skipped when the weights file already exists
(delete ``artifacts/weights`` to retrain); lowering is always re-run (fast
relative to training).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config, datasets, gnn, model, train, verbalize
from .hashembed import embed_text
from .tokenizer import Tokenizer


def to_hlo_text(fn, *abstract_args) -> str:
    lowered = jax.jit(fn).lower(*abstract_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


import re as _re

_ARG_RE = _re.compile(r"%?Arg_(\d+)\.[0-9.]* = \S+ parameter\((\d+)\)")


def entry_arg_map(hlo_text: str) -> list:
    """Map HLO entry parameter position -> original flattened argument index.

    XLA may dead-code-eliminate unused arguments (renumbering the survivors),
    so the Rust runtime must not assume position == flatten order. We keep
    every argument live by construction (each entry returns something that
    depends on all params), but parse the map defensively: arg_map[n] = m
    means HLO parameter(n) is flattened argument m.
    """
    entry = hlo_text[hlo_text.index("ENTRY"):]
    pairs = sorted((int(n), int(m)) for m, n in _ARG_RE.findall(entry))
    positions = [n for n, _ in pairs]
    assert positions == list(range(len(pairs))), f"non-contiguous params: {positions}"
    return [m for _, m in pairs]


def abstract(params):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), params
    )


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.1f} MB)", flush=True)


# ---------------------------------------------------------------------------
# Golden vectors (cross-language pinning; consumed by rust tests)
# ---------------------------------------------------------------------------

def write_goldens(out: str, tok: Tokenizer, dsets) -> None:
    gdir = os.path.join(out, "golden")
    os.makedirs(gdir, exist_ok=True)

    texts = [
        "what is the color of the cords ?",
        'how is " a dynamic environment for video surveillance " connected to'
        ' " computer science " ?',
        "graph : cords color blue ; laptop ; cords left of laptop ;",
        "Mixed CASE with   spaces\tand-punct.uation!",
        "",
    ]
    with open(os.path.join(gdir, "tokenizer.json"), "w") as f:
        json.dump([{"text": t, "ids": tok.encode(t)} for t in texts], f)
    with open(os.path.join(gdir, "embed.json"), "w") as f:
        json.dump([{"text": t, "vec": [float(x) for x in embed_text(t)]}
                   for t in texts], f)

    scene = dsets[0]
    cases = []
    for nodes, edges, q in [
        ([0, 1, 2], [0, 1], "what is the color of the cords ?"),
        (list(range(8)), list(range(12)), "what color is the laptop ?"),
        ([2], [], "what is the material of the screen ?"),
    ]:
        cases.append({
            "nodes": nodes, "edges": edges, "query": q,
            "prefix": verbalize.prefix_text(scene, nodes, edges),
            "prefix_capped": verbalize.prefix_text(scene, nodes, edges, max_tokens=24),
            "prompt": verbalize.full_prompt(scene, nodes, edges, q),
        })
    with open(os.path.join(gdir, "verbalize.json"), "w") as f:
        json.dump(cases, f)


def write_llm_golden(out: str, name: str, tok: Tokenizer, params, dims) -> None:
    """End-to-end numeric golden: prefill→extend→generate on the Pallas path.

    Pins the Rust runtime (HLO executables + buffer plumbing) to the Python
    semantics, including the SubGCache consistency property: the golden is
    produced via the *split* path exactly as Rust serves it.
    """
    prefill, extend, generate = model.make_entries(dims, use_kernel=True)
    prefix = "graph : cords color blue ; laptop ; screen material glass ; " \
             "cords left of laptop ; screen above laptop ;"
    question = " question : what is the color of the cords ? answer :"
    p_ids = [config.BOS_ID] + tok.encode(prefix)
    q_ids = tok.encode(question)
    S, Qm = dims.max_seq, config.MAX_Q
    tokens = np.full(S, config.PAD_ID, np.int32)
    tokens[: len(p_ids)] = p_ids
    q_tok = np.full(Qm, config.PAD_ID, np.int32)
    q_tok[: len(q_ids)] = q_ids

    kv_k, kv_v, _ = jax.jit(prefill)(params, jnp.asarray(tokens),
                                     jnp.int32(len(p_ids)))
    kv_k, kv_v, logits = jax.jit(extend)(params, kv_k, kv_v,
                                         jnp.int32(len(p_ids)), jnp.asarray(q_tok))
    first = int(jnp.argmax(logits[len(q_ids) - 1]))
    gen = jax.jit(generate)(params, kv_k, kv_v,
                            jnp.int32(len(p_ids) + len(q_ids)), jnp.int32(first))
    gen = [int(x) for x in np.asarray(gen)]

    # Baseline (monolithic) path golden: prefill(prefix ⊕ question) directly.
    full = np.full(S, config.PAD_ID, np.int32)
    full[: len(p_ids)] = p_ids
    full[len(p_ids): len(p_ids) + len(q_ids)] = q_ids
    flen = len(p_ids) + len(q_ids)
    bk, bv, blogits = jax.jit(prefill)(params, jnp.asarray(full), jnp.int32(flen))
    bfirst = int(jnp.argmax(blogits))
    bgen = jax.jit(generate)(params, bk, bv, jnp.int32(flen), jnp.int32(bfirst))
    bgen = [int(x) for x in np.asarray(bgen)]

    golden = {
        "backbone": name,
        "prefix_tokens": tokens.tolist(),
        "prefix_len": len(p_ids),
        "q_tokens": q_tok.tolist(),
        "q_len": len(q_ids),
        "first_token": first,
        "generated": gen,
        "answer_text": tok.decode(gen),
        "extend_logits_row": [float(x) for x in np.asarray(logits[len(q_ids) - 1])[:32]],
        "baseline_tokens": full.tolist(),
        "baseline_len": flen,
        "baseline_first_token": bfirst,
        "baseline_generated": bgen,
        "baseline_answer_text": tok.decode(bgen),
    }
    with open(os.path.join(out, "golden", f"llm_{name}.json"), "w") as f:
        json.dump(golden, f)
    print(f"  golden answer [{name}]: {golden['answer_text']!r}", flush=True)


# ---------------------------------------------------------------------------
# Main build
# ---------------------------------------------------------------------------

def build(out: str, backbones, steps_override=None, skip_train=False):
    t0 = time.time()
    os.makedirs(out, exist_ok=True)

    print("[1/5] datasets", flush=True)
    datasets.write_datasets(os.path.join(out, "data"))
    with open(os.path.join(out, "data", "scene_graph.json")) as f:
        scene = json.load(f)
    with open(os.path.join(out, "data", "oag.json")) as f:
        oag = json.load(f)
    dsets = [scene, oag]

    print("[2/5] tokenizer", flush=True)
    tok = train.build_tokenizer(dsets)
    tok.save(os.path.join(out, "vocab.json"))
    vocab = tok.padded_size
    print(f"  vocab: {len(tok)} tokens (padded to {vocab})", flush=True)

    manifest = {
        "constants": {
            "max_seq": config.MAX_SEQ, "max_q": config.MAX_Q,
            "max_gen": config.MAX_GEN, "max_prefix": config.MAX_PREFIX,
            "vocab": vocab, "feat_dim": config.FEAT_DIM, "n_max": config.N_MAX,
            "gnn_emb": config.GNN_EMB,
            "pad_id": config.PAD_ID, "bos_id": config.BOS_ID,
            "eos_id": config.EOS_ID, "unk_id": config.UNK_ID,
        },
        "modules": {},
    }

    print("[3/5] LLM backbones", flush=True)
    from . import synth
    rng = np.random.default_rng(1)
    # Mostly procedurally-sampled graphs (forces extraction over memorization
    # — see synth.py) plus the real datasets' train splits for distribution
    # anchoring.
    n_synth = int(os.environ.get("SUBGCACHE_SYNTH", "12000"))
    synth_toks, synth_masks = synth.make_synth_examples(n_synth, tok, rng)
    real_ex = [train.make_examples(ds, tok, rng) for ds in dsets]
    real_toks, real_masks = train.balance_examples(real_ex, rng)
    all_toks = np.concatenate([synth_toks, real_toks])
    all_masks = np.concatenate([synth_masks, real_masks])
    order = rng.permutation(all_toks.shape[0])
    all_toks, all_masks = all_toks[order], all_masks[order]
    print(f"  {all_toks.shape[0]} training examples "
          f"({n_synth} synthetic + {real_toks.shape[0]} real, shuffled), "
          f"seq {all_toks.shape[1]}", flush=True)

    for name in backbones:
        bb = config.BACKBONES[name]
        dims = model.dims_for(bb, vocab)
        wpath = os.path.join(out, "weights", f"{name}.npz")
        if os.path.exists(wpath) and not steps_override:
            print(f"  [{name}] weights exist, skipping training", flush=True)
            spec = json.load(open(os.path.join(out, "weights", f"{name}.spec.json")))
            params = load_params(out, name, dims)
        else:
            if skip_train:
                params = model.init_params(dims, bb.seed)
            else:
                params = train.train_backbone(bb, dims, all_toks, all_masks,
                                              steps=steps_override)
                acc = train.teacher_forced_acc(params, dims, all_toks, all_masks)
                print(f"  [{name}] teacher-forced answer ACC: {acc:.2%}", flush=True)
            spec = train.save_weights(params, wpath)
            with open(os.path.join(out, "weights", f"{name}.spec.json"), "w") as f:
                json.dump(spec, f)

        prefill, extend, generate = model.make_entries(dims, use_kernel=True)
        S, Q = dims.max_seq, config.MAX_Q
        kv = jax.ShapeDtypeStruct((dims.n_layers, S, dims.n_heads, dims.d_head),
                                  jnp.float32)
        ab_params = abstract(params)
        i32 = jax.ShapeDtypeStruct((), jnp.int32)
        toks_s = jax.ShapeDtypeStruct((S,), jnp.int32)
        toks_q = jax.ShapeDtypeStruct((Q,), jnp.int32)

        print(f"  [{name}] lowering prefill/extend/generate", flush=True)
        hlo_prefill = to_hlo_text(prefill, ab_params, toks_s, i32)
        hlo_extend = to_hlo_text(extend, ab_params, kv, kv, i32, toks_q)
        hlo_generate = to_hlo_text(generate, ab_params, kv, kv, i32, i32)
        _write(os.path.join(out, "hlo", f"{name}.prefill.hlo.txt"), hlo_prefill)
        _write(os.path.join(out, "hlo", f"{name}.extend.hlo.txt"), hlo_extend)
        _write(os.path.join(out, "hlo", f"{name}.generate.hlo.txt"), hlo_generate)

        n_params = len(spec)
        manifest["modules"][name] = {
            "kind": "llm", "params": spec,
            "dims": {"vocab": vocab, "d_model": bb.d_model,
                     "n_layers": bb.n_layers, "n_heads": bb.n_heads,
                     "d_head": bb.d_head, "d_ff": bb.d_ff,
                     "max_seq": S},
            "entries": {
                "prefill": {"hlo": f"hlo/{name}.prefill.hlo.txt",
                            "extra_args": [["tokens", "i32", [S]],
                                           ["plen", "i32", []]],
                            "outputs": 3,
                            "arg_map": entry_arg_map(hlo_prefill)},
                "extend": {"hlo": f"hlo/{name}.extend.hlo.txt",
                           "extra_args": [["kv_k", "f32", list(kv.shape)],
                                          ["kv_v", "f32", list(kv.shape)],
                                          ["plen", "i32", []],
                                          ["q_tokens", "i32", [Q]]],
                           "outputs": 3,
                           "arg_map": entry_arg_map(hlo_extend)},
                "generate": {"hlo": f"hlo/{name}.generate.hlo.txt",
                             "extra_args": [["kv_k", "f32", list(kv.shape)],
                                            ["kv_v", "f32", list(kv.shape)],
                                            ["cur_len", "i32", []],
                                            ["first_tok", "i32", []]],
                             "outputs": 1,
                             "arg_map": entry_arg_map(hlo_generate)},
            },
        }
        for entry, meta in manifest["modules"][name]["entries"].items():
            n_extra = len(meta["extra_args"])
            assert len(meta["arg_map"]) == n_params + n_extra, \
                f"{name}.{entry}: {len(meta['arg_map'])} live args, " \
                f"expected {n_params + n_extra} (dead parameters?)"

        if name == config.PRIMARY_BACKBONE:
            os.makedirs(os.path.join(out, "golden"), exist_ok=True)
            write_llm_golden(out, name, tok, params, dims)

    print("[4/5] GNN encoders", flush=True)
    for gname, (init, encode) in gnn.ENCODERS.items():
        params = init()
        spec = train.save_weights(params, os.path.join(out, "weights", f"{gname}.npz"))
        with open(os.path.join(out, "weights", f"{gname}.spec.json"), "w") as f:
            json.dump(spec, f)
        N, F = config.N_MAX, config.FEAT_DIM
        x = jax.ShapeDtypeStruct((N, F), jnp.float32)
        adj = jax.ShapeDtypeStruct((N, N), jnp.float32)
        mask = jax.ShapeDtypeStruct((N,), jnp.float32)
        hlo_enc = to_hlo_text(encode, abstract(params), x, adj, mask)
        _write(os.path.join(out, "hlo", f"{gname}.encode.hlo.txt"), hlo_enc)
        manifest["modules"][gname] = {
            "kind": "gnn", "params": spec,
            "entries": {"encode": {"hlo": f"hlo/{gname}.encode.hlo.txt",
                                   "extra_args": [["x", "f32", [N, F]],
                                                  ["adj", "f32", [N, N]],
                                                  ["mask", "f32", [N]]],
                                   "outputs": 1,
                                   "arg_map": entry_arg_map(hlo_enc)}},
        }
        assert len(manifest["modules"][gname]["entries"]["encode"]["arg_map"]) \
            == len(spec) + 3

    print("[5/5] goldens + manifest", flush=True)
    write_goldens(out, tok, dsets)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"done in {time.time() - t0:.0f}s", flush=True)


def load_params(out: str, name: str, dims) -> dict:
    """Rebuild a params pytree from a saved npz (used for goldens/tests)."""
    spec = json.load(open(os.path.join(out, "weights", f"{name}.spec.json")))
    data = np.load(os.path.join(out, "weights", f"{name}.npz"))
    flat = [jnp.asarray(data[e["key"]]) for e in spec]
    template = model.init_params(dims, 0)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    assert len(leaves) == len(flat), "weight count mismatch"
    return jax.tree_util.tree_unflatten(treedef, flat)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--backbones", default=",".join(config.BACKBONES),
                    help="comma-separated subset of backbones to build")
    ap.add_argument("--steps", type=int, default=None,
                    help="override train steps (forces retraining)")
    ap.add_argument("--skip-train", action="store_true",
                    help="random-init weights (CI smoke mode)")
    args = ap.parse_args()
    build(os.path.abspath(args.out), args.backbones.split(","),
          steps_override=args.steps, skip_train=args.skip_train)


if __name__ == "__main__":
    main()
