//! Domain scenario from the paper's introduction: batched question answering
//! over a knowledge graph (the paper motivates medical QA over biomedical
//! KGs; our stand-in is the OAG academic graph — same shape: typed entities,
//! typed relations, link-style questions arriving in volume).
//!
//! Demonstrates the end-to-end in-batch flow with GRAG retrieval + GAT
//! subgraph encoding, sweeping the batch size the way a deployment would
//! size its batching window.
//!
//! ```bash
//! cargo run --release --offline --example biomedical_batch -- --batches 25,50,100
//! ```

use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = ArtifactStore::discover()?;
    let ds = store.dataset("oag")?;
    let engine = Engine::start(&store)?;
    let retriever = GragRetriever::default();

    let batches: Vec<usize> = args
        .list_or("batches", "25,50,100")
        .iter()
        .map(|s| s.parse().expect("bad --batches"))
        .collect();

    let cfg = ServeConfig {
        backbone: args.get_or("backbone", "llama-3.2-3b-sim").to_string(),
        n_clusters: 2,
        ..Default::default()
    };
    let coord = Coordinator::new(&store, &engine, cfg)?;

    println!("in-batch KGQA over {} ({} entities, {} relations)",
             ds.graph.name, ds.graph.n_nodes(), ds.graph.n_edges());
    let mut t = Table::new(&["batch", "method", "ACC (%)", "TTFT (ms)", "PFTT (ms)",
                             "cluster stage (ms)"]);
    for &b in &batches {
        let queries = ds.sample_test(b, 13);
        let base = coord.serve_baseline(&ds, &queries, &retriever)?;
        let ours = coord.serve_subgcache(&ds, &queries, &retriever)?;
        t.row(&[b.to_string(), "GRAG".into(),
                format!("{:.1}", base.metrics.acc()),
                format!("{:.1}", base.metrics.ttft_ms()),
                format!("{:.1}", base.metrics.pftt_ms()),
                "-".into()]);
        t.row(&[b.to_string(), "GRAG+SubGCache".into(),
                format!("{:.1}", ours.metrics.acc()),
                format!("{:.1}", ours.metrics.ttft_ms()),
                format!("{:.1}", ours.metrics.pftt_ms()),
                format!("{:.1}", ours.metrics.cluster_time * 1e3)]);
    }
    t.print();
    println!("\nlarger batches expose more subgraph overlap: the shared \
              representative prefill amortizes further and PFTT keeps dropping.");
    Ok(())
}
