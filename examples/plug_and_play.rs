//! SubGCache is plug-and-play (paper §1, Design 2): the same coordinator
//! wraps ANY retriever implementing [`subgcache::retrieval::Retriever`].
//!
//! This example defines a custom third retriever — a naive "top-k nodes
//! only" strategy — plugs it into both serving paths next to the two
//! built-ins, and shows the cache still composes: clustering, representative
//! construction and KV reuse all operate on whatever subgraphs come out.
//!
//! ```bash
//! cargo run --release --offline --example plug_and_play
//! ```

use subgcache::embed::{cosine, embed_text};
use subgcache::graph::{Subgraph, TextualGraph};
use subgcache::prelude::*;

/// A deliberately simple retriever: top-5 nodes by text similarity, plus the
/// edges among them. No connectivity repair, no ego networks.
struct TopKNodes {
    k: usize,
}

impl Retriever for TopKNodes {
    fn name(&self) -> &'static str {
        "topk-nodes"
    }

    fn retrieve(&self, g: &TextualGraph, feats: &GraphFeatures, query: &str) -> Subgraph {
        let q = embed_text(query);
        let mut scored: Vec<(f32, usize)> = feats
            .node_emb
            .iter()
            .enumerate()
            .map(|(i, e)| (cosine(&q, e), i))
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut sg = Subgraph::default();
        sg.nodes.extend(scored.iter().take(self.k).map(|&(_, i)| i));
        for &n in sg.nodes.clone().iter() {
            for &(ei, v, _) in g.incident(n) {
                if sg.nodes.contains(&v) {
                    sg.edges.insert(ei);
                }
            }
        }
        sg
    }
}

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover()?;
    let ds = store.dataset("scene_graph")?;
    let engine = Engine::start(&store)?;
    let queries = ds.sample_test(12, 99);

    let retrievers: Vec<Box<dyn Retriever>> = vec![
        Box::new(GRetriever::default()),
        Box::new(GragRetriever::default()),
        Box::new(TopKNodes { k: 5 }),
    ];

    let cfg = ServeConfig { n_clusters: 2, gnn: Some("graph_transformer".into()),
                            ..Default::default() };
    let coord = Coordinator::new(&store, &engine, cfg)?;

    let mut t = Table::new(&["retriever", "ACC base", "ACC +SGC", "TTFT x", "PFTT x"]);
    for r in &retrievers {
        let base = coord.serve_baseline(&ds, &queries, r.as_ref())?;
        let ours = coord.serve_subgcache(&ds, &queries, r.as_ref())?;
        let d = delta(&base.metrics, &ours.metrics);
        t.row(&[r.name().into(),
                format!("{:.1}", base.metrics.acc()),
                format!("{:.1}", ours.metrics.acc()),
                format!("{:.2}x", d.ttft_x),
                format!("{:.2}x", d.pftt_x)]);
    }
    t.print();
    println!("\nthe coordinator never special-cases a retriever: subgraph-level \
              caching attaches to any graph-based RAG front end.");
    Ok(())
}
