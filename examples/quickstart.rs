//! Quickstart: load the Scene Graph dataset, serve a small in-batch workload
//! with and without SubGCache, and print the speedup.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    // artifacts/ holds everything `make artifacts` produced: datasets, vocab,
    // AOT HLO and trained weights. Python is NOT needed from here on.
    let store = ArtifactStore::discover()?;
    let ds = store.dataset("scene_graph")?;
    println!("loaded {}: {} nodes, {} edges, {} queries",
             ds.graph.name, ds.graph.n_nodes(), ds.graph.n_edges(), ds.queries.len());

    // The PJRT engine thread compiles the AOT artifacts on first use.
    let engine = Engine::start(&store)?;

    // An in-batch workload: 16 test queries arriving together.
    let queries = ds.sample_test(16, 7);
    let retriever = GRetriever::default();

    let cfg = ServeConfig {
        backbone: "llama-3.2-3b-sim".into(),
        n_clusters: 1, // the paper's best Scene Graph setting (§4.3)
        ..Default::default()
    };
    let coord = Coordinator::new(&store, &engine, cfg)?;

    println!("\nserving baseline (per-query full prefill)...");
    let base = coord.serve_baseline(&ds, &queries, &retriever)?;
    println!("serving with SubGCache (clustered KV reuse)...");
    let ours = coord.serve_subgcache(&ds, &queries, &retriever)?;

    let d = delta(&base.metrics, &ours.metrics);
    let mut t = Table::new(&["method", "ACC (%)", "RT (ms)", "TTFT (ms)", "PFTT (ms)"]);
    t.row(&["G-Retriever".into(),
            format!("{:.1}", base.metrics.acc()),
            format!("{:.1}", base.metrics.rt_ms()),
            format!("{:.1}", base.metrics.ttft_ms()),
            format!("{:.1}", base.metrics.pftt_ms())]);
    t.row(&["+SubGCache".into(),
            format!("{:.1}", ours.metrics.acc()),
            format!("{:.1}", ours.metrics.rt_ms()),
            format!("{:.1}", ours.metrics.ttft_ms()),
            format!("{:.1}", ours.metrics.pftt_ms())]);
    t.print();
    println!("\nspeedups: RT {:.2}x, TTFT {:.2}x, PFTT {:.2}x (ΔACC {:+.1})",
             d.rt_x, d.ttft_x, d.pftt_x, d.acc_points);
    println!("cache: {} prefills, {} hits, peak {} KiB",
             ours.cache.prefills, ours.cache.hits, ours.cache.peak_bytes / 1024);

    // A few generated answers:
    for r in ours.results.iter().take(4) {
        println!("  [{}] {:?} -> {:?} (gold {:?})", r.id, r.query, r.predicted, r.gold);
    }
    Ok(())
}
