//! Chaos-injection suite: serving correctness under injected backend
//! faults, driven by [`FaultPlan`] on the deterministic [`SimBackend`]
//! (see `runtime` module docs, "Injecting faults in a test").
//!
//! The acceptance property: with the LLM lane killed mid-run plus a ~5%
//! transient-failure rate, a 4-stream `serve_online_multi` fleet completes
//! every stream with answers **bit-identical** to a fault-free run — the
//! representative KV pool is reconstructible state, so faults cost
//! recovery work (counted in `ReliabilityStats`), never answers. A
//! fault-free run reports zero restarts/retries with unchanged metrics.
//!
//! Fault seeds below are chosen so the injection pattern is *provably*
//! safe for the configured retry budget: the transient roll is a pure
//! function of (seed, lane, op index), so for each seed used here the
//! per-lane hit indices were enumerated up front — at least one hit lands
//! inside the guaranteed-executed op range, and no lane has a run of
//! consecutive hits long enough to exhaust `max_retries`.

use std::collections::BTreeSet;
use std::time::Duration;

use subgcache::data::Query;
use subgcache::prelude::*;
use subgcache::runtime::{sim_dataset, sim_store, ArtifactStore};

mod common;

fn faulty_env(lat: SimLatency, plan: FaultPlan, policy: SupervisorPolicy)
              -> (ArtifactStore, SimBackend) {
    let store = sim_store();
    let backend = SimBackend::start_faulty(&store, lat, BatchConfig::off(), plan, policy)
        .expect("faulty sim backend start");
    (store, backend)
}

fn answers(r: &ServeReport) -> Vec<String> {
    r.results.iter().map(|x| x.predicted.clone()).collect()
}

/// Single-cluster online config: every query shares one representative, so
/// lane kills always strand a warm cached entry (the interesting case).
fn chaos_config() -> ServeConfig {
    ServeConfig { online_threshold: f32::INFINITY, ..common::sim_config() }
}

// ---------------------------------------------------------------------------
// The acceptance property.
// ---------------------------------------------------------------------------

#[test]
fn killed_llm_lane_fleet_recovers_bit_identical() {
    let lat = SimLatency::from_millis(2, 1, 1, 1);
    let n_streams = 4;
    let n_queries = 6;
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(n_queries, 7);
    let streams: Vec<Vec<&Query>> =
        (0..n_streams).map(|_| queries.clone()).collect();

    // fault-free reference fleet: zero recovery work on the books.
    let clean = common::sim_env(lat);
    let coord = Coordinator::new(&clean.store, &clean.backend, chaos_config()).unwrap();
    let reference = coord
        .serve_online_multi(&ds, &streams, &GRetriever::default())
        .unwrap();
    assert_eq!(reference.reliability.restarts, 0,
               "fault-free fleet must report zero lane restarts");
    assert_eq!(reference.reliability.retries, 0,
               "fault-free fleet must report zero retries");
    assert_eq!(reference.failed_streams(), 0);

    // chaos fleet: the LLM lane dies at its 12th op (mid-run — the fleet
    // executes >= 49 LLM ops) and ~5% of ops reply a transient error.
    // seed 1 pre-enumerated: LLM transients at op 6/17/51 (op 6 is inside
    // the guaranteed range), no consecutive hits on either lane.
    let plan = FaultPlan {
        seed: 1,
        kill_llm_at_op: Some(12),
        transient_prob: 0.05,
        ..FaultPlan::none()
    };
    let (store, backend) = faulty_env(lat, plan, SupervisorPolicy::default());
    let coord = Coordinator::new(&store, &backend, chaos_config()).unwrap();
    let multi = coord
        .serve_online_multi(&ds, &streams, &GRetriever::default())
        .unwrap();

    // every stream completed, in input order.
    assert_eq!(multi.streams.len(), n_streams);
    assert_eq!(multi.failed_streams(), 0);
    for (i, o) in multi.outcomes.iter().enumerate() {
        assert!(matches!(o, StreamOutcome::Completed(idx) if *idx == i),
                "stream {i} must complete in order, got {o:?}");
    }

    // answers bit-identical to the fault-free fleet, stream for stream.
    for (i, (got, want)) in multi.streams.iter().zip(&reference.streams).enumerate() {
        assert_eq!(answers(got), answers(want),
                   "stream {i} answers must survive the faults bit-identical");
        assert_eq!(got.metrics.per_query.len(), n_queries);
    }

    // the recovery work is on the books.
    assert!(multi.reliability.restarts >= 1,
            "the killed lane must have been supervisor-restarted: {:?}",
            multi.reliability);
    assert!(multi.reliability.retries >= 1,
            "the dead lane's in-flight tickets must have been retried: {:?}",
            multi.reliability);
    assert_eq!(multi.reliability.restarts, backend.lane_restarts(),
               "fleet restart delta must match the supervisor's counter");
    let (transients, _spikes) = backend.injected_faults();
    assert!(transients >= 1, "seed 1 injects a transient inside the run");
}

// ---------------------------------------------------------------------------
// The acceptance property again, with the host KV tier enabled: a lane
// kill invalidates device residency, but demoted host copies survive and
// keep promoting — same bit-identical bar, extra tier traffic on the books.
// ---------------------------------------------------------------------------

#[test]
fn killed_llm_lane_fleet_recovers_with_host_tier_enabled() {
    let lat = SimLatency::from_millis(4, 1, 1, 1)
        .with_host_copy_per_byte(Duration::from_nanos(10));
    let n_streams = 3;
    let ds = sim_dataset(3, 4);
    // two distinct representatives, alternated: under a one-entry device
    // budget the fleet constantly demotes one rep while the other serves,
    // so host copies exist whenever the kill lands.
    let sample = ds.sample_test(8, 11);
    let feats = GraphFeatures::build(&ds.graph);
    let retr = GRetriever::default();
    let mut seen: BTreeSet<(Vec<usize>, Vec<usize>)> = BTreeSet::new();
    let mut picked: Vec<&Query> = Vec::new();
    for &q in &sample {
        let sg = retr.retrieve(&ds.graph, &feats, &q.text);
        if seen.insert((sg.nodes.iter().copied().collect(),
                        sg.edges.iter().copied().collect())) {
            picked.push(q);
            if picked.len() == 2 {
                break;
            }
        }
    }
    assert_eq!(picked.len(), 2, "fixture must span two distinct reps");
    let mut queries: Vec<&Query> = Vec::new();
    for _ in 0..4 {
        queries.push(picked[0]);
        queries.push(picked[1]);
    }
    let streams: Vec<Vec<&Query>> =
        (0..n_streams).map(|_| queries.clone()).collect();
    let cfg = ServeConfig {
        online_threshold: -1.0, // never join: content keying dedups reps
        cache: CachePolicy::new(usize::MAX, 1).with_host_bytes(1 << 20),
        ..common::sim_config()
    };

    let clean = common::sim_env(lat);
    let coord = Coordinator::new(&clean.store, &clean.backend, cfg.clone()).unwrap();
    let reference = coord
        .serve_online_multi(&ds, &streams, &retr)
        .unwrap();
    assert!(reference.shared.demotions >= 1,
            "the workload must exercise the tier: {:?}", reference.shared);
    assert!(reference.shared.promotions >= 1, "{:?}", reference.shared);

    let plan = FaultPlan { seed: 9, kill_llm_at_op: Some(12), ..FaultPlan::none() };
    let (store, backend) = faulty_env(lat, plan, SupervisorPolicy::default());
    let coord = Coordinator::new(&store, &backend, cfg).unwrap();
    let multi = coord.serve_online_multi(&ds, &streams, &retr).unwrap();

    assert_eq!(multi.failed_streams(), 0);
    for (i, (got, want)) in multi.streams.iter().zip(&reference.streams).enumerate() {
        assert_eq!(answers(got), answers(want),
                   "stream {i} must survive the kill bit-identical with the \
                    host tier enabled");
    }
    assert!(multi.reliability.restarts >= 1,
            "the killed lane must have been restarted: {:?}", multi.reliability);
    assert!(multi.shared.demotions >= 1, "{:?}", multi.shared);
    assert!(multi.shared.promotions >= 1,
            "host copies must promote across the lane death: {:?}", multi.shared);
    assert_eq!(multi.reliability.restarts, backend.lane_restarts());
}

// ---------------------------------------------------------------------------
// The same bar with the disk archive under a host budget too small to keep
// any copy: quarantine sweeps only device residency, so archived records
// survive the lane death and recovery recalls them instead of repaying.
// ---------------------------------------------------------------------------

#[test]
fn killed_llm_lane_fleet_recovers_with_disk_tier_enabled() {
    let lat = SimLatency::from_millis(4, 1, 1, 1)
        .with_host_copy_per_byte(Duration::from_nanos(10));
    let n_streams = 3;
    let ds = sim_dataset(3, 4);
    let sample = ds.sample_test(8, 11);
    let feats = GraphFeatures::build(&ds.graph);
    let retr = GRetriever::default();
    let mut seen: BTreeSet<(Vec<usize>, Vec<usize>)> = BTreeSet::new();
    let mut picked: Vec<&Query> = Vec::new();
    for &q in &sample {
        let sg = retr.retrieve(&ds.graph, &feats, &q.text);
        if seen.insert((sg.nodes.iter().copied().collect(),
                        sg.edges.iter().copied().collect())) {
            picked.push(q);
            if picked.len() == 2 {
                break;
            }
        }
    }
    assert_eq!(picked.len(), 2, "fixture must span two distinct reps");
    // a/b alternation under a one-entry device budget and a half-entry host
    // budget: every demotion spills straight through to the disk archive,
    // and every revisit is a disk recall — so the kill always lands with
    // live archived records on disk and none in host memory.
    let mut queries: Vec<&Query> = Vec::new();
    for _ in 0..4 {
        queries.push(picked[0]);
        queries.push(picked[1]);
    }
    let streams: Vec<Vec<&Query>> =
        (0..n_streams).map(|_| queries.clone()).collect();
    let probe = common::sim_env(lat);
    let entry_bytes = probe.backend
        .kv_bytes(subgcache::runtime::SIM_BACKBONE).unwrap();
    let cfg = ServeConfig {
        online_threshold: -1.0, // never join: content keying dedups reps
        cache: CachePolicy::new(usize::MAX, 1)
            .with_host_bytes(entry_bytes / 2)
            .with_disk_bytes(64 << 20),
        ..common::sim_config()
    };

    let coord = Coordinator::new(&probe.store, &probe.backend, cfg.clone()).unwrap();
    let reference = coord
        .serve_online_multi(&ds, &streams, &retr)
        .unwrap();
    assert!(reference.shared.archived >= 1,
            "the workload must exercise the archive: {:?}", reference.shared);
    assert!(reference.shared.recalls >= 1, "{:?}", reference.shared);
    assert!(reference.shared.disk_hits >= 1, "{:?}", reference.shared);

    let plan = FaultPlan { seed: 9, kill_llm_at_op: Some(12), ..FaultPlan::none() };
    let (store, backend) = faulty_env(lat, plan, SupervisorPolicy::default());
    let coord = Coordinator::new(&store, &backend, cfg).unwrap();
    let multi = coord.serve_online_multi(&ds, &streams, &retr).unwrap();

    assert_eq!(multi.failed_streams(), 0);
    for (i, (got, want)) in multi.streams.iter().zip(&reference.streams).enumerate() {
        assert_eq!(answers(got), answers(want),
                   "stream {i} must survive the kill bit-identical with the \
                    disk tier enabled");
    }
    assert!(multi.reliability.restarts >= 1,
            "the killed lane must have been restarted: {:?}", multi.reliability);
    assert!(multi.shared.quarantined >= 1,
            "the stranded device entry must be quarantined: {:?}", multi.shared);
    assert!(multi.shared.archived >= 1, "{:?}", multi.shared);
    assert!(multi.shared.recalls >= 1,
            "archived records must keep recalling across the lane death: {:?}",
            multi.shared);
    assert!(multi.shared.disk_hits >= 1, "{:?}", multi.shared);
    assert_eq!(multi.reliability.restarts, backend.lane_restarts());
}

// ---------------------------------------------------------------------------
// An empty plan is inert: start_faulty(none) == start, metric for metric.
// ---------------------------------------------------------------------------

#[test]
fn empty_fault_plan_is_inert() {
    let lat = SimLatency::from_millis(2, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(6, 7);

    let plain = common::sim_env(lat);
    let coord = Coordinator::new(&plain.store, &plain.backend, chaos_config()).unwrap();
    let want = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    let (store, backend) =
        faulty_env(lat, FaultPlan::none(), SupervisorPolicy::default());
    let coord = Coordinator::new(&store, &backend, chaos_config()).unwrap();
    let got = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    assert_eq!(answers(&got), answers(&want));
    assert_eq!(got.metrics.per_query.len(), want.metrics.per_query.len());
    assert_eq!(got.metrics.hit_count(), want.metrics.hit_count());
    assert_eq!(got.metrics.miss_count(), want.metrics.miss_count());
    assert_eq!(got.cache.prefills, want.cache.prefills);
    assert_eq!(got.cache.quarantined, 0);
    assert!(got.metrics.reliability.is_clean(),
            "no faults -> clean reliability: {:?}", got.metrics.reliability);
    assert_eq!(backend.lane_restarts(), 0);
    assert_eq!(backend.injected_faults(), (0, 0));
}

// ---------------------------------------------------------------------------
// Transient-only plan: retried in place, no restarts, exact bookkeeping.
// ---------------------------------------------------------------------------

#[test]
fn transient_faults_retry_in_place() {
    let lat = SimLatency::from_millis(2, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(6, 7);

    let clean = common::sim_env(lat);
    let coord = Coordinator::new(&clean.store, &clean.backend, chaos_config()).unwrap();
    let want = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    // seed 332 pre-enumerated at prob 0.25: LLM hits at op 4/10/12/15...,
    // GNN at 2/3/8... — several inside the 13 guaranteed LLM ops and 6
    // guaranteed GNN ops, max consecutive run 2 < default max_retries.
    let plan = FaultPlan { seed: 332, transient_prob: 0.25, ..FaultPlan::none() };
    let (store, backend) = faulty_env(lat, plan, SupervisorPolicy::default());
    let coord = Coordinator::new(&store, &backend, chaos_config()).unwrap();
    let got = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    assert_eq!(answers(&got), answers(&want),
               "transient retries must be bit-identical (no side effects)");
    let rel = got.metrics.reliability;
    assert!(rel.retries >= 2, "seed 332 injects several transients: {rel:?}");
    assert_eq!(rel.restarts, 0, "no lane ever died");
    assert_eq!(rel.quarantined_entries, 0, "no KV incarnation was lost");
    assert!(rel.degraded_spans >= 1, "retried queries count as degraded");
    assert!(rel.degraded_secs > 0.0, "recovery spent measurable time");
    // every injected transient is one coordinator retry — nothing waits on
    // a ticket without a recovery ladder behind it.
    let (transients, _spikes) = backend.injected_faults();
    assert_eq!(rel.retries, transients,
               "one retry per injected transient, exactly");
}

// ---------------------------------------------------------------------------
// A lane kill strands the warm representative: quarantine + repay.
// ---------------------------------------------------------------------------

#[test]
fn lane_kill_quarantines_and_repays_the_representative() {
    let lat = SimLatency::from_millis(2, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(6, 7);

    let clean = common::sim_env(lat);
    let coord = Coordinator::new(&clean.store, &clean.backend, chaos_config()).unwrap();
    let want = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    // op 4 is early in the single stream's >= 13 LLM ops: the cluster's
    // representative is already resident and pinned when the lane dies.
    let plan = FaultPlan { seed: 9, kill_llm_at_op: Some(4), ..FaultPlan::none() };
    let (store, backend) = faulty_env(lat, plan, SupervisorPolicy::default());
    let coord = Coordinator::new(&store, &backend, chaos_config()).unwrap();
    let got = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    assert_eq!(answers(&got), answers(&want),
               "the repaid prefill must reproduce the lost KV bit-identical");
    let rel = got.metrics.reliability;
    assert_eq!(rel.restarts, 1, "exactly one supervisor restart: {rel:?}");
    assert!(rel.retries >= 1);
    assert!(rel.quarantined_entries >= 1,
            "the stale representative entry must be quarantined: {rel:?}");
    assert!(got.cache.quarantined >= 1, "cache stats agree: {:?}", got.cache);
    assert!(got.cache.prefills > want.cache.prefills,
            "the lost representative was repaid with a fresh prefill");
    assert_eq!(backend.lane_restarts(), 1);
}

// ---------------------------------------------------------------------------
// Budget semantics: recovery is bounded, deadlines are counted.
// ---------------------------------------------------------------------------

#[test]
fn zero_retry_budget_disables_recovery() {
    let lat = SimLatency::from_millis(1, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(4, 7);

    // every op fails transient; with max_retries = 0 the first failure is
    // terminal for the stream.
    let plan = FaultPlan { seed: 5, transient_prob: 1.0, ..FaultPlan::none() };
    let (store, backend) = faulty_env(lat, plan, SupervisorPolicy::default());
    let cfg = ServeConfig { max_retries: 0, ..chaos_config() };
    let coord = Coordinator::new(&store, &backend, cfg).unwrap();
    let err = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .expect_err("max_retries = 0 must propagate the first failure");
    let msg = format!("{err:#}");
    assert!(msg.contains("transient"),
            "the typed error must survive the chain: {msg}");
}

#[test]
fn exhausted_restart_budget_condemns_the_lane() {
    let lat = SimLatency::from_millis(1, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(4, 7);

    // the lane dies at op 2 and the supervisor has no restart budget: the
    // lane is condemned and the stream's recovery attempts fail fast with
    // LaneDead instead of hanging.
    let plan = FaultPlan { seed: 5, kill_llm_at_op: Some(2), ..FaultPlan::none() };
    let policy = SupervisorPolicy { max_restarts: 0, ..SupervisorPolicy::default() };
    let (store, backend) = faulty_env(lat, plan, policy);
    let coord = Coordinator::new(&store, &backend, chaos_config()).unwrap();
    let err = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .expect_err("a condemned lane must fail the stream");
    let msg = format!("{err:#}");
    assert!(msg.contains("lane"), "LaneDead must surface in the chain: {msg}");
    assert_eq!(backend.lane_restarts(), 0, "no restart budget, no restarts");
}

#[test]
fn deadline_hits_count_queries_past_the_bound() {
    let lat = SimLatency::from_millis(2, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(4, 7);

    // a 1 ns deadline: every (fault-free) query completes — the deadline
    // bounds *recovery*, it never aborts healthy work — but each one is
    // counted as a deadline hit.
    let env = common::sim_env(lat);
    let cfg = ServeConfig {
        deadline: Some(Duration::from_nanos(1)),
        ..chaos_config()
    };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let r = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();
    let rel = r.metrics.reliability;
    assert_eq!(rel.deadline_hits, r.metrics.per_query.len() as u64,
               "every served query ran past a 1 ns deadline: {rel:?}");
    assert_eq!(rel.retries, 0);
    assert_eq!(rel.restarts, 0);

    // and with a generous deadline nothing is counted.
    let cfg = ServeConfig {
        deadline: Some(Duration::from_secs(3600)),
        ..chaos_config()
    };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let r = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();
    assert_eq!(r.metrics.reliability.deadline_hits, 0);
}

// ---------------------------------------------------------------------------
// The overload plane: a seeded flash crowd against bounded lane queues
// sheds deterministically and never corrupts an admitted answer.
// ---------------------------------------------------------------------------

fn overload_env(lat: SimLatency, queue: QueueConfig) -> (ArtifactStore, SimBackend) {
    let store = sim_store();
    let backend = SimBackend::start_guarded(
        &store, lat, BatchConfig::off(), FaultPlan::none(),
        SupervisorPolicy::default(), queue, Some(BreakerConfig::default()))
        .expect("guarded sim backend start");
    (store, backend)
}

/// Flash crowd of 6 arrivals at one instant over ~10 ms background traffic,
/// with a 25 ms deadline and a fixed 7 ms service estimate: the crowd's
/// virtual backlog provably crosses the deadline from its 4th member on, so
/// the shed set is nonempty and a pure function of the seed — no wall
/// clock, no watermarks.
fn overload_config(lat: SimLatency) -> ServeConfig {
    ServeConfig {
        deadline: Some(Duration::from_millis(25)),
        overload: OverloadConfig {
            arrivals: ArrivalPlan {
                seed: 21,
                process: ArrivalProcess::FlashCrowd {
                    mean: Duration::from_millis(10),
                    at: 3,
                    size: 6,
                },
                zipf_skew: 0.0,
            },
            shed: true,
            initial_estimate: Duration::from_secs_f64(lat.serial_sum()),
            headroom: 1.0,
            brownout: Some(BrownoutConfig {
                backlog_steps: [Duration::from_millis(5),
                                Duration::from_millis(50),
                                Duration::ZERO],
                depth_watermark: None,
                p95_watermark: None,
                gen_cap: 8,
            }),
        },
        ..chaos_config()
    }
}

#[test]
fn flash_crowd_sheds_deterministically_and_admitted_answers_survive() {
    let lat = SimLatency::from_millis(4, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(12, 7);

    // unloaded reference: closed loop, no shedding — the answer every
    // admitted query must still produce under load.
    let clean = common::sim_env(lat);
    let coord = Coordinator::new(&clean.store, &clean.backend, chaos_config()).unwrap();
    let reference = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();
    let reference_answers: std::collections::BTreeMap<usize, &str> = reference
        .results.iter().map(|r| (r.id, r.predicted.as_str())).collect();

    let run = || {
        let (store, backend) =
            overload_env(lat, QueueConfig::block(4, Duration::from_millis(500)));
        let coord = Coordinator::new(&store, &backend, overload_config(lat)).unwrap();
        coord
            .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
            .unwrap()
    };
    let a = run();
    let b = run();

    // one outcome per offered arrival; the crowd forced real sheds, the
    // opening queries were admitted, and nothing blocked forever (the test
    // finishing at all proves the Block(500 ms) bound held).
    assert_eq!(a.outcomes.len(), queries.len());
    let rel = &a.metrics.reliability;
    assert_eq!(rel.shed.offered(), queries.len() as u64, "{:?}", rel.shed);
    assert!(rel.shed.shed_deadline >= 3,
            "a 6-wide crowd over a 25 ms deadline sheds its tail: {:?}", rel.shed);
    assert!(rel.shed.admitted >= 1,
            "the opening query always fits the empty backlog: {:?}", rel.shed);
    assert_eq!(rel.shed.admitted, a.metrics.per_query.len() as u64);
    assert_eq!(rel.shed.admitted, a.results.len() as u64);
    let rate = rel.shed.shed_rate();
    assert!(rate.is_finite() && rate > 0.0 && rate < 1.0, "shed rate {rate}");
    assert!(rel.brownout_spans >= 1,
            "a crowd member waits >= 7 ms virtual, past the 5 ms step: {rel:?}");
    assert!(rel.brownout_secs > 0.0);

    // bit-reproducible: the shed set is a function of the seed alone.
    assert_eq!(a.outcomes, b.outcomes, "same seed must shed the same arrivals");
    assert_eq!(a.metrics.reliability.shed, b.metrics.reliability.shed);
    assert_eq!(a.metrics.reliability.brownout_spans,
               b.metrics.reliability.brownout_spans);
    assert_eq!(answers(&a), answers(&b));

    // outcomes agree with the served results, in arrival order.
    let served: Vec<usize> = a.outcomes.iter().filter_map(|o| match o {
        QueryOutcome::Served { id } => Some(*id),
        QueryOutcome::Shed { .. } => None,
    }).collect();
    assert_eq!(served, a.results.iter().map(|r| r.id).collect::<Vec<_>>());

    // every admitted query's answer is bit-identical to the unloaded run.
    for r in &a.results {
        let want = reference_answers
            .get(&r.id)
            .expect("admitted query must exist in the reference run");
        assert_eq!(r.predicted.as_str(), *want,
                   "query {} answer must survive the overload", r.id);
    }
}

// ---------------------------------------------------------------------------
// Edge deadline: deadline zero + shedding on sheds EVERY query at
// admission — no device work, consistent counters, finite rates.
// ---------------------------------------------------------------------------

#[test]
fn zero_deadline_sheds_everything_at_admission() {
    let lat = SimLatency::from_millis(2, 1, 1, 1);
    let ds = sim_dataset(4, 4);
    let queries = ds.sample_test(5, 7);

    let env = common::sim_env(lat);
    let cfg = ServeConfig {
        deadline: Some(Duration::ZERO),
        overload: OverloadConfig { shed: true, ..OverloadConfig::default() },
        ..chaos_config()
    };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let r = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();

    assert_eq!(r.outcomes.len(), queries.len());
    assert!(r.outcomes.iter().all(
                |o| matches!(o, QueryOutcome::Shed { reason: ShedReason::Deadline, .. })),
            "deadline 0 admits nothing: {:?}", r.outcomes);
    assert!(r.results.is_empty());
    assert!(r.metrics.per_query.is_empty());
    let rel = &r.metrics.reliability;
    assert_eq!(rel.shed.admitted, 0);
    assert_eq!(rel.shed.shed_deadline, queries.len() as u64);
    assert_eq!(rel.shed.offered(), queries.len() as u64);
    assert_eq!(rel.shed.shed_rate(), 1.0);
    assert!(rel.shed.shed_rate().is_finite());
    assert_eq!(rel.deadline_hits, 0,
               "a query shed at admission never ran, so it cannot overrun");
    assert_eq!(rel.retries, 0);
    assert!(!rel.is_clean(), "an all-shed run is not a clean run: {rel:?}");
    // and throughput math over an empty served set stays finite.
    assert!(r.metrics.qps().is_finite());
    assert!(r.metrics.rt_ms().is_finite());
}
