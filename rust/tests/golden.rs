//! Cross-language golden tests: the Rust tokenizer / embedder / verbalizer
//! must reproduce the Python compile path byte-for-byte (the prompts the LM
//! was trained on ARE the serving prompts). Goldens are emitted by
//! `python -m compile.aot` into `artifacts/golden/`.

use subgcache::embed::embed_text;
use subgcache::graph::{prefix_text, full_prompt, Subgraph};
use subgcache::runtime::ArtifactStore;
use subgcache::util::json::Json;

mod common;

fn store() -> Option<ArtifactStore> {
    common::store("golden test")
}

#[test]
fn tokenizer_matches_python() {
    let Some(store) = store() else { return };
    let tok = store.tokenizer();
    let cases = store.golden("tokenizer.json").unwrap();
    let cases = cases.as_arr().unwrap();
    assert!(cases.len() >= 4);
    for case in cases {
        let text = case.get("text").as_str().unwrap();
        let want: Vec<i32> = case.get("ids").as_arr().unwrap()
            .iter().map(|v| v.as_i64().unwrap() as i32).collect();
        assert_eq!(tok.encode(text), want, "tokenizer mismatch on {text:?}");
    }
}

#[test]
fn embedder_matches_python() {
    let Some(store) = store() else { return };
    let cases = store.golden("embed.json").unwrap();
    for case in cases.as_arr().unwrap() {
        let text = case.get("text").as_str().unwrap();
        let want: Vec<f32> = case.get("vec").as_arr().unwrap()
            .iter().map(|v| v.as_f64().unwrap() as f32).collect();
        let got = embed_text(text);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-6, "embed mismatch on {text:?} dim {i}: {g} vs {w}");
        }
    }
}

#[test]
fn verbalizer_matches_python() {
    let Some(store) = store() else { return };
    let ds = store.dataset("scene_graph").unwrap();
    let cases = store.golden("verbalize.json").unwrap();
    for case in cases.as_arr().unwrap() {
        let nodes: Vec<usize> = case.get("nodes").as_arr().unwrap()
            .iter().map(|v| v.as_usize().unwrap()).collect();
        let edges: Vec<usize> = case.get("edges").as_arr().unwrap()
            .iter().map(|v| v.as_usize().unwrap()).collect();
        let sg = Subgraph::from_parts(nodes, edges);
        assert_eq!(prefix_text(&ds.graph, &sg, None),
                   case.get("prefix").as_str().unwrap());
        assert_eq!(prefix_text(&ds.graph, &sg, Some(24)),
                   case.get("prefix_capped").as_str().unwrap());
        let q = case.get("query").as_str().unwrap();
        assert_eq!(full_prompt(&ds.graph, &sg, q, None),
                   case.get("prompt").as_str().unwrap());
    }
}

#[test]
fn datasets_match_table1() {
    let Some(store) = store() else { return };
    let scene = store.dataset("scene_graph").unwrap();
    assert_eq!((scene.graph.n_nodes(), scene.graph.n_edges(), scene.queries.len()),
               (22, 147, 426));
    let oag = store.dataset("oag").unwrap();
    assert_eq!((oag.graph.n_nodes(), oag.graph.n_edges(), oag.queries.len()),
               (1071, 2022, 3434));
}

#[test]
fn dataset_vocab_fully_covered() {
    // Serving must never hit <unk> on dataset content (answers would be
    // ungeneratable) — mirrors python tests/test_train_aot.py.
    let Some(store) = store() else { return };
    let tok = store.tokenizer();
    for name in ["scene_graph", "oag"] {
        let ds = store.dataset(name).unwrap();
        for n in &ds.graph.nodes {
            assert!(!tok.encode(&n.text).contains(&subgcache::tokenizer::UNK_ID),
                    "{name}: unk in node {:?}", n.text);
        }
        for q in ds.queries.iter().take(200) {
            assert!(!tok.encode(&q.text).contains(&subgcache::tokenizer::UNK_ID));
            assert!(!tok.encode(&q.answer).contains(&subgcache::tokenizer::UNK_ID));
        }
    }
}

#[test]
fn manifest_covers_all_modules() {
    let Some(store) = store() else { return };
    let m = store.manifest();
    assert_eq!(m.llm_names().len(), 4, "expected 4 simulated backbones");
    assert_eq!(m.gnn_names().len(), 2, "expected graph_transformer + gat");
    for name in m.llm_names() {
        let ms = m.module(name).unwrap();
        for entry in ["prefill", "extend", "generate"] {
            let e = ms.entries.get(entry).expect(entry);
            assert!(store.root().join(&e.hlo).exists(), "missing {}", e.hlo);
        }
        assert!(store.root().join("weights").join(format!("{name}.npz")).exists());
    }
    let _ = Json::Null; // keep util::json linked into this test crate
}
