//! Coordinator end-to-end tests: the full SubGCache pipeline vs the
//! baseline on small in-batch workloads.
//!
//! Each scenario is written once against the `Backend` trait and runs in
//! two flavors: on the deterministic [`SimBackend`] (always — fresh clone,
//! CI), and on the real PJRT engine over `artifacts/` (the `*_artifacts`
//! variants, which self-skip with a message when artifacts are absent).

use subgcache::cluster::Linkage;
use subgcache::coordinator::{Coordinator, ServeConfig};
use subgcache::data::Dataset;
use subgcache::prelude::*;
use subgcache::runtime::SimLatency;

mod common;

fn with_engine<T>(f: impl FnOnce(&ArtifactStore, &Engine) -> T) -> Option<T> {
    common::with_engine("coordinator e2e test", f)
}

// ---------------------------------------------------------------------------
// Scenarios (backend-generic)
// ---------------------------------------------------------------------------

/// c = m degenerates SubGCache to per-query prompts built from the query's
/// own retrieved subgraph — answers must match the baseline exactly (greedy
/// decoding; same effective tokens reach the model either way).
fn check_singleton_parity(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                          base_cfg: &ServeConfig) {
    let queries = ds.sample_test(6, 3);
    let cfg = ServeConfig { n_clusters: queries.len(), ..base_cfg.clone() };
    let coord = Coordinator::new(store, backend, cfg).unwrap();
    let r = GRetriever::default();
    let base = coord.serve_baseline(ds, &queries, &r).unwrap();
    let ours = coord.serve_subgcache(ds, &queries, &r).unwrap();
    assert_eq!(ours.cluster_sizes.len(), queries.len());
    for (b, o) in base.results.iter().zip(&ours.results) {
        assert_eq!(b.id, o.id);
        assert_eq!(b.predicted, o.predicted,
                   "q{}: baseline {:?} vs singleton-subgcache {:?}",
                   b.id, b.predicted, o.predicted);
    }
}

fn check_reports_complete(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                          base_cfg: &ServeConfig) {
    let queries = ds.sample_test(10, 5);
    let coord = Coordinator::new(store, backend, base_cfg.clone()).unwrap();
    let rep = coord.serve_subgcache(ds, &queries, &GragRetriever::default()).unwrap();

    assert_eq!(rep.results.len(), queries.len());
    assert_eq!(rep.metrics.per_query.len(), queries.len());
    // results are in submit order
    for (r, q) in rep.results.iter().zip(&queries) {
        assert_eq!(r.id, q.id);
        assert_eq!(r.gold, q.answer);
    }
    // cluster bookkeeping
    assert_eq!(rep.cluster_sizes.iter().sum::<usize>(), queries.len());
    assert_eq!(rep.cluster_sizes.len(), rep.representative_sizes.len());
    assert!(rep.cluster_sizes.len() <= base_cfg.n_clusters);
    // every member's retrieved subgraph ⊆ its representative
    for r in &rep.results {
        let (rn, re) = rep.representative_sizes[r.cluster];
        let (qn, qe) = r.retrieved.len();
        assert!(qn <= rn && qe <= re, "representative smaller than member");
    }
    // cache: one prefill + one release per cluster; a hit per member
    // beyond each cluster's first (the first rides the fresh prefill)
    assert_eq!(rep.cache.prefills as usize, rep.cluster_sizes.len());
    assert_eq!(rep.cache.released as usize, rep.cluster_sizes.len());
    assert_eq!(rep.cache.hits as usize, queries.len() - rep.cluster_sizes.len());
    assert_eq!(rep.cache.resident_bytes, 0, "cache must be drained");
    // latency sanity
    for q in &rep.metrics.per_query {
        assert!(q.pftt > 0.0 && q.ttft >= q.pftt && q.rt >= q.ttft);
    }
    // the encode stage ran on the GNN lane, everything else on the LLM lane
    assert_eq!(rep.metrics.lane_gnn.calls as usize, queries.len());
    assert!(rep.metrics.lane_llm.calls > 0);
}

/// The headline claim at small scale: shared-prefix extend is much cheaper
/// than per-query full prefill.
fn check_pftt_cut(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                  base_cfg: &ServeConfig) {
    let queries = ds.sample_test(8, 11);
    let cfg = ServeConfig { n_clusters: 1, ..base_cfg.clone() };
    let coord = Coordinator::new(store, backend, cfg).unwrap();
    let r = GRetriever::default();
    let base = coord.serve_baseline(ds, &queries, &r).unwrap();
    let ours = coord.serve_subgcache(ds, &queries, &r).unwrap();
    assert!(
        ours.metrics.pftt_ms() < base.metrics.pftt_ms(),
        "PFTT should drop: baseline {:.1} ms vs subgcache {:.1} ms",
        base.metrics.pftt_ms(), ours.metrics.pftt_ms()
    );
}

fn check_no_kv_leaks(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                     base_cfg: &ServeConfig) {
    let queries = ds.sample_test(5, 17);
    let coord = Coordinator::new(store, backend, base_cfg.clone()).unwrap();
    let r = GRetriever::default();
    let live_before = backend.stats().unwrap().live_kv;
    coord.serve_baseline(ds, &queries, &r).unwrap();
    coord.serve_subgcache(ds, &queries, &r).unwrap();
    assert_eq!(backend.stats().unwrap().live_kv, live_before, "leaked KV handles");
}

fn check_all_backbones(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                       base_cfg: &ServeConfig) {
    let queries = ds.sample_test(3, 23);
    for backbone in store.manifest().llm_names() {
        let cfg = ServeConfig { backbone: backbone.to_string(), n_clusters: 1,
                                ..base_cfg.clone() };
        let coord = Coordinator::new(store, backend, cfg).unwrap();
        let rep = coord.serve_subgcache(ds, &queries, &GRetriever::default()).unwrap();
        assert_eq!(rep.results.len(), 3, "{backbone}");
        for r in &rep.results {
            assert!(!r.predicted.is_empty() || r.gold.is_empty(),
                    "{backbone}: empty generation for {:?}", r.query);
        }
    }
}

fn check_linkages(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                  base_cfg: &ServeConfig) {
    let queries = ds.sample_test(6, 29);
    for linkage in Linkage::ALL {
        let cfg = ServeConfig { n_clusters: 3, linkage, ..base_cfg.clone() };
        let coord = Coordinator::new(store, backend, cfg).unwrap();
        let rep = coord.serve_subgcache(ds, &queries, &GragRetriever::default()).unwrap();
        assert_eq!(rep.cluster_sizes.len(), 3, "{linkage:?}");
        assert_eq!(rep.results.len(), 6);
    }
}

fn check_rejects_unknown_backbone(store: &ArtifactStore, backend: &dyn Backend) {
    let cfg = ServeConfig { backbone: "gpt-5".into(), ..Default::default() };
    assert!(Coordinator::new(store, backend, cfg).is_err());
    // a GNN module exists in the manifest but has no KV geometry — the
    // coordinator must reject it up front, not size cache entries at 0.
    let cfg = ServeConfig { backbone: "gat".into(), ..Default::default() };
    assert!(Coordinator::new(store, backend, cfg).is_err());
}

// ---------------------------------------------------------------------------
// Sim flavor (always runs)
// ---------------------------------------------------------------------------

#[test]
fn sim_subgcache_answers_match_baseline_with_singleton_clusters() {
    let env = common::sim_env(SimLatency::zero());
    check_singleton_parity(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_pipeline_reports_are_complete_and_consistent() {
    let env = common::sim_env(SimLatency::zero());
    check_reports_complete(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_subgcache_cuts_pftt_vs_baseline() {
    // prefill dominates extend, as on real hardware, so the shared-prefix
    // win is visible and the assertion is robust to scheduler jitter.
    let env = common::sim_env(SimLatency::from_millis(10, 2, 2, 2));
    check_pftt_cut(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_no_kv_leaks_after_serving() {
    let env = common::sim_env(SimLatency::zero());
    check_no_kv_leaks(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_works_across_all_backbones() {
    let env = common::sim_env(SimLatency::zero());
    check_all_backbones(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_linkage_strategies_all_serve() {
    let env = common::sim_env(SimLatency::zero());
    check_linkages(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_rejects_unknown_backbone() {
    let env = common::sim_env(SimLatency::zero());
    check_rejects_unknown_backbone(&env.store, &env.backend);
}

// ---------------------------------------------------------------------------
// Artifact flavor (opt-in by presence of artifacts/)
// ---------------------------------------------------------------------------

#[test]
fn subgcache_answers_match_baseline_with_singleton_clusters_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_singleton_parity(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn pipeline_reports_are_complete_and_consistent_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("oag").unwrap();
        check_reports_complete(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn subgcache_cuts_pftt_vs_baseline_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_pftt_cut(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn no_kv_leaks_after_serving_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_no_kv_leaks(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn works_across_all_backbones_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_all_backbones(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn linkage_strategies_all_serve_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("oag").unwrap();
        check_linkages(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn rejects_unknown_backbone_artifacts() {
    with_engine(|store, engine| {
        check_rejects_unknown_backbone(store, engine);
    });
}
