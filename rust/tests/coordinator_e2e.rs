//! Coordinator end-to-end tests over real artifacts: the full SubGCache
//! pipeline vs the baseline on small in-batch workloads.
//!
//! Skipped (with a message) when `artifacts/` is absent, so `cargo test -q`
//! stays green on a fresh clone; run `make artifacts` to enable.

use subgcache::cluster::Linkage;
use subgcache::coordinator::{Coordinator, ServeConfig};
use subgcache::prelude::*;
use subgcache::runtime::{ArtifactStore, Engine};

mod common;

fn with_engine<T>(f: impl FnOnce(&ArtifactStore, &Engine) -> T) -> Option<T> {
    common::with_engine("coordinator e2e test", f)
}

#[test]
fn subgcache_answers_match_baseline_with_singleton_clusters() {
    // c = m degenerates SubGCache to per-query prompts built from the query's
    // own retrieved subgraph — answers must match the baseline exactly
    // (greedy decoding; same tokens reach the model either way).
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(6, 3);
        let cfg = ServeConfig { n_clusters: queries.len(), ..Default::default() };
        let coord = Coordinator::new(store, engine, cfg).unwrap();
        let r = GRetriever::default();
        let base = coord.serve_baseline(&ds, &queries, &r).unwrap();
        let ours = coord.serve_subgcache(&ds, &queries, &r).unwrap();
        assert_eq!(ours.cluster_sizes.len(), queries.len());
        for (b, o) in base.results.iter().zip(&ours.results) {
            assert_eq!(b.id, o.id);
            assert_eq!(b.predicted, o.predicted,
                       "q{}: baseline {:?} vs singleton-subgcache {:?}",
                       b.id, b.predicted, o.predicted);
        }
    });
}

#[test]
fn pipeline_reports_are_complete_and_consistent() {
    with_engine(|store, engine| {
        let ds = store.dataset("oag").unwrap();
        let queries = ds.sample_test(10, 5);
        let coord = Coordinator::new(store, engine, ServeConfig::default()).unwrap();
        let rep = coord.serve_subgcache(&ds, &queries, &GragRetriever::default()).unwrap();

        assert_eq!(rep.results.len(), queries.len());
        assert_eq!(rep.metrics.per_query.len(), queries.len());
        // results are in submit order
        for (r, q) in rep.results.iter().zip(&queries) {
            assert_eq!(r.id, q.id);
            assert_eq!(r.gold, q.answer);
        }
        // cluster bookkeeping
        assert_eq!(rep.cluster_sizes.iter().sum::<usize>(), queries.len());
        assert_eq!(rep.cluster_sizes.len(), rep.representative_sizes.len());
        assert!(rep.cluster_sizes.len() <= 2);
        // every member's retrieved subgraph ⊆ its representative
        for r in &rep.results {
            let (rn, re) = rep.representative_sizes[r.cluster];
            let (qn, qe) = r.retrieved.len();
            assert!(qn <= rn && qe <= re, "representative smaller than member");
        }
        // cache: one prefill + one release per cluster; a hit per member
        // beyond each cluster's first (the first rides the fresh prefill)
        assert_eq!(rep.cache.prefills as usize, rep.cluster_sizes.len());
        assert_eq!(rep.cache.released as usize, rep.cluster_sizes.len());
        assert_eq!(rep.cache.hits as usize, queries.len() - rep.cluster_sizes.len());
        assert_eq!(rep.cache.resident_bytes, 0, "cache must be drained");
        // latency sanity
        for q in &rep.metrics.per_query {
            assert!(q.pftt > 0.0 && q.ttft >= q.pftt && q.rt >= q.ttft);
        }
    });
}

#[test]
fn subgcache_cuts_pftt_vs_baseline() {
    // The headline claim at small scale: shared-prefix extend is much
    // cheaper than per-query full prefill.
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(8, 11);
        let cfg = ServeConfig { n_clusters: 1, ..Default::default() };
        let coord = Coordinator::new(store, engine, cfg).unwrap();
        let r = GRetriever::default();
        let base = coord.serve_baseline(&ds, &queries, &r).unwrap();
        let ours = coord.serve_subgcache(&ds, &queries, &r).unwrap();
        assert!(
            ours.metrics.pftt_ms() < base.metrics.pftt_ms(),
            "PFTT should drop: baseline {:.1} ms vs subgcache {:.1} ms",
            base.metrics.pftt_ms(), ours.metrics.pftt_ms()
        );
    });
}

#[test]
fn no_kv_leaks_after_serving() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(5, 17);
        let coord = Coordinator::new(store, engine, ServeConfig::default()).unwrap();
        let r = GRetriever::default();
        let live_before = engine.stats().unwrap().live_kv;
        coord.serve_baseline(&ds, &queries, &r).unwrap();
        coord.serve_subgcache(&ds, &queries, &r).unwrap();
        assert_eq!(engine.stats().unwrap().live_kv, live_before, "leaked KV handles");
    });
}

#[test]
fn works_across_all_backbones() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(3, 23);
        for backbone in store.manifest().llm_names() {
            let cfg = ServeConfig { backbone: backbone.to_string(), n_clusters: 1,
                                    ..Default::default() };
            let coord = Coordinator::new(store, engine, cfg).unwrap();
            let rep = coord.serve_subgcache(&ds, &queries, &GRetriever::default()).unwrap();
            assert_eq!(rep.results.len(), 3, "{backbone}");
            for r in &rep.results {
                assert!(!r.predicted.is_empty() || r.gold.is_empty(),
                        "{backbone}: empty generation for {:?}", r.query);
            }
        }
    });
}

#[test]
fn linkage_strategies_all_serve() {
    with_engine(|store, engine| {
        let ds = store.dataset("oag").unwrap();
        let queries = ds.sample_test(6, 29);
        for linkage in Linkage::ALL {
            let cfg = ServeConfig { n_clusters: 3, linkage, ..Default::default() };
            let coord = Coordinator::new(store, engine, cfg).unwrap();
            let rep = coord.serve_subgcache(&ds, &queries, &GragRetriever::default()).unwrap();
            assert_eq!(rep.cluster_sizes.len(), 3, "{linkage:?}");
            assert_eq!(rep.results.len(), 6);
        }
    });
}

#[test]
fn rejects_unknown_backbone() {
    with_engine(|store, engine| {
        let cfg = ServeConfig { backbone: "gpt-5".into(), ..Default::default() };
        assert!(Coordinator::new(store, engine, cfg).is_err());
        // a GNN module exists in the manifest but has no KV geometry — the
        // coordinator must reject it up front, not size cache entries at 0.
        let cfg = ServeConfig { backbone: "gat".into(), ..Default::default() };
        assert!(Coordinator::new(store, engine, cfg).is_err());
    });
}
