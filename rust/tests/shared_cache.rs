//! Concurrency suite for the shared cross-stream KV cache
//! ([`subgcache::cache::SharedKvCache`]) and the multi-stream serving path
//! (`Coordinator::serve_online_multi`), driven on the deterministic
//! [`SimBackend`] so every scenario runs un-skipped in plain `cargo test`
//! under default parallel test threads.
//!
//! What is pinned down here:
//!
//! * **Dedup** — with N streams sharing representatives, the pool pays one
//!   prefill per *distinct* representative (single-flight install
//!   coalescing), never N; `shared_hits`/`dedup_bytes_saved` surface it.
//! * **Budget** — the byte/entry budget holds at every observable moment
//!   under concurrency (or only pinned entries remain, the documented
//!   overrun), checked by a live poller thread.
//! * **Pin safety** — no entry is released while any stream pins it: if it
//!   were, the sim backend would fail the pinned stream's extend with an
//!   unknown-handle error, so "all streams correct" is the proof.
//! * **Conservation** — every handle installed into the pool leaves it
//!   exactly once (evictions, releases, deferred graveyard, final drain),
//!   under a randomized multi-threaded hammer.
//! * **Failure** — a dead LLM lane mid-run errors every stream instead of
//!   hanging any, and aborted install reservations wake their waiters.
//! * **Parity** — single-stream `serve_online` and a one-stream
//!   `serve_online_multi` agree metric-for-metric with the serial PR 3
//!   path for k ∈ {1, 2, 4}.
//! * **Tiering** — with a host budget, a device eviction demotes the entry
//!   to the host tier and a revisit promotes it back: strictly cheaper
//!   than repaying the prefill, bit-identical answers, and copies killed
//!   by the host budget (or stranded by a lane death) never leak and never
//!   resurrect stale KV.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use subgcache::data::Query;
use subgcache::prelude::*;
use subgcache::runtime::{sim_dataset, SimLatency};
use subgcache::util::prop::prop_check;

mod common;

/// N identical copies of one seed-sampled query sequence — the
/// many-users-asking-similar-things regime cross-stream sharing targets.
fn replicated_streams<'q>(queries: &[&'q Query], n: usize) -> Vec<Vec<&'q Query>> {
    (0..n).map(|_| queries.to_vec()).collect()
}

/// Distinct retrieved-subgraph contents across a query set: the expected
/// number of pool prefills under ample budget (content-keyed dedup).
fn distinct_reps(ds: &subgcache::data::Dataset, queries: &[&Query]) -> usize {
    let feats = GraphFeatures::build(&ds.graph);
    let r = GRetriever::default();
    let mut seen: BTreeSet<(Vec<usize>, Vec<usize>)> = BTreeSet::new();
    for q in queries {
        let sg = r.retrieve(&ds.graph, &feats, &q.text);
        seen.insert((sg.nodes.iter().copied().collect(),
                     sg.edges.iter().copied().collect()));
    }
    seen.len()
}

// ---------------------------------------------------------------------------
// The acceptance criterion: 4 streams, one prefill per distinct rep,
// dedup_bytes_saved > 0, and multi wall beats 4 serial runs.
// ---------------------------------------------------------------------------

#[test]
fn four_streams_share_one_prefill_and_beat_serial_wall() {
    // prefill-dominant latencies: the dedup (1 pool prefill instead of 4)
    // must show up in wall time, not just counters.
    let lat = SimLatency::from_millis(40, 1, 1, 1);
    let n_queries = 6;
    let n_streams = 4;

    let env = common::sim_env(lat);
    let ds = sim_dataset(4, 4);
    let cfg = ServeConfig {
        online_threshold: f32::INFINITY, // one cluster per stream, same rep
        ..common::sim_config()
    };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let queries = ds.sample_test(n_queries, 7);
    assert_eq!(queries.len(), n_queries);

    // serial reference: the same workload as 4 back-to-back single streams.
    let mut serial_wall = 0.0;
    let mut serial_answers: Vec<Vec<String>> = Vec::new();
    for _ in 0..n_streams {
        let r = coord
            .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
            .unwrap();
        serial_wall += r.metrics.wall_time;
        serial_answers.push(r.results.iter().map(|x| x.predicted.clone()).collect());
    }

    let streams = replicated_streams(&queries, n_streams);
    let multi = coord
        .serve_online_multi(&ds, &streams, &GRetriever::default())
        .unwrap();

    // -- dedup: one prefill for the whole fleet, not 4x --------------------
    assert_eq!(multi.streams.len(), n_streams);
    assert_eq!(multi.shared.prefills, 1,
               "identical representatives must be prefilled once, not {n_streams}x");
    assert!(multi.shared.shared_hits >= (n_streams - 1) as u64,
            "every non-installing stream scores at least one shared hit: {:?}",
            multi.shared);
    assert!(multi.shared.dedup_bytes_saved > 0);
    assert_eq!(multi.shared.evictions, 0, "ample budget must not evict");
    // one shared entry means device residency never exceeded one rep cache
    // — the byte-budget face of the dedup claim.
    let entry_bytes = env.backend.kv_bytes(subgcache::runtime::SIM_BACKBONE).unwrap();
    assert_eq!(multi.shared.peak_bytes, entry_bytes,
               "four streams must never hold more than the one shared entry");

    // per-stream accounting stays complete and consistent with the pool
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut shared_hits = 0u64;
    for (si, r) in multi.streams.iter().enumerate() {
        assert_eq!(r.metrics.per_query.len(), n_queries, "stream {si} incomplete");
        assert_eq!(r.metrics.hit_count() + r.metrics.miss_count(), n_queries);
        assert_eq!(r.metrics.shared_hits, r.cache.shared_hits,
                   "metrics must mirror the stream's cache view");
        assert_eq!(r.metrics.dedup_bytes_saved, r.cache.dedup_bytes_saved);
        hits += r.cache.hits;
        misses += r.cache.misses;
        shared_hits += r.cache.shared_hits;
        // sharing must never change answers: every stream matches serial.
        let got: Vec<String> = r.results.iter().map(|x| x.predicted.clone()).collect();
        assert_eq!(got, serial_answers[0], "stream {si} diverged from serial answers");
    }
    assert_eq!(hits, multi.shared.hits, "view hit counters must sum to the pool's");
    assert_eq!(misses, multi.shared.misses);
    assert_eq!(shared_hits, multi.shared.shared_hits);
    assert_eq!(hits + misses, (n_streams * n_queries) as u64);

    // -- wall time: concurrency + dedup must beat 4 serial runs ------------
    assert!(
        multi.wall_time < serial_wall * 0.75,
        "4 shared streams should clearly beat 4 serial runs: multi {:.3}s vs \
         serial total {:.3}s",
        multi.wall_time, serial_wall
    );
    assert!(multi.qps() > 0.0);
    assert!(multi.lock.acquisitions > 0);

    // nothing leaked: the pool was drained back to the backend.
    assert_eq!(env.backend.stats().unwrap().live_kv, 0, "leaked KV handles");
}

/// Single-flight dedup and handle conservation must survive the LLM-lane
/// micro-batcher: concurrent streams whose extends now fuse into shared
/// device launches still pay one pool prefill per distinct representative,
/// still drain every handle, and still answer serial-identically.
#[test]
fn batched_streams_keep_dedup_and_answers_consistent() {
    let lat = SimLatency::from_millis(8, 3, 1, 1).with_per_item_millis(2, 1, 1, 1);
    let env = common::sim_env_batched(lat, BatchConfig::new(4, Duration::from_millis(3)));
    let ds = sim_dataset(4, 4);
    let cfg = ServeConfig { online_threshold: f32::INFINITY, ..common::sim_config() };
    let coord = Coordinator::new(&env.store, &env.backend, cfg.clone()).unwrap();
    let queries = ds.sample_test(6, 7);

    // unbatched zero-latency reference answers (sim logits are a pure
    // function of the token sequences, so backends agree bit for bit)
    let serial_env = common::sim_env(SimLatency::zero());
    let serial_coord = Coordinator::new(&serial_env.store, &serial_env.backend, cfg)
        .unwrap();
    let serial = serial_coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap();
    let serial_answers: Vec<String> =
        serial.results.iter().map(|r| r.predicted.clone()).collect();

    let streams = replicated_streams(&queries, 4);
    let multi = coord
        .serve_online_multi(&ds, &streams, &GRetriever::default())
        .unwrap();
    assert_eq!(multi.shared.prefills, 1,
               "single-flight dedup must survive batching");
    for (si, r) in multi.streams.iter().enumerate() {
        let got: Vec<String> = r.results.iter().map(|x| x.predicted.clone()).collect();
        assert_eq!(got, serial_answers, "stream {si} diverged under batching");
    }
    let st = env.backend.stats().unwrap();
    assert_eq!(st.live_kv, 0, "handle conservation must survive batching");
    assert_eq!(st.unbatched_fallbacks, 0, "the sim fuses everything");
}

#[test]
fn pool_prefills_equal_distinct_reps_under_never_join() {
    // never-join: every query opens its own cluster, so representative
    // contents repeat both within and across streams. With an ample budget
    // the pool must pay exactly one prefill per DISTINCT content.
    let env = common::sim_env(SimLatency::zero());
    let ds = sim_dataset(3, 4);
    let cfg = ServeConfig {
        online_threshold: -1.0,
        cache: CachePolicy::unbounded(),
        ..common::sim_config()
    };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let queries = ds.sample_test(8, 11);
    let expect = distinct_reps(&ds, &queries);
    assert!(expect >= 2, "fixture should span several distinct reps");

    let streams = replicated_streams(&queries, 3);
    let multi = coord
        .serve_online_multi(&ds, &streams, &GRetriever::default())
        .unwrap();
    assert_eq!(multi.shared.prefills as usize, expect,
               "prefills must equal distinct representative contents");
    assert_eq!(multi.shared.evictions, 0);
    assert_eq!(env.backend.stats().unwrap().live_kv, 0);
}

// ---------------------------------------------------------------------------
// Host tier: demote → promote round trips (the PR 7 acceptance criterion)
// ---------------------------------------------------------------------------

/// First `n` queries of `sample` with pairwise-distinct retrieved-subgraph
/// contents — the minimal workload that churns a one-entry device budget.
fn distinct_rep_queries<'q>(ds: &subgcache::data::Dataset, sample: &[&'q Query],
                            n: usize) -> Vec<&'q Query> {
    let feats = GraphFeatures::build(&ds.graph);
    let r = GRetriever::default();
    let mut seen: BTreeSet<(Vec<usize>, Vec<usize>)> = BTreeSet::new();
    let mut out = Vec::new();
    for q in sample {
        let sg = r.retrieve(&ds.graph, &feats, &q.text);
        if seen.insert((sg.nodes.iter().copied().collect(),
                        sg.edges.iter().copied().collect())) {
            out.push(*q);
            if out.len() == n {
                break;
            }
        }
    }
    out
}

/// A demoted representative promotes back strictly cheaper than a repaid
/// prefill, with bit-identical answers to a never-evicted run, and the
/// tier counters (`demotions`/`promotions`/`host_hits`) on the books.
#[test]
fn demoted_rep_promotes_cheaper_than_repaid_prefill_bit_identical() {
    // 30 ms prefill vs a ~4 ms promotion copy (65536 B × 61 ns/B): the
    // gap must show up in the revisit's prompt-ready → first-token time.
    let lat = SimLatency::from_millis(30, 2, 2, 2)
        .with_host_copy_per_byte(Duration::from_nanos(61));
    let env = common::sim_env(lat);
    let ds = sim_dataset(3, 4);
    let sample = ds.sample_test(8, 11);
    let picked = distinct_rep_queries(&ds, &sample, 2);
    assert_eq!(picked.len(), 2, "fixture must span two distinct reps");
    // a, b, a: under a one-entry device budget the revisit of `a` finds
    // it demoted, not resident. Never-join so every query opens its own
    // cluster and the content keying (not cluster identity) dedups.
    let queries = vec![picked[0], picked[1], picked[0]];
    let cfg = ServeConfig { online_threshold: -1.0, ..common::sim_config() };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let retr = GRetriever::default();

    let serve = |policy: CachePolicy| {
        let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
            Arc::new(SharedKvCache::new(policy));
        let mut view = KvCacheManager::shared_view(&pool);
        let r = coord
            .serve_online_with_cache(&ds, queries.iter().copied(), &retr, &mut view)
            .unwrap();
        env.backend.release_many(pool.drain_all());
        r
    };
    let tiered = serve(CachePolicy::new(usize::MAX, 1).with_host_bytes(1 << 20));
    let repaid = serve(CachePolicy::new(usize::MAX, 1));
    let warm = serve(CachePolicy::unbounded());

    // the round trip must never change an answer.
    let answers = |r: &ServeReport| -> Vec<String> {
        r.results.iter().map(|x| x.predicted.clone()).collect()
    };
    assert_eq!(answers(&tiered), answers(&warm),
               "demote → promote round trip changed an answer");
    assert_eq!(answers(&repaid), answers(&warm), "repaid run changed an answer");

    // tier counters nonzero, and the repay actually skipped.
    assert_eq!(tiered.cache.prefills, 2, "the revisit must promote, not repay");
    assert_eq!(tiered.cache.promotions, 1, "{:?}", tiered.cache);
    assert_eq!(tiered.cache.host_hits, 1, "{:?}", tiered.cache);
    assert_eq!(tiered.cache.demotions, 2,
               "each eviction demotes (b again at the promote): {:?}", tiered.cache);
    assert_eq!(repaid.cache.prefills, 3, "no host tier: the revisit repays");
    assert_eq!(repaid.cache.promotions, 0);
    assert_eq!(warm.cache.prefills, 2);
    assert_eq!(warm.cache.evictions, 0);

    // strictly cheaper: the promotion copy beats the repaid prefill.
    let promoted = tiered.metrics.per_query[2].pftt;
    let repay = repaid.metrics.per_query[2].pftt;
    assert!(promoted > 0.0, "the copy is not free");
    assert!(promoted < repay * 0.5,
            "a host-tier hit must be well under a repaid prefill: \
             promoted {promoted:.4}s vs repaid {repay:.4}s");
    assert!(promoted < tiered.metrics.per_query[0].pftt,
            "the promotion must also beat this run's own cold misses");
    assert_eq!(tiered.metrics.per_query[2].cache_hit, Some(false),
               "a promotion is still a device miss in the hit/miss split");

    assert_eq!(env.backend.stats().unwrap().live_kv, 0,
               "device KV and host copies must all drain");
}

/// Host budget smaller than one entry: every demotion is admitted and then
/// immediately LRU-killed (demotion-to-death), so revisits are true misses
/// again — and the killed copies drain back to the backend, never leak.
#[test]
fn host_budget_exhaustion_kills_copies_and_revisits_repay() {
    let lat = SimLatency::from_millis(4, 1, 1, 1)
        .with_host_copy_per_byte(Duration::from_nanos(5));
    let env = common::sim_env(lat);
    let ds = sim_dataset(3, 4);
    let sample = ds.sample_test(8, 11);
    let picked = distinct_rep_queries(&ds, &sample, 2);
    assert_eq!(picked.len(), 2, "fixture must span two distinct reps");
    let queries = vec![picked[0], picked[1], picked[0], picked[1]];
    let cfg = ServeConfig { online_threshold: -1.0, ..common::sim_config() };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let entry_bytes = env.backend.kv_bytes(subgcache::runtime::SIM_BACKBONE).unwrap();

    let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
        Arc::new(SharedKvCache::new(
            CachePolicy::new(usize::MAX, 1).with_host_bytes(entry_bytes / 2)));
    let mut view = KvCacheManager::shared_view(&pool);
    let r = coord
        .serve_online_with_cache(&ds, queries.iter().copied(),
                                 &GRetriever::default(), &mut view)
        .unwrap();

    assert_eq!(r.cache.prefills, 4, "dead host copies must not serve hits");
    assert_eq!(r.cache.promotions, 0, "{:?}", r.cache);
    assert_eq!(r.cache.host_hits, 0, "{:?}", r.cache);
    assert_eq!(r.cache.demotions, 3,
               "every eviction was offered to the tier: {:?}", r.cache);
    assert_eq!(pool.host_resident_bytes(), 0, "no copy survives the budget");
    env.backend.release_many(pool.drain_all());
    assert_eq!(env.backend.stats().unwrap().live_kv, 0,
               "killed host copies must drain back to the backend");
}

/// A lane death invalidates device residency, but host-tier copies survive
/// and keep promoting after the supervisor restart — answers bit-identical
/// to the fault-free run, with at most a bounded repay bill.
#[test]
fn quarantined_device_entries_repromote_from_surviving_host_copies() {
    let lat = SimLatency::from_millis(5, 1, 1, 1)
        .with_host_copy_per_byte(Duration::from_nanos(10));
    let ds = sim_dataset(3, 4);
    let sample = ds.sample_test(8, 11);
    let picked = distinct_rep_queries(&ds, &sample, 2);
    assert_eq!(picked.len(), 2, "fixture must span two distinct reps");
    // long a/b alternation: under a one-entry device budget one rep is
    // always on device and the other in the host tier, so the kill lands
    // with a live host copy whichever op it interrupts.
    let mut queries: Vec<&Query> = Vec::new();
    for _ in 0..8 {
        queries.push(picked[0]);
        queries.push(picked[1]);
    }
    let cfg = ServeConfig { online_threshold: -1.0, ..common::sim_config() };
    let policy = CachePolicy::new(usize::MAX, 1).with_host_bytes(1 << 20);
    let retr = GRetriever::default();

    let serve = |store: &ArtifactStore, backend: &SimBackend| {
        let coord = Coordinator::new(store, backend, cfg.clone()).unwrap();
        let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
            Arc::new(SharedKvCache::new(policy));
        let mut view = KvCacheManager::shared_view(&pool);
        let r = coord
            .serve_online_with_cache(&ds, queries.iter().copied(), &retr, &mut view)
            .unwrap();
        backend.release_many(pool.drain_all());
        r
    };

    let clean = common::sim_env(lat);
    let want = serve(&clean.store, &clean.backend);
    assert_eq!(want.cache.prefills, 2, "alternation must live off the tier");
    assert!(want.cache.promotions >= 10, "{:?}", want.cache);

    // kill the LLM lane mid-alternation; the supervisor restarts it.
    let plan = FaultPlan { seed: 9, kill_llm_at_op: Some(20), ..FaultPlan::none() };
    let store = subgcache::runtime::sim_store();
    let backend = SimBackend::start_faulty(&store, lat, BatchConfig::off(), plan,
                                           SupervisorPolicy::default())
        .expect("faulty sim backend start");
    let got = serve(&store, &backend);

    let get = |r: &ServeReport| -> Vec<String> {
        r.results.iter().map(|x| x.predicted.clone()).collect()
    };
    assert_eq!(get(&got), get(&want),
               "promoted and repaid recovery must agree bit-identical");
    let rel = got.metrics.reliability;
    assert_eq!(rel.restarts, 1, "exactly one supervisor restart: {rel:?}");
    assert!(got.cache.quarantined >= 1,
            "the stranded device entry must be quarantined: {:?}", got.cache);
    assert!(got.cache.promotions >= 1,
            "host copies must keep promoting across the lane death: {:?}",
            got.cache);
    assert!(got.cache.prefills > want.cache.prefills,
            "the quarantined key itself repays: {:?}", got.cache);
    assert!(got.cache.prefills <= want.cache.prefills + 3,
            "surviving host copies must cap the repay bill: {:?}", got.cache);
    assert_eq!(backend.lane_restarts(), 1);
}

/// Three tiers end to end: a device eviction demotes to a host budget too
/// small to keep the copy, which spills it to the disk archive; the revisit
/// recalls disk → host → device bit-identical and strictly cheaper than the
/// repaid prefill, with `archived`/`recalls`/`disk_hits` on the books.
#[test]
fn archived_rep_recalls_cheaper_than_repaid_prefill_bit_identical() {
    // 30 ms prefill vs a ~4 ms recall walk (the promote copy dominates:
    // 65536 B × 61 ns/B): the gap must show up in the revisit's PFTT.
    let lat = SimLatency::from_millis(30, 2, 2, 2)
        .with_host_copy_per_byte(Duration::from_nanos(61));
    let env = common::sim_env(lat);
    let ds = sim_dataset(3, 4);
    let sample = ds.sample_test(8, 11);
    let picked = distinct_rep_queries(&ds, &sample, 2);
    assert_eq!(picked.len(), 2, "fixture must span two distinct reps");
    // a, b, a under a one-entry device budget AND a half-entry host budget:
    // installing `b` demotes `a` to the host tier, whose budget immediately
    // spills it to disk — so the revisit of `a` is a disk recall, not a
    // promotion.
    let queries = vec![picked[0], picked[1], picked[0]];
    let cfg = ServeConfig { online_threshold: -1.0, ..common::sim_config() };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let retr = GRetriever::default();
    let entry_bytes = env.backend.kv_bytes(subgcache::runtime::SIM_BACKBONE).unwrap();

    let serve = |policy: CachePolicy| {
        let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
            Arc::new(SharedKvCache::new(policy));
        let mut view = KvCacheManager::shared_view(&pool);
        let r = coord
            .serve_online_with_cache(&ds, queries.iter().copied(), &retr, &mut view)
            .unwrap();
        env.backend.release_many(pool.drain_all());
        r
    };
    let tiered = serve(CachePolicy::new(usize::MAX, 1)
        .with_host_bytes(entry_bytes / 2)
        .with_disk_bytes(64 << 20));
    let repaid = serve(CachePolicy::new(usize::MAX, 1));
    let warm = serve(CachePolicy::unbounded());

    // the archive round trip must never change an answer.
    let answers = |r: &ServeReport| -> Vec<String> {
        r.results.iter().map(|x| x.predicted.clone()).collect()
    };
    assert_eq!(answers(&tiered), answers(&warm),
               "demote → archive → recall round trip changed an answer");
    assert_eq!(answers(&repaid), answers(&warm), "repaid run changed an answer");

    // tier counters nonzero, and the repay actually skipped.
    assert_eq!(tiered.cache.prefills, 2, "the revisit must recall, not repay");
    assert_eq!(tiered.cache.recalls, 1, "{:?}", tiered.cache);
    assert_eq!(tiered.cache.disk_hits, 1, "{:?}", tiered.cache);
    assert_eq!(tiered.cache.archived, 2,
               "both host-budget deaths must spill to disk: {:?}", tiered.cache);
    assert_eq!(tiered.cache.demotions, 2, "{:?}", tiered.cache);
    assert_eq!(tiered.cache.promotions, 0,
               "the half-entry host budget keeps no copy to promote: {:?}",
               tiered.cache);
    assert_eq!(tiered.cache.host_hits, 0, "{:?}", tiered.cache);
    assert_eq!(repaid.cache.prefills, 3, "no disk tier: the revisit repays");
    assert_eq!(repaid.cache.recalls, 0);
    assert_eq!(warm.cache.prefills, 2);
    assert_eq!(warm.cache.evictions, 0);

    // strictly cheaper: the recall walk beats the repaid prefill.
    let recalled = tiered.metrics.per_query[2].pftt;
    let repay = repaid.metrics.per_query[2].pftt;
    assert!(recalled > 0.0, "the recall walk is not free");
    assert!(recalled < repay * 0.5,
            "a disk-tier hit must be well under a repaid prefill: \
             recalled {recalled:.4}s vs repaid {repay:.4}s");
    assert!(recalled < tiered.metrics.per_query[0].pftt,
            "the recall must also beat this run's own cold misses");
    assert_eq!(tiered.metrics.per_query[2].cache_hit, Some(false),
               "a recall is still a device miss in the hit/miss split");

    assert_eq!(env.backend.stats().unwrap().live_kv, 0,
               "device KV, host copies and recalled handles must all drain");
}

// ---------------------------------------------------------------------------
// Randomized concurrent workloads (the satellite property tests)
// ---------------------------------------------------------------------------

/// N threads x M queries with overlapping representatives: byte budget held
/// at every observed moment, all streams complete with serial-identical
/// answers (pin safety), and hit/miss/eviction counters sum consistently.
#[test]
fn randomized_concurrent_streams_hold_budget_and_stay_consistent() {
    prop_check(4, |rng| {
        let n_streams = rng.range(2, 5);
        let n_queries = rng.range(3, 8);
        let tight = rng.below(2) == 0;
        let cache = if tight {
            CachePolicy::new(usize::MAX, rng.range(1, 3))
        } else {
            CachePolicy::unbounded()
        };
        let thresholds = [-1.0f32, 0.5, f32::INFINITY];
        let cfg = ServeConfig {
            online_threshold: thresholds[rng.below(3)],
            cache,
            pipeline_depth: 1 + rng.below(3),
            ..common::sim_config()
        };
        let env = common::sim_env(SimLatency::from_millis(2, 1, 1, 1));
        let ds = sim_dataset(3, 3);
        let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
        let queries = ds.sample_test(n_queries, rng.below(100) as u64);
        let serial = coord
            .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
            .unwrap();
        let serial_answers: Vec<String> =
            serial.results.iter().map(|r| r.predicted.clone()).collect();

        // drive the workers over an explicit pool so a live poller can
        // watch the budget invariant WHILE the streams race.
        let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
            Arc::new(SharedKvCache::new(cache));
        let done = AtomicBool::new(false);
        let retr = GRetriever::default();
        let reports: Vec<anyhow::Result<ServeReport>> = std::thread::scope(|scope| {
            let poller = scope.spawn(|| {
                let mut checks = 0u64;
                // Budget discipline itself is debug-asserted inside every
                // install (the install-point invariant); this polls the
                // anytime invariants while the streams race. Bounded so a
                // failing worker (which panics before setting `done`) can
                // never strand this thread in the scope join; at least one
                // check always runs even if the workers finish instantly.
                loop {
                    assert!(pool.consistent(),
                            "pool accounting went inconsistent under concurrency");
                    checks += 1;
                    if done.load(Ordering::Relaxed) || checks >= 10_000 {
                        break;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                checks
            });
            let workers: Vec<_> = (0..n_streams)
                .map(|_| {
                    let pool = Arc::clone(&pool);
                    let coord = &coord;
                    let ds = &ds;
                    let retr = &retr;
                    let queries = &queries;
                    scope.spawn(move || {
                        let mut view = KvCacheManager::shared_view(&pool);
                        coord.serve_online_with_cache(ds, queries.iter().copied(),
                                                      retr, &mut view)
                    })
                })
                .collect();
            let out: Vec<_> = workers
                .into_iter()
                .map(|h| h.join().expect("worker must not panic"))
                .collect();
            done.store(true, Ordering::Relaxed);
            assert!(poller.join().expect("poller must not panic") > 0);
            out
        });
        // quiescent: drain the pool back to the backend.
        env.backend.release_many(pool.drain_all());

        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut prefills = 0u64;
        let mut evictions = 0u64;
        for (si, rep) in reports.into_iter().enumerate() {
            let rep = rep.unwrap_or_else(|e| panic!("stream {si} failed: {e}"));
            assert_eq!(rep.metrics.per_query.len(), n_queries);
            assert_eq!(rep.metrics.hit_count() + rep.metrics.miss_count(), n_queries);
            let got: Vec<String> =
                rep.results.iter().map(|r| r.predicted.clone()).collect();
            assert_eq!(got, serial_answers,
                       "stream {si}: sharing changed an answer (pin-safety breach?)");
            hits += rep.cache.hits;
            misses += rep.cache.misses;
            prefills += rep.cache.prefills;
            evictions += rep.cache.evictions;
        }
        let pool_stats = pool.stats();
        assert_eq!(hits, pool_stats.hits, "hit counters must sum to the pool's");
        assert_eq!(misses, pool_stats.misses);
        assert_eq!(prefills, pool_stats.prefills);
        assert_eq!(evictions, pool_stats.evictions);
        assert_eq!(hits + misses, (n_streams * n_queries) as u64);
        assert_eq!(pool_stats.resident_bytes, 0, "pool drained");
        assert_eq!(env.backend.stats().unwrap().live_kv, 0, "no leaked KV");
        if !tight {
            assert_eq!(pool_stats.evictions, 0, "ample budget must not evict");
        }
    });
}

/// Raw multi-threaded hammer on the pool views (no backend): every handle
/// installed leaves the pool exactly once, across evictions, releases,
/// deferred (doomed) releases, and the final drain.
#[test]
fn hammer_handle_conservation_across_threads() {
    prop_check(3, |rng| {
        let n_threads = rng.range(2, 5);
        let policy = CachePolicy::new(usize::MAX, rng.range(1, 4));
        let pool: Arc<SharedKvCache<u64>> = Arc::new(SharedKvCache::new(policy));
        let keys: Vec<RepKey> =
            (0..6).map(|i| RepKey::of_parts(["hammer"], [i as u64])).collect();
        let returned: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        let installed: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        let seed_base = rng.below(1 << 30) as u64;

        std::thread::scope(|scope| {
            for t in 0..n_threads {
                let pool = Arc::clone(&pool);
                let keys = &keys;
                let returned = &returned;
                let installed = &installed;
                scope.spawn(move || {
                    let mut rng = subgcache::util::rng::Rng::new(seed_base + t as u64);
                    let mut view: KvCacheManager<u64> =
                        KvCacheManager::shared_view(&pool);
                    for (cid, &k) in keys.iter().enumerate() {
                        view.bind(cid, k);
                    }
                    let mut next: u64 = ((t as u64) << 32) + 1;
                    for _ in 0..120 {
                        let cid = rng.below(keys.len());
                        match rng.below(4) {
                            // serve-shaped: lookup, install on miss, unpin.
                            0 | 1 => {
                                if view.lookup(cid).is_hit() {
                                    view.unpin(cid);
                                } else {
                                    let h = next;
                                    next += 1;
                                    installed.lock().unwrap().push(h);
                                    let out = view.install(cid, h, 10);
                                    returned.lock().unwrap().extend(out);
                                    view.unpin(cid);
                                }
                            }
                            // TTL-shaped: release (possibly deferring past
                            // another thread's pin).
                            2 => {
                                let out = view.release(cid);
                                returned.lock().unwrap().extend(out);
                            }
                            // reservation churn: miss then abort.
                            _ => {
                                if view.lookup(cid).is_hit() {
                                    view.unpin(cid);
                                } else {
                                    view.abort_install(cid);
                                }
                            }
                        }
                    }
                    // end of stream: deferred handles drain through the view.
                    let out = view.release_all();
                    returned.lock().unwrap().extend(out);
                });
            }
        });
        // quiescent: whatever is still resident (or deferred) drains once.
        returned.lock().unwrap().extend(pool.drain_all());

        let mut got = returned.into_inner().unwrap();
        let mut want = installed.into_inner().unwrap();
        got.sort_unstable();
        want.sort_unstable();
        let dups: Vec<&u64> = got.windows(2).filter(|w| w[0] == w[1]).map(|w| &w[0])
            .collect();
        assert!(dups.is_empty(), "handles returned twice: {dups:?}");
        assert_eq!(got, want, "installed and returned handle sets must match");
        assert_eq!(pool.stats().resident_bytes, 0);
        assert!(pool.consistent());
    });
}

// ---------------------------------------------------------------------------
// Stress/regression: TTL vs foreign pins, dead lane, serial parity
// ---------------------------------------------------------------------------

/// A TTL-sweeping stream and a no-TTL stream hammer the same representative
/// pool: sweeps must never invalidate the other stream's in-flight pins
/// (the sim would error "unknown KV handle" on a freed entry), every
/// deferred release must still reach the backend, and answers stay
/// serial-identical on both streams.
#[test]
fn ttl_sweep_races_foreign_pins_without_corruption() {
    let env = common::sim_env(SimLatency::from_millis(1, 2, 1, 1));
    let ds = sim_dataset(2, 4);
    let queries = ds.sample_test(12, 3);
    let retr = GRetriever::default();
    let base = ServeConfig { online_threshold: f32::INFINITY, ..common::sim_config() };
    let sweeper_cfg = ServeConfig { cluster_ttl: Some(0), ..base.clone() };
    let keeper_cfg = base.clone();

    let serial = {
        let coord = Coordinator::new(&env.store, &env.backend, keeper_cfg.clone()).unwrap();
        coord.serve_online(&ds, queries.iter().copied(), &retr).unwrap()
    };
    let serial_answers: Vec<String> =
        serial.results.iter().map(|r| r.predicted.clone()).collect();

    let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
        Arc::new(SharedKvCache::new(base.cache));
    let (sweeper, keeper) = std::thread::scope(|scope| {
        let sweeper = {
            let pool = Arc::clone(&pool);
            let (env, ds, retr, queries, cfg) = (&env, &ds, &retr, &queries, &sweeper_cfg);
            scope.spawn(move || {
                let coord = Coordinator::new(&env.store, &env.backend, cfg.clone()).unwrap();
                let mut view = KvCacheManager::shared_view(&pool);
                coord.serve_online_with_cache(ds, queries.iter().copied(), retr, &mut view)
            })
        };
        let keeper = {
            let pool = Arc::clone(&pool);
            let (env, ds, retr, queries, cfg) = (&env, &ds, &retr, &queries, &keeper_cfg);
            scope.spawn(move || {
                let coord = Coordinator::new(&env.store, &env.backend, cfg.clone()).unwrap();
                let mut view = KvCacheManager::shared_view(&pool);
                coord.serve_online_with_cache(ds, queries.iter().copied(), retr, &mut view)
            })
        };
        (sweeper.join().expect("sweeper must not panic"),
         keeper.join().expect("keeper must not panic"))
    });
    env.backend.release_many(pool.drain_all());

    let sweeper = sweeper.expect("TTL stream must serve cleanly under contention");
    let keeper = keeper.expect("no-TTL stream must serve cleanly under contention");
    for (name, rep) in [("sweeper", &sweeper), ("keeper", &keeper)] {
        let got: Vec<String> = rep.results.iter().map(|r| r.predicted.clone()).collect();
        assert_eq!(got, serial_answers, "{name} diverged under TTL contention");
        assert_eq!(rep.metrics.per_query.len(), queries.len());
    }
    assert_eq!(env.backend.stats().unwrap().live_kv, 0,
               "every handle (including deferred TTL releases) must drain");
}

/// An LLM lane killed MID-run must surface an error on every stream —
/// never hang any of them (the single-flight waiters are woken by the
/// failing installer's reservation abort).
#[test]
fn dead_llm_lane_mid_run_errors_every_stream() {
    let env = common::sim_env(SimLatency::from_millis(25, 2, 2, 1));
    let ds = sim_dataset(3, 4);
    // long streams so the kill lands mid-serving, not after.
    let base = ds.sample_test(6, 5);
    let mut long: Vec<&Query> = Vec::new();
    for _ in 0..4 {
        long.extend(base.iter().copied());
    }
    let cfg = ServeConfig { online_threshold: f32::INFINITY, ..common::sim_config() };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
        Arc::new(SharedKvCache::new(CachePolicy::default()));
    let retr = GRetriever::default();

    let results: Vec<anyhow::Result<ServeReport>> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let (coord, ds, retr, long) = (&coord, &ds, &retr, &long);
                scope.spawn(move || {
                    let mut view = KvCacheManager::shared_view(&pool);
                    coord.serve_online_with_cache(ds, long.iter().copied(), retr,
                                                  &mut view)
                })
            })
            .collect();
        // let the streams get going, then kill the LLM lane under them.
        std::thread::sleep(Duration::from_millis(40));
        env.backend.kill_lane_for_test(Lane::Llm);
        workers
            .into_iter()
            .map(|h| h.join().expect("stream must error, not panic"))
            .collect()
    });
    pool.drain_all(); // sim lane is gone; just empty the bookkeeping

    for (si, r) in results.iter().enumerate() {
        let err = r.as_ref().expect_err(&format!("stream {si} must surface an error"));
        assert!(err.to_string().contains("lane"),
                "stream {si}: unhelpful dead-lane error: {err}");
    }

    // serve_online_multi over the same dead backend also errors (fast),
    // reporting how many streams failed.
    let streams = replicated_streams(&base, 2);
    let err = coord
        .serve_online_multi(&ds, &streams, &retr)
        .expect_err("multi over a dead lane must error, not hang");
    assert!(err.to_string().contains("lane"), "unhelpful error: {err}");
}

/// A shed install leader must abort its reservation so blocked
/// single-flight waiters wake (the overload-plane analogue of the
/// dead-lane wake above): with a one-slot fail-fast LLM queue held full by
/// two long foreign prefills, every install leader's prefill submit is
/// terminally `Overloaded` and — with `overload.shed` on — sheds the query
/// instead of erroring the stream. The racing stream blocked in the
/// single-flight lookup must wake on the leader's `abort_install`, elect
/// itself the new installer, and shed in turn; the test completing at all
/// is the no-stranded-condvar-waiter proof, and the pool must stay
/// consistent with nothing leaked.
#[test]
fn shed_leader_aborts_reservation_and_wakes_single_flight_waiters() {
    // prefill dominates: the two occupier prefills hold the one-slot LLM
    // queue full (one executing with its slot released at pickup, one
    // queued holding the slot) for ~400 ms — far longer than the streams
    // need to run their submit-shed races.
    let lat = SimLatency::from_millis(400, 1, 1, 1);
    let store = subgcache::runtime::sim_store();
    let backend = SimBackend::start_guarded(
        &store, lat, BatchConfig::off(), FaultPlan::none(),
        SupervisorPolicy::default(), QueueConfig::reject(1), None)
        .expect("guarded sim backend start");
    let ds = sim_dataset(4, 4);
    let sample = ds.sample_test(4, 7);
    // the same query three times per stream: three install races, each
    // abort re-arming the single-flight reservation for the next turn.
    let queries = vec![sample[0], sample[0], sample[0]];
    let cfg = ServeConfig {
        online_threshold: f32::INFINITY,
        pipeline_depth: 1,
        max_retries: 2,
        overload: OverloadConfig { shed: true, ..OverloadConfig::default() },
        ..common::sim_config()
    };
    let coord = Coordinator::new(&store, &backend, cfg).unwrap();

    // occupy the LLM lane: the first prefill is picked up (slot released),
    // the second sits in the channel holding the single queue slot.
    let bb = subgcache::runtime::SIM_BACKBONE;
    let occ1 = backend.submit_prefill(bb, &[1, 2, 3, 4], 4).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // let the worker take occ1
    let occ2 = backend.submit_prefill(bb, &[1, 2, 3, 4], 4).unwrap();

    let pool: Arc<SharedKvCache<subgcache::runtime::KvHandle>> =
        Arc::new(SharedKvCache::new(CachePolicy::default()));
    let retr = GRetriever::default();
    let reports: Vec<anyhow::Result<ServeReport>> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let (coord, ds, retr, queries) = (&coord, &ds, &retr, &queries);
                scope.spawn(move || {
                    let mut view = KvCacheManager::shared_view(&pool);
                    coord.serve_online_with_cache(ds, queries.iter().copied(), retr,
                                                  &mut view)
                })
            })
            .collect();
        workers
            .into_iter()
            .map(|h| h.join().expect("stream must shed, not panic"))
            .collect()
    });

    let mut shed_overloaded = 0u64;
    for (si, rep) in reports.into_iter().enumerate() {
        // reaching here at all proves neither stream stranded on the
        // single-flight condvar: the leader's shed aborted its reservation.
        let rep = rep.unwrap_or_else(|e| {
            panic!("stream {si}: terminal overload must shed, not error: {e}")
        });
        let shed = rep.metrics.reliability.shed;
        assert_eq!(rep.outcomes.len(), queries.len(),
                   "stream {si}: every arrival gets an outcome");
        assert_eq!(shed.offered(), queries.len() as u64, "stream {si}");
        assert_eq!(rep.results.len(), shed.admitted as usize,
                   "stream {si}: served results must match admissions");
        assert!(shed.shed_overloaded >= 1,
                "stream {si}: the full queue must shed at least the first \
                 query: {shed:?}");
        for out in &rep.outcomes {
            if let QueryOutcome::Shed { reason, .. } = out {
                assert!(matches!(reason, ShedReason::Overloaded),
                        "stream {si}: only overload sheds expected: {out:?}");
            }
        }
        assert!(rep.metrics.lane_llm.depth_peak >= 1,
                "stream {si}: the held queue slot must show on the gauge");
        shed_overloaded += shed.shed_overloaded;
    }
    assert!(shed_overloaded >= 2,
            "both streams must have shed under the held queue");

    // nothing installed may linger, and the pool books must balance.
    assert!(pool.consistent(), "pool accounting inconsistent after sheds");
    backend.release_many(pool.drain_all());

    // the occupiers finish and drain: no handle leaks from the whole dance.
    let (kv1, _) = occ1.wait().expect("occupier prefill 1");
    backend.release(kv1);
    let (kv2, _) = occ2.wait().expect("occupier prefill 2");
    backend.release(kv2);
    assert_eq!(backend.stats().unwrap().live_kv, 0, "leaked KV handles");
}

/// Single-stream serving through the shared-cache machinery must be
/// metric-for-metric identical to the serial PR 3 path, for k in {1,2,4}.
///
/// Two legs per depth:
/// * default threshold — answers, arrival order, and clustering must be
///   identical (cluster assignment never depends on which pool backs the
///   cache);
/// * infinite threshold (one cluster, the unambiguous-content case) —
///   additionally the full hit/miss split and every cache counter must be
///   equal. (At finite thresholds a shared view's content keying may
///   legitimately dedup a drift-duplicated representative that the serial
///   salted keying re-prefills — strictly fewer prefills, not comparable
///   counter-for-counter.)
#[test]
fn single_stream_through_shared_pool_matches_serial_metrics() {
    for depth in [1usize, 2, 4] {
        for strict in [false, true] {
            let lat = SimLatency::from_millis(3, 1, 1, 2);
            let run_env = common::sim_env(lat);
            let ds = sim_dataset(4, 3);
            let cfg = ServeConfig {
                pipeline_depth: depth,
                online_threshold: if strict { f32::INFINITY } else { 0.5 },
                ..common::sim_config()
            };
            let coord = Coordinator::new(&run_env.store, &run_env.backend, cfg).unwrap();
            let queries = ds.sample_test(9, 3);
            let retr = GRetriever::default();

            let serial = coord.serve_online(&ds, queries.iter().copied(), &retr).unwrap();
            let streams = replicated_streams(&queries, 1);
            let multi = coord.serve_online_multi(&ds, &streams, &retr).unwrap();
            assert_eq!(multi.streams.len(), 1);
            let shared = &multi.streams[0];

            assert_eq!(serial.results.len(), shared.results.len());
            for (a, b) in serial.results.iter().zip(&shared.results) {
                assert_eq!(a.id, b.id, "k={depth}: arrival order diverged");
                assert_eq!(a.predicted, b.predicted, "k={depth}: answer diverged");
                assert_eq!(a.cluster, b.cluster, "k={depth}: clustering diverged");
            }
            assert_eq!(serial.cluster_sizes, shared.cluster_sizes);
            assert_eq!(serial.expired_clusters, shared.expired_clusters);
            assert_eq!(shared.cache.shared_hits, 0,
                       "a lone stream can have nothing shared with it");
            if strict {
                assert_eq!(serial.metrics.hit_count(), shared.metrics.hit_count(),
                           "k={depth}");
                assert_eq!(serial.metrics.miss_count(), shared.metrics.miss_count());
                assert_eq!(serial.cache.prefills, shared.cache.prefills);
                assert_eq!(serial.cache.hits, shared.cache.hits);
                assert_eq!(serial.cache.misses, shared.cache.misses);
                assert_eq!(serial.cache.evictions, shared.cache.evictions);
            } else {
                // content keying can only ever SAVE prefills.
                assert!(shared.cache.prefills <= serial.cache.prefills,
                        "k={depth}: shared pool must never prefill more");
            }
            assert_eq!(run_env.backend.stats().unwrap().live_kv, 0);
        }
    }
}
