//! Continuous micro-batching suite for the LLM lane, driven on the
//! deterministic [`SimBackend`] so every scenario runs un-skipped in plain
//! `cargo test`.
//!
//! What is pinned down here:
//!
//! * **Lone member** — a window that fires with a single request pays no
//!   fusion penalty: device cost is the op's base latency, the stall is
//!   recorded, and the answer is bit-identical to an unbatched backend.
//! * **Fusion** — compatible extends share ONE device launch (one leader,
//!   shared device span, occupancy 3) that beats executing them serially.
//! * **Compatibility** — different op kinds never fuse; the incompatible
//!   arrival closes the window early (no stall) and runs right after.
//! * **Failure** — an LLM lane killed while a batch window is open errors
//!   every member's ticket instead of hanging any of them.
//! * **Property** — 4 batched streams finish strictly faster than 4
//!   unbatched streams over the same workload, with bit-identical
//!   per-query answers and leader-only device accounting that fits inside
//!   the wall clock.

use std::time::Duration;

use subgcache::data::Query;
use subgcache::prelude::*;
use subgcache::runtime::{sim_dataset, SimLatency, SIM_BACKBONE};

mod common;

/// Padded prefix tokens (length `max_seq`) carrying `n` distinct real ids.
fn prefix_tokens(c: &subgcache::runtime::Constants, n: usize) -> (Vec<i32>, i32) {
    let mut toks = vec![c.pad_id; c.max_seq];
    for (i, t) in toks.iter_mut().take(n).enumerate() {
        *t = 5 + i as i32;
    }
    (toks, n as i32)
}

/// Padded question tokens (length `max_q`) distinct per `salt`.
fn question_tokens(c: &subgcache::runtime::Constants, salt: i32, n: usize)
                   -> (Vec<i32>, i32) {
    let mut q = vec![c.pad_id; c.max_q];
    for (i, t) in q.iter_mut().take(n).enumerate() {
        *t = 40 + salt * 16 + i as i32;
    }
    (q, n as i32)
}

#[test]
fn single_request_window_fires_without_fusion_penalty() {
    let lat = SimLatency::from_millis(0, 5, 0, 0);
    let cfg = BatchConfig::new(4, Duration::from_millis(30));
    let env = common::sim_env_batched(lat, cfg);
    let c = *env.store.constants();
    let (toks, plen) = prefix_tokens(&c, 8);
    let (q, qlen) = question_tokens(&c, 0, 4);

    let (kv, _) = env.backend.prefill(SIM_BACKBONE, &toks, plen).unwrap();
    let (kv2, logits, t) = env
        .backend
        .submit_extend(SIM_BACKBONE, &kv, plen, &q, qlen)
        .unwrap()
        .wait_timed()
        .unwrap();
    assert_eq!(t.batch.size, 1, "nothing else was queued to fuse with");
    assert!(t.batch.leader);
    assert!(t.batch.stalled, "an expired window is a stall");
    assert!(t.window_secs >= 0.02,
            "the 30 ms window must show up as window time, got {:.4}s", t.window_secs);
    assert!(t.device_secs < 0.025,
            "a lone member pays the base latency only (no per-item penalty), \
             got {:.4}s", t.device_secs);

    // the stalled-out window must not have changed the answer
    let unbatched = common::sim_env(lat);
    let (ukv, _) = unbatched.backend.prefill(SIM_BACKBONE, &toks, plen).unwrap();
    let (ukv2, ulogits) = unbatched.backend.extend(SIM_BACKBONE, &ukv, plen, &q, qlen)
        .unwrap();
    assert_eq!(logits, ulogits, "batched path must be bit-identical to unbatched");
    env.backend.release_many(vec![kv, kv2]);
    unbatched.backend.release_many(vec![ukv, ukv2]);
}

#[test]
fn compatible_extends_fuse_into_one_device_call() {
    let lat = SimLatency::from_millis(0, 6, 0, 0).with_per_item_millis(0, 1, 0, 0);
    let cfg = BatchConfig::new(3, Duration::from_millis(100));
    let env = common::sim_env_batched(lat, cfg);
    let c = *env.store.constants();
    let (toks, plen) = prefix_tokens(&c, 8);
    let (kv, _) = env.backend.prefill(SIM_BACKBONE, &toks, plen).unwrap();

    let questions: Vec<(Vec<i32>, i32)> =
        (0..3).map(|s| question_tokens(&c, s, 4)).collect();
    let pending: Vec<_> = questions
        .iter()
        .map(|(q, qlen)| {
            env.backend.submit_extend(SIM_BACKBONE, &kv, plen, q, *qlen).unwrap()
        })
        .collect();
    let done: Vec<_> = pending.into_iter().map(|p| p.wait_timed().unwrap()).collect();

    let timings: Vec<_> = done.iter().map(|(_, _, t)| *t).collect();
    for t in &timings {
        assert_eq!(t.batch.size, 3, "all three extends must ride one launch");
        assert!(!t.batch.stalled, "a full batch is not a stall");
        assert!(t.window_secs < 0.05, "the window closed on fill, not expiry");
        assert_eq!(t.device_secs, timings[0].device_secs,
                   "every member reports the batch's shared device span");
    }
    assert_eq!(timings.iter().filter(|t| t.batch.leader).count(), 1,
               "exactly one leader per launch");
    // fused cost: base + per_item * 2 = 8 ms — well under 3 serial extends.
    assert!(timings[0].device_secs >= 0.008 - 1e-4);
    assert!(timings[0].device_secs < 0.016,
            "fused call must beat 3 serial extends (18 ms), got {:.4}s",
            timings[0].device_secs);

    // fused results match the unbatched backend member-for-member
    let unbatched = common::sim_env(lat);
    let (ukv, _) = unbatched.backend.prefill(SIM_BACKBONE, &toks, plen).unwrap();
    let mut env_kvs = vec![kv];
    for ((q, qlen), (bkv, blogits, _)) in questions.iter().zip(done) {
        let (uk, ulogits) = unbatched.backend
            .extend(SIM_BACKBONE, &ukv, plen, q, *qlen).unwrap();
        assert_eq!(blogits, ulogits, "fusion must not cross-contaminate members");
        unbatched.backend.release(uk);
        env_kvs.push(bkv);
    }
    unbatched.backend.release(ukv);
    // the launch counted once: 3 member calls, ~one 8 ms device span
    let st = env.backend.stats().unwrap();
    let extend = st.calls.iter().find(|(k, _, _)| k.ends_with(".extend")).unwrap();
    assert_eq!(extend.1, 3, "all members counted as calls");
    assert!(extend.2 < 0.02,
            "device seconds counted once per launch, got {:.4}s", extend.2);
    assert_eq!(st.unbatched_fallbacks, 0, "the sim fuses everything");
    env.backend.release_many(env_kvs);
}

#[test]
fn incompatible_ops_never_fuse() {
    let lat = SimLatency::from_millis(0, 4, 4, 0);
    let cfg = BatchConfig::new(4, Duration::from_millis(50));
    let env = common::sim_env_batched(lat, cfg);
    let c = *env.store.constants();
    let (toks, plen) = prefix_tokens(&c, 8);
    let (q, qlen) = question_tokens(&c, 0, 4);
    let (kv, _) = env.backend.prefill(SIM_BACKBONE, &toks, plen).unwrap();

    // extend opens a window; the generate arriving inside it is a
    // different op kind and must close the window instead of joining.
    let e = env.backend.submit_extend(SIM_BACKBONE, &kv, plen, &q, qlen).unwrap();
    let g = env.backend.submit_generate(SIM_BACKBONE, &kv, plen, 5).unwrap();
    let (ekv, _, te) = e.wait_timed().unwrap();
    let (gen_toks, tg) = g.wait_timed().unwrap();

    assert_eq!(te.batch.size, 1, "extend must not have fused with the generate");
    assert!(!te.batch.stalled,
            "window closed by the incompatible arrival, not by expiry");
    assert!(te.window_secs < 0.04, "incompatible arrival closes the window early");
    assert_eq!(tg.batch.size, 1);
    assert!(tg.batch.stalled, "the carried generate then stalls out its own window");
    assert!(!gen_toks.is_empty(), "the carried request still executed (FIFO held)");
    env.backend.release_many(vec![kv, ekv]);
}

#[test]
fn dead_llm_lane_mid_batch_errors_every_member() {
    let lat = SimLatency::zero();
    let cfg = BatchConfig::new(8, Duration::from_millis(100));
    let env = common::sim_env_batched(lat, cfg);
    let c = *env.store.constants();
    let (toks, plen) = prefix_tokens(&c, 8);
    let (kv, _) = env.backend.prefill(SIM_BACKBONE, &toks, plen).unwrap();

    // three extends enter an open window (3 < max_batch, so the worker
    // keeps the window open waiting for more); the lane dies mid-window.
    let pending: Vec<_> = (0..3)
        .map(|s| {
            let (q, qlen) = question_tokens(&c, s, 4);
            env.backend.submit_extend(SIM_BACKBONE, &kv, plen, &q, qlen).unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    env.backend.kill_lane_for_test(Lane::Llm);

    for (i, p) in pending.into_iter().enumerate() {
        let err = p.wait().expect_err(&format!("member {i} must error, not hang"));
        assert!(err.to_string().contains("lane"),
                "member {i}: unhelpful dead-lane error: {err}");
    }
    // and the dead lane rejects new submissions at the send
    assert!(env.backend.submit_prefill(SIM_BACKBONE, &toks, plen).is_err());
}

/// The acceptance criterion: at 4 streams, the batched backend's wall clock
/// is strictly below the unbatched backend's on the same workload, per-query
/// answers are bit-identical, and leader-only device attribution keeps the
/// fleet's summed LLM device seconds inside the wall clock.
#[test]
fn batched_multi_stream_wall_beats_unbatched_with_identical_answers() {
    let lat = SimLatency::from_millis(6, 4, 2, 1).with_per_item_millis(2, 1, 1, 1);
    let n_streams = 4;
    let serve = |bcfg: BatchConfig| {
        let env = common::sim_env_batched(lat, bcfg);
        let ds = sim_dataset(4, 4);
        let cfg = ServeConfig { online_threshold: f32::INFINITY, ..common::sim_config() };
        let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
        let queries = ds.sample_test(8, 7);
        let streams: Vec<Vec<&Query>> =
            (0..n_streams).map(|_| queries.clone()).collect();
        let multi = coord
            .serve_online_multi(&ds, &streams, &GRetriever::default())
            .unwrap();
        assert_eq!(multi.streams.len(), n_streams);
        let answers: Vec<Vec<String>> = multi
            .streams
            .iter()
            .map(|r| r.results.iter().map(|x| x.predicted.clone()).collect())
            .collect();
        let device: f64 = multi.streams.iter()
            .map(|r| r.metrics.lane_llm.device_time).sum();
        let fused: u64 = multi.streams.iter()
            .map(|r| r.metrics.lane_llm.batch.fused_calls).sum();
        assert_eq!(env.backend.stats().unwrap().live_kv, 0, "leaked KV handles");
        (multi.wall_time, answers, device, fused)
    };

    let (wall_off, ans_off, dev_off, fused_off) = serve(BatchConfig::off());
    let (wall_on, ans_on, dev_on, fused_on) =
        serve(BatchConfig::new(4, Duration::from_millis(4)));

    assert_eq!(fused_off, 0, "batching off must never fuse");
    assert!(fused_on > 0, "4 concurrent streams must fuse at least one call");
    assert_eq!(ans_on, ans_off,
               "fusion must not change any stream's answers, bit for bit");
    assert!(
        wall_on < wall_off,
        "batched fleet must finish strictly faster: batched {wall_on:.3}s vs \
         unbatched {wall_off:.3}s"
    );
    // leader-only counting: one lane cannot have been busy longer than the
    // run took, whether fused or not.
    assert!(dev_on <= wall_on + 0.02,
            "summed LLM device time {dev_on:.3}s exceeds wall {wall_on:.3}s — \
             a fused launch was double-counted");
    assert!(dev_off <= wall_off + 0.02,
            "unbatched device accounting inconsistent: {dev_off:.3}s vs wall \
             {wall_off:.3}s");
}
