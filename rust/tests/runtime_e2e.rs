//! Runtime end-to-end tests: the Rust PJRT engine executing the AOT HLO must
//! reproduce the Python (jax + interpret-Pallas) semantics, including the
//! SubGCache cache-consistency core. Pinned by `artifacts/golden/llm_*.json`.

use subgcache::coordinator::argmax;
use subgcache::runtime::{ArtifactStore, Engine};

const BACKBONE: &str = "llama-3.2-3b-sim";

mod common;

fn store() -> Option<ArtifactStore> {
    common::store("runtime e2e test")
}

fn ivec(v: &subgcache::util::json::Json, key: &str) -> Vec<i32> {
    v.get(key).as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect()
}

fn with_engine<T>(f: impl FnOnce(&ArtifactStore, &Engine) -> T) -> Option<T> {
    common::with_engine("runtime e2e test", f)
}

#[test]
fn split_path_matches_python_golden() {
    with_engine(|store, engine| {
        let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
        let prefix_tokens = ivec(&g, "prefix_tokens");
        let plen = g.get("prefix_len").as_i64().unwrap() as i32;
        let q_tokens = ivec(&g, "q_tokens");
        let qlen = g.get("q_len").as_i64().unwrap() as usize;
        let vocab = store.constants().vocab;

        let (kv, _) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
        // the engine now returns only the [V] row after the last real
        // question token (selected on the engine side from qlen).
        let (kv2, row) = engine.extend(BACKBONE, &kv, plen, &q_tokens,
                                       qlen as i32).unwrap();
        assert_eq!(row.len(), vocab, "extend must return a single [V] row");

        // logits row prefix must match python's to float tolerance
        let want_row: Vec<f64> = g.get("extend_logits_row").as_arr().unwrap()
            .iter().map(|x| x.as_f64().unwrap()).collect();
        for (i, w) in want_row.iter().enumerate() {
            assert!((row[i] as f64 - w).abs() < 1e-2,
                    "logit {i}: {} vs python {w}", row[i]);
        }

        let first = argmax(&row);
        assert_eq!(first as i64, g.get("first_token").as_i64().unwrap());

        let gen = engine.generate(BACKBONE, &kv2, plen + qlen as i32, first).unwrap();
        assert_eq!(gen, ivec(&g, "generated"), "generated tokens diverge from python");
        let text = store.tokenizer().decode(&gen);
        assert_eq!(text, g.get("answer_text").as_str().unwrap());

        engine.release(kv2);
        engine.release(kv);
    });
}

#[test]
fn baseline_path_matches_python_golden() {
    with_engine(|store, engine| {
        let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
        let tokens = ivec(&g, "baseline_tokens");
        let flen = g.get("baseline_len").as_i64().unwrap() as i32;
        let (kv, logits) = engine.prefill(BACKBONE, &tokens, flen).unwrap();
        let first = argmax(&logits);
        assert_eq!(first as i64, g.get("baseline_first_token").as_i64().unwrap());
        let gen = engine.generate(BACKBONE, &kv, flen, first).unwrap();
        assert_eq!(gen, ivec(&g, "baseline_generated"));
        engine.release(kv);
    });
}

#[test]
fn cached_prefix_is_reusable_across_queries() {
    // The SubGCache property at engine level: extending the SAME prefix KV
    // with different questions must not interfere.
    with_engine(|store, engine| {
        let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
        let prefix_tokens = ivec(&g, "prefix_tokens");
        let plen = g.get("prefix_len").as_i64().unwrap() as i32;
        let q_tokens = ivec(&g, "q_tokens");
        let qlen = g.get("q_len").as_i64().unwrap() as i32;

        let (kv, _) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
        let (kv_a, logits_a) = engine.extend(BACKBONE, &kv, plen, &q_tokens, qlen).unwrap();
        // a different question against the same cache
        let mut other = q_tokens.clone();
        other.swap(3, 5);
        let (kv_b, logits_b) = engine.extend(BACKBONE, &kv, plen, &other, qlen).unwrap();
        assert_ne!(logits_a, logits_b);
        // and the original question again: bitwise identical to the first hit
        let (kv_c, logits_c) = engine.extend(BACKBONE, &kv, plen, &q_tokens, qlen).unwrap();
        assert_eq!(logits_a, logits_c, "cache reuse must be deterministic");
        for h in [kv_a, kv_b, kv_c, kv] {
            engine.release(h);
        }
    });
}

#[test]
fn release_invalidates_handle() {
    with_engine(|store, engine| {
        let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
        let prefix_tokens = ivec(&g, "prefix_tokens");
        let plen = g.get("prefix_len").as_i64().unwrap() as i32;
        let (kv, _) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
        let q = vec![store.constants().pad_id; store.constants().max_q];
        engine.release(kv);
        // handle ids are unique; a stale one must error, not alias
        let stale = {
            // fabricate by prefilling + releasing again, then using the old id
            let (kv2, _) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
            let err = engine.extend(BACKBONE, &kv2, plen, &q[..1], 1);
            assert!(err.is_err(), "wrong-length q_tokens must be rejected");
            kv2
        };
        engine.release(stale);
    });
}

#[test]
fn gnn_encoders_run_and_discriminate() {
    with_engine(|store, engine| {
        let c = store.constants();
        let ds = store.dataset("scene_graph").unwrap();
        let feats = subgcache::retrieval::GraphFeatures::build(&ds.graph);
        let sg1 = subgcache::graph::Subgraph::from_parts([0, 1, 2], [0]);
        let sg2 = subgcache::graph::Subgraph::from_parts([10, 11, 12], []);
        for gnn in ["graph_transformer", "gat"] {
            let p1 = subgcache::runtime::pack_subgraph(&ds.graph, &feats, &sg1,
                                                       c.n_max, c.feat_dim);
            let p2 = subgcache::runtime::pack_subgraph(&ds.graph, &feats, &sg2,
                                                       c.n_max, c.feat_dim);
            let e1 = engine.encode(gnn, p1.x, p1.adj, p1.mask).unwrap();
            let e2 = engine.encode(gnn, p2.x, p2.adj, p2.mask).unwrap();
            assert_eq!(e1.len(), c.gnn_emb);
            assert!(e1.iter().all(|v| v.is_finite()));
            let diff: f32 = e1.iter().zip(&e2).map(|(a, b)| (a - b).abs()).sum();
            assert!(diff > 1e-4, "{gnn}: different subgraphs must embed differently");
            // determinism
            let ds2 = store.dataset("scene_graph").unwrap();
            let feats2 = subgcache::retrieval::GraphFeatures::build(&ds2.graph);
            let p1b = subgcache::runtime::pack_subgraph(&ds2.graph, &feats2, &sg1,
                                                        c.n_max, c.feat_dim);
            let e1b = engine.encode(gnn, p1b.x, p1b.adj, p1b.mask).unwrap();
            assert_eq!(e1, e1b, "{gnn}: encode must be deterministic");
        }
    });
}

#[test]
fn engine_stats_track_calls() {
    with_engine(|store, engine| {
        let before: u64 = engine.stats().unwrap().calls.iter()
            .filter(|(k, _, _)| k.starts_with(BACKBONE))
            .map(|&(_, n, _)| n).sum();
        let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
        let prefix_tokens = ivec(&g, "prefix_tokens");
        let (kv, _) = engine.prefill(BACKBONE, &prefix_tokens, 5).unwrap();
        engine.release(kv);
        let after: u64 = engine.stats().unwrap().calls.iter()
            .filter(|(k, _, _)| k.starts_with(BACKBONE))
            .map(|&(_, n, _)| n).sum();
        assert_eq!(after, before + 1);
    });
}

#[test]
fn release_many_returns_all_handles() {
    with_engine(|store, engine| {
        let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
        let prefix_tokens = ivec(&g, "prefix_tokens");
        let plen = g.get("prefix_len").as_i64().unwrap() as i32;
        let live_before = engine.stats().unwrap().live_kv;
        let mut handles = Vec::new();
        for _ in 0..3 {
            let (kv, _) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
            handles.push(kv);
        }
        assert_eq!(engine.stats().unwrap().live_kv, live_before + 3);
        engine.release_many(handles);
        assert_eq!(engine.stats().unwrap().live_kv, live_before,
                   "release_many must drop every handle");
        engine.release_many(Vec::new()); // empty batch is a no-op
        assert_eq!(engine.stats().unwrap().live_kv, live_before);
    });
}

#[test]
fn kv_bytes_sized_from_manifest() {
    let Some(store) = store() else { return };
    let engine = Engine::start(&store).expect("engine start");
    for name in store.manifest().llm_names() {
        let dims = store.manifest().module(name).unwrap().dims.unwrap();
        assert_eq!(engine.kv_bytes(name).unwrap(), 2 * dims.kv_bytes_each(),
                   "{name}: k + v buffers");
    }
    for name in store.manifest().gnn_names() {
        assert!(engine.kv_bytes(name).is_err(), "{name}: GNNs have no KV geometry");
    }
    assert!(engine.kv_bytes("no-such-module").is_err());
}
