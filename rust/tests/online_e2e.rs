//! Online (streaming) SubGCache end-to-end tests: queries arrive one at a
//! time, clusters form on the fly, and warm representative KV caches are
//! reused across the stream.
//!
//! Each scenario is written once against the `Backend` trait and runs in
//! two flavors: on the deterministic [`SimBackend`] (always — fresh clone,
//! CI), and on the real PJRT engine over `artifacts/` (the `*_artifacts`
//! variants, which self-skip with a message when artifacts are absent).

use subgcache::coordinator::{Coordinator, ServeConfig};
use subgcache::data::Dataset;
use subgcache::prelude::*;
use subgcache::runtime::SimLatency;

mod common;

fn with_engine<T>(f: impl FnOnce(&ArtifactStore, &Engine) -> T) -> Option<T> {
    common::with_engine("online e2e test", f)
}

// ---------------------------------------------------------------------------
// Scenarios (backend-generic)
// ---------------------------------------------------------------------------

/// A negative threshold never joins: every query opens its own cluster
/// whose representative IS its own retrieved subgraph, so the online path
/// degenerates to per-query prefix + extend — which must predict exactly
/// what the baseline's full prompt predicts (greedy decoding).
fn check_singleton_parity(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                          base_cfg: &ServeConfig) {
    let queries = ds.sample_test(6, 3);
    let cfg = ServeConfig { online_threshold: -1.0, ..base_cfg.clone() };
    let coord = Coordinator::new(store, backend, cfg).unwrap();
    let r = GRetriever::default();
    let base = coord.serve_baseline(ds, &queries, &r).unwrap();
    let ours = coord.serve_online(ds, queries.iter().copied(), &r).unwrap();
    assert_eq!(ours.cluster_sizes.len(), queries.len());
    assert_eq!(ours.metrics.miss_count(), queries.len(), "never-join = all misses");
    assert_eq!(ours.metrics.hit_count(), 0);
    for (b, o) in base.results.iter().zip(&ours.results) {
        assert_eq!(b.id, o.id);
        assert_eq!(b.predicted, o.predicted,
                   "q{}: baseline {:?} vs online-singleton {:?}",
                   b.id, b.predicted, o.predicted);
    }
}

/// An infinite threshold funnels the whole stream into one cluster: the
/// first query prefills the representative, every later query must hit
/// the warm cache. Hit PFTT excludes the prefill, so the split must be
/// visible and ordered.
fn check_warm_hits_split_ttft(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                              base_cfg: &ServeConfig) {
    let queries = ds.sample_test(8, 11);
    let cfg = ServeConfig { online_threshold: f32::INFINITY, ..base_cfg.clone() };
    let coord = Coordinator::new(store, backend, cfg).unwrap();
    let r = GRetriever::default();
    let rep = coord.serve_online(ds, queries.iter().copied(), &r).unwrap();

    assert_eq!(rep.results.len(), queries.len());
    assert_eq!(rep.cluster_sizes, vec![queries.len()]);
    assert_eq!(rep.metrics.miss_count(), 1, "only the opener prefills");
    assert_eq!(rep.metrics.hit_count(), queries.len() - 1);
    assert_eq!(rep.cache.prefills, 1);
    assert_eq!(rep.cache.hits as usize, queries.len() - 1);
    assert!((rep.cache.hit_rate() - (queries.len() - 1) as f64
             / queries.len() as f64).abs() < 1e-9);
    // the headline asymmetry: a warm hit skips the representative
    // prefill entirely, so its PFTT (and TTFT) must come in under the
    // miss's.
    assert!(rep.metrics.pftt_hit_ms() < rep.metrics.pftt_miss_ms(),
            "hit PFTT {:.2} ms should undercut miss PFTT {:.2} ms",
            rep.metrics.pftt_hit_ms(), rep.metrics.pftt_miss_ms());
    assert!(rep.metrics.ttft_hit_ms() > 0.0 && rep.metrics.ttft_miss_ms() > 0.0);
    // per-query records carry the split
    for (i, q) in rep.metrics.per_query.iter().enumerate() {
        assert_eq!(q.cache_hit, Some(i > 0));
        assert!(q.pftt > 0.0 && q.ttft >= q.pftt && q.rt >= q.ttft);
    }
    // the scheduler reports its configured depth and lane usage
    assert_eq!(rep.metrics.pipeline_depth, base_cfg.pipeline_depth.max(1));
    assert_eq!(rep.metrics.lane_gnn.calls as usize, queries.len());
}

/// max_entries = 1 with singleton clusters: new clusters evict previous
/// representatives as soon as they are unpinned, so every query is a miss
/// and the backend gets every evicted handle back (no leaks). How long a
/// pin is held depends on the decode stage: at depth 1 the decode is waited
/// inline (the previous representative is already evictable when the next
/// install runs → N-1 evictions); at depth ≥ 2 the decode is decoupled and
/// the pin spans into the next turn, so the first install finds only
/// pinned entries and runs over budget once (→ N-2 evictions).
fn check_tight_budget_reprefill(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                                base_cfg: &ServeConfig) {
    let queries = ds.sample_test(5, 17);
    let cfg = ServeConfig {
        online_threshold: -1.0,
        cache: CachePolicy::single_resident(),
        ..base_cfg.clone()
    };
    let depth = cfg.pipeline_depth.max(1);
    let coord = Coordinator::new(store, backend, cfg).unwrap();
    let live_before = backend.stats().unwrap().live_kv;
    let rep = coord.serve_online(ds, queries.iter().copied(),
                                 &GRetriever::default()).unwrap();
    assert_eq!(rep.metrics.miss_count(), queries.len());
    assert_eq!(rep.cache.prefills as usize, queries.len());
    let expected_evictions = if depth >= 2 { queries.len() - 2 } else { queries.len() - 1 };
    assert_eq!(rep.cache.evictions as usize, expected_evictions,
               "depth {depth}: pinned in-flight entries must survive installs");
    assert_eq!(rep.cache.resident_bytes, 0, "cache must be drained");
    assert_eq!(backend.stats().unwrap().live_kv, live_before, "leaked KV handles");
}

fn check_report_complete(store: &ArtifactStore, backend: &dyn Backend, ds: &Dataset,
                         base_cfg: &ServeConfig) {
    let queries = ds.sample_test(10, 5);
    let coord = Coordinator::new(store, backend, base_cfg.clone()).unwrap();
    let rep = coord.serve_online(ds, queries.iter().copied(),
                                 &GragRetriever::default()).unwrap();
    assert_eq!(rep.results.len(), queries.len());
    assert_eq!(rep.metrics.per_query.len(), queries.len());
    for (r, q) in rep.results.iter().zip(&queries) {
        assert_eq!(r.id, q.id, "results must be in arrival order");
        assert_eq!(r.gold, q.answer);
    }
    assert_eq!(rep.cluster_sizes.iter().sum::<usize>(), queries.len());
    assert_eq!(rep.cluster_sizes.len(), rep.representative_sizes.len());
    assert_eq!(rep.metrics.hit_count() + rep.metrics.miss_count(), queries.len(),
               "every online query is either a hit or a miss");
    // misses == prefills == installs; the first member of every cluster
    // is necessarily a miss.
    assert!(rep.metrics.miss_count() >= rep.cluster_sizes.len());
    assert_eq!(rep.cache.prefills as usize, rep.metrics.miss_count());
    assert_eq!(rep.expired_clusters, 0, "no TTL configured, nothing may expire");
}

// ---------------------------------------------------------------------------
// Sim flavor (always runs)
// ---------------------------------------------------------------------------

#[test]
fn sim_online_singleton_clusters_match_baseline() {
    let env = common::sim_env(SimLatency::zero());
    check_singleton_parity(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_online_stream_hits_warm_cache_and_splits_ttft() {
    // prefill well above extend so the hit/miss asymmetry is unambiguous.
    let env = common::sim_env(SimLatency::from_millis(12, 2, 2, 2));
    check_warm_hits_split_ttft(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_online_eviction_under_tight_budget_forces_reprefill() {
    let env = common::sim_env(SimLatency::zero());
    check_tight_budget_reprefill(&env.store, &env.backend, &env.ds, &common::sim_config());
}

#[test]
fn sim_online_eviction_at_depth_1_matches_serial_pin_lifetime() {
    let env = common::sim_env(SimLatency::zero());
    let cfg = ServeConfig { pipeline_depth: 1, ..common::sim_config() };
    check_tight_budget_reprefill(&env.store, &env.backend, &env.ds, &cfg);
}

#[test]
fn sim_online_report_is_complete_and_ordered() {
    let env = common::sim_env(SimLatency::zero());
    check_report_complete(&env.store, &env.backend, &env.ds, &common::sim_config());
}

// ---------------------------------------------------------------------------
// Artifact flavor (opt-in by presence of artifacts/)
// ---------------------------------------------------------------------------

#[test]
fn online_singleton_clusters_match_baseline_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_singleton_parity(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn online_stream_hits_warm_cache_and_splits_ttft_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_warm_hits_split_ttft(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn online_eviction_under_tight_budget_forces_reprefill_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        check_tight_budget_reprefill(store, engine, &ds, &ServeConfig::default());
    });
}

#[test]
fn online_report_is_complete_and_ordered_artifacts() {
    with_engine(|store, engine| {
        let ds = store.dataset("oag").unwrap();
        check_report_complete(store, engine, &ds, &ServeConfig::default());
    });
}
