//! Online (streaming) SubGCache end-to-end tests over real artifacts:
//! queries arrive one at a time, clusters form on the fly, and warm
//! representative KV caches are reused across the stream.
//!
//! Skipped (with a message) when `artifacts/` is absent, so `cargo test -q`
//! stays green on a fresh clone; run `make artifacts` to enable.

use subgcache::coordinator::{Coordinator, ServeConfig};
use subgcache::prelude::*;
use subgcache::runtime::{ArtifactStore, Engine};

mod common;

fn with_engine<T>(f: impl FnOnce(&ArtifactStore, &Engine) -> T) -> Option<T> {
    common::with_engine("online e2e test", f)
}

#[test]
fn online_singleton_clusters_match_baseline() {
    // A negative threshold never joins: every query opens its own cluster
    // whose representative IS its own retrieved subgraph, so the online path
    // degenerates to per-query prefix + extend — which must predict exactly
    // what the baseline's full prompt predicts (greedy decoding).
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(6, 3);
        let cfg = ServeConfig { online_threshold: -1.0, ..Default::default() };
        let coord = Coordinator::new(store, engine, cfg).unwrap();
        let r = GRetriever::default();
        let base = coord.serve_baseline(&ds, &queries, &r).unwrap();
        let ours = coord.serve_online(&ds, queries.iter().copied(), &r).unwrap();
        assert_eq!(ours.cluster_sizes.len(), queries.len());
        assert_eq!(ours.metrics.miss_count(), queries.len(), "never-join = all misses");
        assert_eq!(ours.metrics.hit_count(), 0);
        for (b, o) in base.results.iter().zip(&ours.results) {
            assert_eq!(b.id, o.id);
            assert_eq!(b.predicted, o.predicted,
                       "q{}: baseline {:?} vs online-singleton {:?}",
                       b.id, b.predicted, o.predicted);
        }
    });
}

#[test]
fn online_stream_hits_warm_cache_and_splits_ttft() {
    // An infinite threshold funnels the whole stream into one cluster: the
    // first query prefills the representative, every later query must hit
    // the warm cache. Hit PFTT excludes the prefill, so the split must be
    // visible and ordered.
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(8, 11);
        let cfg = ServeConfig { online_threshold: f32::INFINITY, ..Default::default() };
        let coord = Coordinator::new(store, engine, cfg).unwrap();
        let r = GRetriever::default();
        let rep = coord.serve_online(&ds, queries.iter().copied(), &r).unwrap();

        assert_eq!(rep.results.len(), queries.len());
        assert_eq!(rep.cluster_sizes, vec![queries.len()]);
        assert_eq!(rep.metrics.miss_count(), 1, "only the opener prefills");
        assert_eq!(rep.metrics.hit_count(), queries.len() - 1);
        assert_eq!(rep.cache.prefills, 1);
        assert_eq!(rep.cache.hits as usize, queries.len() - 1);
        assert!((rep.cache.hit_rate() - (queries.len() - 1) as f64
                 / queries.len() as f64).abs() < 1e-9);
        // the headline asymmetry: a warm hit skips the representative
        // prefill entirely, so its PFTT (and TTFT) must come in under the
        // miss's.
        assert!(rep.metrics.pftt_hit_ms() < rep.metrics.pftt_miss_ms(),
                "hit PFTT {:.2} ms should undercut miss PFTT {:.2} ms",
                rep.metrics.pftt_hit_ms(), rep.metrics.pftt_miss_ms());
        assert!(rep.metrics.ttft_hit_ms() > 0.0 && rep.metrics.ttft_miss_ms() > 0.0);
        // per-query records carry the split
        for (i, q) in rep.metrics.per_query.iter().enumerate() {
            assert_eq!(q.cache_hit, Some(i > 0));
            assert!(q.pftt > 0.0 && q.ttft >= q.pftt && q.rt >= q.ttft);
        }
    });
}

#[test]
fn online_eviction_under_tight_budget_forces_reprefill() {
    // max_entries = 1 with singleton clusters: each new cluster evicts the
    // previous representative, so every query is a miss and the engine gets
    // every evicted handle back (no leaks).
    with_engine(|store, engine| {
        let ds = store.dataset("scene_graph").unwrap();
        let queries = ds.sample_test(5, 17);
        let cfg = ServeConfig {
            online_threshold: -1.0,
            cache: CachePolicy::single_resident(),
            ..Default::default()
        };
        let coord = Coordinator::new(store, engine, cfg).unwrap();
        let live_before = engine.stats().unwrap().live_kv;
        let rep = coord.serve_online(&ds, queries.iter().copied(),
                                     &GRetriever::default()).unwrap();
        assert_eq!(rep.metrics.miss_count(), queries.len());
        assert_eq!(rep.cache.prefills as usize, queries.len());
        assert_eq!(rep.cache.evictions as usize, queries.len() - 1);
        assert_eq!(rep.cache.resident_bytes, 0, "cache must be drained");
        assert_eq!(engine.stats().unwrap().live_kv, live_before, "leaked KV handles");
    });
}

#[test]
fn online_report_is_complete_and_ordered() {
    with_engine(|store, engine| {
        let ds = store.dataset("oag").unwrap();
        let queries = ds.sample_test(10, 5);
        let coord = Coordinator::new(store, engine, ServeConfig::default()).unwrap();
        let rep = coord.serve_online(&ds, queries.iter().copied(),
                                     &GragRetriever::default()).unwrap();
        assert_eq!(rep.results.len(), queries.len());
        assert_eq!(rep.metrics.per_query.len(), queries.len());
        for (r, q) in rep.results.iter().zip(&queries) {
            assert_eq!(r.id, q.id, "results must be in arrival order");
            assert_eq!(r.gold, q.answer);
        }
        assert_eq!(rep.cluster_sizes.iter().sum::<usize>(), queries.len());
        assert_eq!(rep.cluster_sizes.len(), rep.representative_sizes.len());
        assert_eq!(rep.metrics.hit_count() + rep.metrics.miss_count(), queries.len(),
                   "every online query is either a hit or a miss");
        // misses == prefills == installs; the first member of every cluster
        // is necessarily a miss.
        assert!(rep.metrics.miss_count() >= rep.cluster_sizes.len());
        assert_eq!(rep.cache.prefills as usize, rep.metrics.miss_count());
    });
}
