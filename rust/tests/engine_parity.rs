//! Golden parity between the device-resident KV path and the seed's
//! host-bounce path: storing prefill/extend K/V outputs as device buffers
//! (zero-copy) must change *nothing* about what the model computes — same
//! logits bit for bit, same generated tokens. `SUBGCACHE_KV_HOST_BOUNCE=1`
//! forces the old device→host→device path for the comparison engine; the
//! flag is read once per `Engine::start`, on the caller's thread.
//!
//! Everything lives in ONE #[test]: libtest runs a binary's tests on
//! parallel threads, and mutating the process environment while a sibling
//! test calls `Engine::start` (which reads it) would be a data race — so
//! this binary deliberately has a single test and no other env mutators.
//!
//! Skipped (with a message) when `artifacts/` is absent, so `cargo test -q`
//! stays green on a fresh clone; run `make artifacts` to enable.

use subgcache::coordinator::argmax;
use subgcache::runtime::Engine;

mod common;

const BACKBONE: &str = "llama-3.2-3b-sim";

fn ivec(v: &subgcache::util::json::Json, key: &str) -> Vec<i32> {
    v.get(key).as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect()
}

#[test]
fn device_resident_kv_matches_host_bounce_bit_exact() {
    let Some(store) = common::store("engine parity test") else { return };
    // `fast` is the default zero-copy engine, `slow` the forced host-bounce
    // one. Both env flips happen before any other engine in this process
    // could read them (single test in this binary — see module docs).
    std::env::remove_var("SUBGCACHE_KV_HOST_BOUNCE");
    let fast = Engine::start(&store).expect("engine start (device-resident)");
    std::env::set_var("SUBGCACHE_KV_HOST_BOUNCE", "1");
    let slow = Engine::start(&store).expect("engine start (host-bounce)");
    std::env::remove_var("SUBGCACHE_KV_HOST_BOUNCE");

    let g = store.golden(&format!("llm_{BACKBONE}.json")).unwrap();
    let prefix_tokens = ivec(&g, "prefix_tokens");
    let plen = g.get("prefix_len").as_i64().unwrap() as i32;
    let q_tokens = ivec(&g, "q_tokens");
    let qlen = g.get("q_len").as_i64().unwrap() as i32;
    let c = *store.constants();

    let run = |engine: &Engine| {
        let (kv, prefill_logits) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
        let (kv2, row) = engine.extend(BACKBONE, &kv, plen, &q_tokens, qlen).unwrap();
        let first = argmax(&row);
        let gen = engine.generate(BACKBONE, &kv2, plen + qlen, first).unwrap();
        engine.release(kv2);
        engine.release(kv);
        (prefill_logits, row, first, gen)
    };
    let (a_pre, a_row, a_first, a_gen) = run(&fast);
    let (b_pre, b_row, b_first, b_gen) = run(&slow);

    assert_eq!(a_pre, b_pre, "prefill logits must be bit-identical across KV paths");
    assert_eq!(a_row, b_row, "extend logits row must be bit-identical across KV paths");
    assert_eq!(a_first, b_first, "first token must agree");
    assert_eq!(a_gen, b_gen, "generated tokens must be identical across KV paths");

    // The transfer asymmetry IS this optimization: the device-resident path
    // must move zero KV bytes through the host, the forced bounce plenty.
    let fs = fast.stats().unwrap();
    let ss = slow.stats().unwrap();
    assert_eq!(fs.host_kv_bytes, 0,
               "device-resident path bounced {} KV bytes through the host",
               fs.host_kv_bytes);
    assert!(ss.host_kv_bytes > 0,
            "forced host-bounce path must account its KV transfers");

    // Regression (both KV paths): the seed sliced extend logits with
    // (qlen - 1) unchecked, so a question that tokenizes to zero tokens
    // panicked. The engine now clamps the row selection; a degenerate query
    // must cost one odd answer, never the process.
    for engine in [&fast, &slow] {
        let (kv, _) = engine.prefill(BACKBONE, &prefix_tokens, plen).unwrap();
        let all_pad = vec![c.pad_id; c.max_q];
        let (kv2, row) = engine
            .extend(BACKBONE, &kv, plen, &all_pad, 0)
            .expect("qlen = 0 must clamp, not panic");
        assert_eq!(row.len(), c.vocab, "extend must return exactly one [V] row");
        assert!(row.iter().all(|v| v.is_finite()));
        engine.release(kv2);
        engine.release(kv);
    }
}
