//! Shared environment plumbing for the integration test binaries.
//!
//! Every serving scenario in `coordinator_e2e.rs` / `online_e2e.rs` runs in
//! two flavors over the same assertions:
//!
//! * **sim** (always on): the in-memory artifact world + [`SimBackend`] —
//!   runs on a fresh clone and in CI, no `make artifacts` needed.
//! * **artifacts** (opt-in by presence): the real PJRT engine over
//!   `artifacts/`; self-skips (with a message) when the directory is
//!   absent, so `cargo test -q` stays green everywhere.

use subgcache::coordinator::ServeConfig;
use subgcache::data::Dataset;
use subgcache::runtime::{sim_dataset, sim_store, ArtifactStore, BatchConfig, Engine,
                         SimBackend, SimLatency, SIM_BACKBONE};

pub const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

/// `None` (with a skip message) when artifacts/ is absent.
#[allow(dead_code)] // each test binary uses the subset it needs
pub fn store(what: &str) -> Option<ArtifactStore> {
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("skipping {what}: {ARTIFACTS} not found — run `make artifacts` first");
        return None;
    }
    Some(ArtifactStore::open(ARTIFACTS).expect("artifacts present but unreadable"))
}

/// Fresh engine per test: a process-static engine thread would still own
/// the PJRT client while C++ static destructors run at exit (observed as an
/// exit-time SIGSEGV); Engine::drop joins the lane threads deterministically.
/// Tests in one binary run sequentially, so compile cost stays bounded.
#[allow(dead_code)]
pub fn with_engine<T>(what: &str, f: impl FnOnce(&ArtifactStore, &Engine) -> T)
                      -> Option<T> {
    let s = store(what)?;
    let e = Engine::start(&s).expect("engine start");
    Some(f(&s, &e))
}

/// One self-contained simulation environment: in-memory store, synthetic
/// dataset (deterministic; all queries in the test split), and a
/// [`SimBackend`] with the given latency profile.
#[allow(dead_code)]
pub struct SimEnv {
    pub store: ArtifactStore,
    pub ds: Dataset,
    pub backend: SimBackend,
}

#[allow(dead_code)]
pub fn sim_env(lat: SimLatency) -> SimEnv {
    let store = sim_store();
    let backend = SimBackend::start(&store, lat).expect("sim backend start");
    SimEnv { store, ds: sim_dataset(4, 4), backend }
}

/// [`sim_env`] with an explicit LLM-lane micro-batch config (the batching
/// test suite's entry point; `BatchConfig::off()` reproduces `sim_env`).
#[allow(dead_code)]
pub fn sim_env_batched(lat: SimLatency, cfg: BatchConfig) -> SimEnv {
    let store = sim_store();
    let backend = SimBackend::start_with(&store, lat, cfg).expect("sim backend start");
    SimEnv { store, ds: sim_dataset(4, 4), backend }
}

/// Default serve config for the sim world (its backbone name differs from
/// the artifact default).
#[allow(dead_code)]
pub fn sim_config() -> ServeConfig {
    ServeConfig { backbone: SIM_BACKBONE.into(), ..Default::default() }
}
