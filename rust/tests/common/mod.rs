//! Shared artifact gating for the integration test binaries.
//!
//! The e2e/golden tests need the `artifacts/` directory that `make
//! artifacts` produces; on a fresh clone they skip (with a message) instead
//! of failing, so `cargo test -q` stays green. `what` names the caller in
//! the skip message (e.g. "golden test").

use subgcache::runtime::{ArtifactStore, Engine};

pub const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

/// `None` (with a skip message) when artifacts/ is absent.
#[allow(dead_code)] // each test binary uses the subset it needs
pub fn store(what: &str) -> Option<ArtifactStore> {
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("skipping {what}: {ARTIFACTS} not found — run `make artifacts` first");
        return None;
    }
    Some(ArtifactStore::open(ARTIFACTS).expect("artifacts present but unreadable"))
}

/// Fresh engine per test: a process-static engine thread would still own
/// the PJRT client while C++ static destructors run at exit (observed as an
/// exit-time SIGSEGV); Engine::drop joins the thread deterministically.
/// Tests in one binary run sequentially, so compile cost stays bounded.
#[allow(dead_code)]
pub fn with_engine<T>(what: &str, f: impl FnOnce(&ArtifactStore, &Engine) -> T)
                      -> Option<T> {
    let s = store(what)?;
    let e = Engine::start(&s).expect("engine start");
    Some(f(&s, &e))
}
