//! Scheduler-level tests of the online serving pipeline over the
//! deterministic [`SimBackend`] — the scenarios artifact-gated e2e tests
//! can never cover in CI: lane overlap and wall-clock wins of the depth-k
//! scheduler, cluster TTL (including pin-safety of in-flight
//! representatives), the TTFT-composition property under random per-op
//! latencies, and dead-lane error propagation through the serving path.
//!
//! Latencies here are real sleeps on the sim lane workers, so assertions
//! compare configurations with generous margins rather than absolute times.

use subgcache::coordinator::{Coordinator, ServeConfig, ServeReport};
use subgcache::data::Dataset;
use subgcache::prelude::*;
use subgcache::runtime::{sim_dataset, SimLatency};
use subgcache::util::prop::prop_check;

mod common;

fn serve_online_with(env: &common::SimEnv, ds: &Dataset, cfg: ServeConfig,
                     n: usize, seed: u64) -> ServeReport {
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let queries = ds.sample_test(n, seed);
    assert!(!queries.is_empty());
    coord.serve_online(ds, queries.iter().copied(), &GRetriever::default()).unwrap()
}

// ---------------------------------------------------------------------------
// Depth-k pipelining (the tentpole acceptance criterion)
// ---------------------------------------------------------------------------

/// With encode ≈ prefill, depth k = 2 must (a) overlap host prep with
/// in-flight engine calls (`overlap_time > 0`) and (b) finish the stream in
/// less wall time than k = 1, because the GNN lane runs query i+1's encode
/// under query i's LLM work and the decode stage is decoupled. Latencies
/// are an order of magnitude above scheduler jitter, and the win at these
/// settings is ~1.4x, so the 10% margin below is conservative.
#[test]
fn depth_2_overlaps_lanes_and_beats_depth_1_wall_time() {
    // encode ≈ prefill (the criterion's regime); never-join so every query
    // pays both, maximizing the overlappable work.
    let lat = SimLatency::from_millis(12, 4, 4, 12);
    let n = 10;
    let run = |depth: usize| {
        let env = common::sim_env(lat);
        let ds = sim_dataset(5, 2);
        let cfg = ServeConfig {
            online_threshold: -1.0,
            pipeline_depth: depth,
            ..common::sim_config()
        };
        serve_online_with(&env, &ds, cfg, n, 7)
    };
    let serial = run(1);
    let piped = run(2);

    assert_eq!(serial.metrics.per_query.len(), n);
    assert_eq!(piped.metrics.per_query.len(), n);
    assert_eq!(serial.metrics.pipeline_depth, 1);
    assert_eq!(piped.metrics.pipeline_depth, 2);

    assert!(piped.metrics.overlap_time > 0.0,
            "depth 2 must run host prep in engine shadows");
    assert!(
        piped.metrics.wall_time < serial.metrics.wall_time * 0.9,
        "depth 2 should beat depth 1 wall time: {:.3}s vs {:.3}s",
        piped.metrics.wall_time, serial.metrics.wall_time
    );
    assert!(piped.metrics.qps() > serial.metrics.qps());

    // both lanes did real work, and at depth 2 their busy fractions overlap
    // (GNN encode time was hidden under LLM time instead of extending wall)
    assert!(piped.metrics.lane_gnn.device_time > 0.0);
    assert!(piped.metrics.lane_llm.device_time > 0.0);
    let busy_sum = piped.metrics.lane_busy_frac(Lane::Llm)
        + piped.metrics.lane_busy_frac(Lane::Gnn);
    assert!(busy_sum > serial.metrics.lane_busy_frac(Lane::Llm)
            + serial.metrics.lane_busy_frac(Lane::Gnn),
            "depth 2 must raise combined lane utilization");

    // per-query answers are identical: scheduling must never change results
    for (a, b) in serial.results.iter().zip(&piped.results) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.predicted, b.predicted,
                   "pipelining changed an answer for q{}", a.id);
    }
}

/// Deeper lookahead must not break ordering, accounting or answers.
#[test]
fn depth_4_serves_identically_to_depth_1() {
    let lat = SimLatency::from_millis(4, 2, 2, 4);
    let run = |depth: usize| {
        let env = common::sim_env(lat);
        let ds = sim_dataset(4, 3);
        let cfg = ServeConfig { pipeline_depth: depth, ..common::sim_config() };
        serve_online_with(&env, &ds, cfg, 9, 3)
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.id, y.id, "arrival order violated");
        assert_eq!(x.predicted, y.predicted);
        assert_eq!(x.cluster, y.cluster, "clustering must not depend on depth");
    }
    assert_eq!(a.metrics.hit_count(), b.metrics.hit_count());
    assert_eq!(a.metrics.miss_count(), b.metrics.miss_count());
}

// ---------------------------------------------------------------------------
// Depth-k scheduler property (satellite)
// ---------------------------------------------------------------------------

/// For random per-op latencies and k ∈ {1, 2, 4}: every query's TTFT,
/// composed from its own component times, never exceeds its serial
/// latency sum (one encode + prefill + extend + generate back to back,
/// plus a host-work allowance), and the reported overlap can never exceed
/// the wall clock. This is the accounting contract that keeps per-query
/// latencies comparable across serial and pipelined runs.
#[test]
fn ttft_composition_never_exceeds_serial_sum_property() {
    // generous allowance for host work + sleep overshoot, still well under
    // the ~60–100 ms serial sums the latency draws below produce — so
    // charging a neighbor's pipeline to a query would trip the bound.
    const HOST_EPS: f64 = 0.08;
    prop_check(3, |rng| {
        let ms = |lo: usize, hi: usize| rng.range(lo, hi) as u64;
        let lat = SimLatency::from_millis(ms(15, 26), ms(15, 26), ms(15, 26),
                                          ms(15, 26));
        for depth in [1usize, 2, 4] {
            let env = common::sim_env(lat);
            let ds = sim_dataset(3, 2);
            let cfg = ServeConfig {
                pipeline_depth: depth,
                online_threshold: if rng.below(2) == 0 { -1.0 } else { f32::INFINITY },
                ..common::sim_config()
            };
            let rep = serve_online_with(&env, &ds, cfg, 4, 1 + depth as u64);
            let bound = lat.serial_sum() + HOST_EPS;
            for (i, q) in rep.metrics.per_query.iter().enumerate() {
                assert!(q.pftt > 0.0 && q.ttft >= q.pftt && q.rt >= q.ttft,
                        "k={depth} q{i}: inconsistent latency composition");
                assert!(q.ttft <= bound,
                        "k={depth} q{i}: ttft {:.4}s exceeds serial sum {:.4}s — \
                         a neighbor's work was charged to this query",
                        q.ttft, bound);
            }
            assert!(rep.metrics.overlap_time <= rep.metrics.wall_time + 1e-6,
                    "k={depth}: overlap {:.4}s cannot exceed wall {:.4}s",
                    rep.metrics.overlap_time, rep.metrics.wall_time);
            assert_eq!(rep.metrics.hit_count() + rep.metrics.miss_count(),
                       rep.metrics.per_query.len());
        }
    });
}

// ---------------------------------------------------------------------------
// Cluster TTL (satellite)
// ---------------------------------------------------------------------------

/// ttl = 0 with an all-join threshold: the single cluster is stale at every
/// sweep (its last use is always the previous arrival), and — under the
/// decoupled decode — still pinned by the previous query's in-flight work
/// when the sweep runs. The sweep must skip it: the stream keeps hitting
/// the warm representative and nothing is ever expired mid-flight.
#[test]
fn ttl_sweep_never_expires_a_pinned_inflight_representative() {
    let env = common::sim_env(SimLatency::from_millis(6, 3, 3, 3));
    let ds = sim_dataset(4, 4);
    let cfg = ServeConfig {
        online_threshold: f32::INFINITY,
        cluster_ttl: Some(0),
        pipeline_depth: 2, // decoupled decode keeps the pin across the sweep
        ..common::sim_config()
    };
    let n = 8;
    let rep = serve_online_with(&env, &ds, cfg, n, 11);
    assert_eq!(rep.cluster_sizes, vec![n], "one cluster serves the whole stream");
    assert_eq!(rep.expired_clusters, 0,
               "a pinned in-flight representative must survive TTL expiry");
    assert_eq!(rep.cache.prefills, 1, "expiring the pinned rep would force re-prefills");
    assert_eq!(rep.metrics.hit_count(), n - 1);
    assert_eq!(env.backend.stats().unwrap().live_kv, 0, "drained after serving");
}

/// ttl = 0 with never-join: every cluster is used exactly once, goes cold
/// immediately, and is reclaimed two turns later (its pin spans one extra
/// turn under the decoupled decode). With N = 5 singleton clusters the
/// sweeps at turns 2, 3 and 4 expire clusters 0, 1 and 2; the last two die
/// with the stream. Every handle is returned exactly once — by the sweep
/// or the end-of-stream drain.
#[test]
fn ttl_expires_cold_clusters_and_releases_their_entries() {
    let env = common::sim_env(SimLatency::zero());
    let ds = sim_dataset(5, 1);
    let cfg = ServeConfig {
        online_threshold: -1.0,
        cluster_ttl: Some(0),
        pipeline_depth: 2,
        ..common::sim_config()
    };
    let n = 5;
    let rep = serve_online_with(&env, &ds, cfg, n, 5);
    assert_eq!(rep.cluster_sizes.len(), n);
    assert_eq!(rep.expired_clusters, n - 2,
               "all but the final two singleton clusters go cold and expire");
    assert_eq!(rep.metrics.miss_count(), n);
    assert_eq!(rep.cache.prefills as usize, n);
    assert_eq!(rep.cache.released as usize, n,
               "every representative handle returns exactly once (sweep or drain)");
    assert_eq!(rep.cache.resident_bytes, 0);
    assert_eq!(env.backend.stats().unwrap().live_kv, 0, "no leaked KV on the backend");
}

/// An expired centroid must stop participating in matching: a query that
/// would have joined it re-opens a fresh cluster instead.
#[test]
fn expired_centroid_no_longer_matches() {
    let env = common::sim_env(SimLatency::zero());
    let ds = sim_dataset(2, 2);
    let queries = ds.sample_test(100, 1); // all 4, deterministic order
    // pick one query from each lexical group (distinct embeddings)
    let qa = queries.iter().copied().find(|q| q.text.contains("river")).unwrap();
    let qb = queries.iter().copied().find(|q| !q.text.contains("river")).unwrap();
    // stream: A opens cA; three Bs keep cB warm while cA goes cold and
    // expires (age 2 at the third arrival); the final identical A would
    // join cA were it alive — it must open a third cluster instead.
    let stream = vec![qa, qb, qb, qb, qa];
    let cfg = ServeConfig {
        online_threshold: 1e-3, // identical queries join, distinct groups don't
        cluster_ttl: Some(1),
        pipeline_depth: 2,
        ..common::sim_config()
    };
    let coord = Coordinator::new(&env.store, &env.backend, cfg).unwrap();
    let rep = coord.serve_online(&ds, stream, &GRetriever::default()).unwrap();
    assert_eq!(rep.cluster_sizes.len(), 3,
               "the expired A-cluster must not absorb the returning A-query");
    assert_eq!(rep.expired_clusters, 1);
    assert_eq!(rep.metrics.miss_count(), 3, "A, B, and the re-opened A prefill");
    assert_eq!(rep.metrics.hit_count(), 2, "the repeated Bs stay warm");
}

// ---------------------------------------------------------------------------
// Dead-lane regression (satellite, serving-level)
// ---------------------------------------------------------------------------

/// A lane whose worker thread has died must fail the serving call with an
/// error — never hang a wait or panic the coordinator. (The ticket-level
/// contract is covered in `runtime::sim` unit tests; this exercises it
/// through the full serving path on the multi-lane backend.)
#[test]
fn serving_on_a_dead_lane_errors_instead_of_hanging() {
    let env = common::sim_env(SimLatency::zero());
    let ds = sim_dataset(3, 2);
    let queries = ds.sample_test(4, 3);

    env.backend.kill_lane_for_test(Lane::Llm);
    let coord = Coordinator::new(&env.store, &env.backend, common::sim_config()).unwrap();
    let err = coord
        .serve_online(&ds, queries.iter().copied(), &GRetriever::default())
        .unwrap_err();
    assert!(err.to_string().contains("lane"), "unhelpful dead-lane error: {err}");

    // the GNN lane is still alive and answers directly
    let c = *env.store.constants();
    let emb = env.backend.encode("gat",
                                 vec![0.0; c.n_max * c.feat_dim],
                                 vec![0.0; c.n_max * c.n_max],
                                 vec![0.0; c.n_max]).unwrap();
    assert_eq!(emb.len(), c.gnn_emb);
}
