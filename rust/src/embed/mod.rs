//! FNV-1a feature-hashing bag-of-tokens embedder — exact mirror of
//! `python/compile/hashembed.py` (SentenceBERT substitute, DESIGN.md §4).
//!
//! Used on the request path for (a) retrieval similarity scoring and (b)
//! GNN node features. Pinned cross-language by `artifacts/golden/embed.json`.

use crate::tokenizer::split_text;

pub const FEAT_DIM: usize = 64;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x1_0000_0001_B3;

/// 64-bit FNV-1a (identical constants to the Python side).
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// L2-normalized hashed bag-of-tokens embedding. Each token adds ±1 to one
/// bucket (bucket = hash % dim, sign = bit 63), keeping E[dot] ≈ 0 for
/// disjoint token sets so cosine tracks token overlap.
pub fn embed_text_dim(text: &str, dim: usize) -> Vec<f32> {
    let mut v = vec![0f64; dim];
    for tok in split_text(text) {
        let h = fnv1a(tok.as_bytes());
        let sign = if h >> 63 == 0 { 1.0 } else { -1.0 };
        v[(h % dim as u64) as usize] += sign;
    }
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    v.into_iter().map(|x| x as f32).collect()
}

pub fn embed_text(text: &str) -> Vec<f32> {
    embed_text_dim(text, FEAT_DIM)
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let (na, nb) = (norm(a), norm(b));
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

/// Squared Euclidean distance (clustering hot path).
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn fnv1a_known_vectors() {
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_F739_67E8);
    }

    #[test]
    fn unit_norm() {
        let v = embed_text("what is the color of the cords ?");
        assert!((norm(&v) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn empty_is_zero() {
        assert!(embed_text("").iter().all(|&x| x == 0.0));
    }

    #[test]
    fn similarity_tracks_overlap() {
        let a = embed_text("the red laptop on the table");
        let b = embed_text("the red laptop near the chair");
        let c = embed_text("graph neural network caching inference");
        assert!(cosine(&a, &b) > cosine(&a, &c));
    }

    #[test]
    fn case_insensitive() {
        assert_eq!(embed_text("Alpha BETA"), embed_text("alpha beta"));
    }

    #[test]
    fn norm_property() {
        prop_check(100, |rng| {
            let n_words = rng.below(8);
            let words: Vec<String> = (0..n_words)
                .map(|_| format!("w{}", rng.below(20)))
                .collect();
            let v = embed_text(&words.join(" "));
            let n = norm(&v);
            assert!(n == 0.0 || (n - 1.0).abs() < 1e-5, "norm {n}");
        });
    }

    #[test]
    fn sq_dist_cosine_consistency() {
        // for unit vectors: ||a-b||² = 2 - 2 cos(a,b)
        let a = embed_text("red laptop table");
        let b = embed_text("blue cords chair");
        let d = sq_dist(&a, &b);
        let c = cosine(&a, &b);
        assert!((d - (2.0 - 2.0 * c)).abs() < 1e-4);
    }
}
