//! Experiment harness shared by the table/figure binaries and benches:
//! runs one (dataset × retriever × backbone × config) cell — baseline,
//! +SubGCache, and optionally the online streaming path — and renders
//! paper-style tables (DESIGN.md §3).

use crate::cache::CachePolicy;
use crate::cluster::Linkage;
use crate::coordinator::{Coordinator, MultiStreamReport, OverloadConfig, ServeConfig,
                         ServeReport};
use crate::data::{Dataset, Query};
use crate::metrics::{delta, delta_cells, metric_cells, Table};
use crate::retrieval::{GRetriever, GragRetriever, Retriever};
use crate::runtime::{ArtifactStore, Backend, BatchConfig, FaultPlan};
use crate::util::bench::JsonRow;

/// The paper's default cluster counts per dataset (§4.3: Scene Graph shines
/// at c=1, OAG at c=2).
pub fn default_clusters(dataset: &str) -> usize {
    match dataset {
        "scene_graph" => 1,
        _ => 2,
    }
}

pub fn retriever_by_name(name: &str) -> anyhow::Result<Box<dyn Retriever>> {
    Ok(match name {
        "g-retriever" => Box::new(GRetriever::default()),
        "grag" => Box::new(GragRetriever::default()),
        other => anyhow::bail!("unknown retriever '{other}' (g-retriever | grag)"),
    })
}

/// One experiment cell specification.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: String,
    pub retriever: String,
    pub backbone: String,
    pub batch: usize,
    pub n_clusters: usize,
    pub linkage: Linkage,
    pub seed: u64,
    /// KV-cache byte/entry budget for the SubGCache paths.
    pub cache: CachePolicy,
    /// squared-distance centroid join bound for the online path.
    pub online_threshold: f32,
    /// online scheduler lookahead k (see `ServeConfig::pipeline_depth`).
    pub pipeline_depth: usize,
    /// online cluster TTL in arrivals (see `ServeConfig::cluster_ttl`).
    pub cluster_ttl: Option<u64>,
    /// per-query recovery deadline (see `ServeConfig::deadline`).
    pub deadline: Option<std::time::Duration>,
    /// per-stage retry budget (see `ServeConfig::max_retries`).
    pub max_retries: u32,
    /// open-loop arrivals / admission control / brownout ladder (see
    /// `ServeConfig::overload`). Defaults to the inert closed-loop plan.
    pub overload: OverloadConfig,
}

impl Cell {
    pub fn new(dataset: &str, retriever: &str, backbone: &str, batch: usize) -> Cell {
        let d = ServeConfig::default();
        Cell {
            dataset: dataset.into(),
            retriever: retriever.into(),
            backbone: backbone.into(),
            batch,
            n_clusters: default_clusters(dataset),
            linkage: Linkage::Ward,
            seed: 7,
            cache: CachePolicy::default(),
            online_threshold: d.online_threshold,
            pipeline_depth: d.pipeline_depth,
            cluster_ttl: d.cluster_ttl,
            deadline: d.deadline,
            max_retries: d.max_retries,
            overload: d.overload,
        }
    }

    fn serve_config(&self) -> ServeConfig {
        ServeConfig {
            backbone: self.backbone.clone(),
            n_clusters: self.n_clusters,
            linkage: self.linkage,
            gnn: None,
            cache: self.cache,
            online_threshold: self.online_threshold,
            pipeline_depth: self.pipeline_depth,
            cluster_ttl: self.cluster_ttl,
            deadline: self.deadline,
            max_retries: self.max_retries,
            overload: self.overload,
        }
    }
}

/// Baseline + SubGCache reports for one cell.
pub struct CellResult {
    pub cell: Cell,
    pub baseline: ServeReport,
    pub subgcache: ServeReport,
}

/// Run one cell (both methods on the identical query sample), loading the
/// dataset from the artifact store.
pub fn run_cell(store: &ArtifactStore, engine: &dyn Backend, cell: &Cell)
                -> anyhow::Result<CellResult> {
    run_cell_with(store, engine, &store.dataset(&cell.dataset)?, cell)
}

/// [`run_cell`] over a caller-supplied dataset — the entry point for sim
/// runs, whose in-memory store has no data files on disk (pair with
/// [`crate::runtime::sim_dataset`]).
pub fn run_cell_with(store: &ArtifactStore, engine: &dyn Backend, ds: &Dataset,
                     cell: &Cell) -> anyhow::Result<CellResult> {
    let retriever = retriever_by_name(&cell.retriever)?;
    let queries = ds.sample_test(cell.batch, cell.seed);
    anyhow::ensure!(!queries.is_empty(), "dataset {} has no test queries", cell.dataset);

    let coord = Coordinator::new(store, engine, cell.serve_config())?;
    let baseline = coord.serve_baseline(ds, &queries, retriever.as_ref())?;
    let subgcache = coord.serve_subgcache(ds, &queries, retriever.as_ref())?;
    Ok(CellResult { cell: cell.clone(), baseline, subgcache })
}

/// Baseline + streaming-SubGCache reports for one cell (Table 5).
pub struct OnlineCellResult {
    pub cell: Cell,
    pub baseline: ServeReport,
    pub online: ServeReport,
}

/// Run one online cell: the same seed-sampled queries, but served one at a
/// time against clusters formed on the fly, vs the per-query baseline.
pub fn run_online_cell(store: &ArtifactStore, engine: &dyn Backend, cell: &Cell)
                       -> anyhow::Result<OnlineCellResult> {
    run_online_cell_with(store, engine, &store.dataset(&cell.dataset)?, cell)
}

/// [`run_online_cell`] over a caller-supplied dataset (sim runs).
pub fn run_online_cell_with(store: &ArtifactStore, engine: &dyn Backend, ds: &Dataset,
                            cell: &Cell) -> anyhow::Result<OnlineCellResult> {
    let retriever = retriever_by_name(&cell.retriever)?;
    let queries = ds.sample_test(cell.batch, cell.seed);
    anyhow::ensure!(!queries.is_empty(), "dataset {} has no test queries", cell.dataset);

    let coord = Coordinator::new(store, engine, cell.serve_config())?;
    let baseline = coord.serve_baseline(ds, &queries, retriever.as_ref())?;
    let online = coord.serve_online(ds, queries.iter().copied(), retriever.as_ref())?;
    Ok(OnlineCellResult { cell: cell.clone(), baseline, online })
}

/// One cell served as N concurrent replicated streams over one shared
/// KV-cache pool (the `--streams` mode of Table 5 and the serving bench).
/// Serial/baseline reference numbers come from [`run_online_cell`] on the
/// same cell — deliberately not re-run here.
pub struct MultiOnlineCellResult {
    pub cell: Cell,
    /// Streams served concurrently.
    pub streams: usize,
    pub multi: MultiStreamReport,
}

/// Run one online cell as `streams` concurrent streams. Every stream serves
/// the same seed-sampled query sequence — the many-users-asking-similar-
/// things regime cross-stream sharing exists for: identical representatives
/// across streams should be prefilled once, not `streams` times.
pub fn run_multi_online_cell(store: &ArtifactStore, engine: &dyn Backend, cell: &Cell,
                             streams: usize) -> anyhow::Result<MultiOnlineCellResult> {
    run_multi_online_cell_with(store, engine, &store.dataset(&cell.dataset)?, cell,
                               streams)
}

/// [`run_multi_online_cell`] over a caller-supplied dataset (sim runs).
pub fn run_multi_online_cell_with(store: &ArtifactStore, engine: &dyn Backend,
                                  ds: &Dataset, cell: &Cell, streams: usize)
                                  -> anyhow::Result<MultiOnlineCellResult> {
    anyhow::ensure!(streams >= 1, "need at least one stream");
    let retriever = retriever_by_name(&cell.retriever)?;
    let queries = ds.sample_test(cell.batch, cell.seed);
    anyhow::ensure!(!queries.is_empty(), "dataset {} has no test queries", cell.dataset);

    let coord = Coordinator::new(store, engine, cell.serve_config())?;
    let lanes: Vec<Vec<&Query>> = (0..streams).map(|_| queries.clone()).collect();
    let multi = coord.serve_online_multi(ds, &lanes, retriever.as_ref())?;
    Ok(MultiOnlineCellResult { cell: cell.clone(), streams, multi })
}

/// Render one retriever block of a paper table (method, +SubGCache, Δ rows).
pub fn push_block(t: &mut Table, label: &str, r: &CellResult) {
    t.row(&metric_cells(label, &r.baseline.metrics));
    t.row(&metric_cells(&format!("{label}+SubGCache"), &r.subgcache.metrics));
    t.row(&delta_cells(&format!("Δ_{label}"), &delta(&r.baseline.metrics,
                                                     &r.subgcache.metrics)));
}

pub const METRIC_HEADER: [&str; 5] = ["Model", "ACC↑", "RT↓(ms)", "TTFT↓(ms)", "PFTT↓(ms)"];

/// Header for the online (streaming) table: the hit/miss TTFT split is the
/// headline, since online speedup is exactly the warm-hit asymmetry.
pub const ONLINE_HEADER: [&str; 8] = [
    "Model", "ACC↑", "RT↓(ms)", "TTFT↓(ms)", "TTFT(hit)", "TTFT(miss)",
    "hits/misses", "hit-rate",
];

/// Format the online-method row of Table 5. An empty hit/miss bucket prints
/// "-" (no measurement), never a zero that reads as 0 ms latency.
pub fn online_cells(name: &str, r: &ServeReport) -> Vec<String> {
    let m = &r.metrics;
    let bucket = |count: usize, ms: f64| {
        if count == 0 { "-".to_string() } else { format!("{ms:.2}") }
    };
    vec![
        name.to_string(),
        format!("{:.2}", m.acc()),
        format!("{:.2}", m.rt_ms()),
        format!("{:.2}", m.ttft_ms()),
        bucket(m.hit_count(), m.ttft_hit_ms()),
        bucket(m.miss_count(), m.ttft_miss_ms()),
        format!("{}/{}", m.hit_count(), m.miss_count()),
        format!("{:.0}%", 100.0 * r.cache.hit_rate()),
    ]
}

/// One-line cache summary for diagnostics under a table. Deliberately no
/// hit-rate: the batch pipeline installs then looks up each cluster, so its
/// rate is trivially 100% — the rate is only meaningful on the online path,
/// where the table's own hit-rate column reports it.
pub fn cache_summary(r: &ServeReport) -> String {
    let s = r.cache;
    format!(
        "cache: {} prefills, {} hits, {} evictions, peak {:.0} KiB, \
         {:.0} KiB prefill bytes saved",
        s.prefills, s.hits, s.evictions,
        s.peak_bytes as f64 / 1024.0, s.bytes_saved as f64 / 1024.0
    )
}

/// One-line wall-clock/throughput summary for diagnostics under a table.
/// Per-query latencies stay composed from each query's own component times
/// (see `coordinator` docs), so the submit/wait pipelining win is only
/// visible here: wall-clock, queries per second, and how much host prep ran
/// in the shadow of in-flight engine calls.
pub fn throughput_summary(r: &ServeReport) -> String {
    let m = &r.metrics;
    format!(
        "wall {:.2}s ({:.1} q/s), {:.1} ms host prep overlapped, k={}, \
         lanes llm {:.0}%/gnn {:.0}% busy",
        m.wall_time, m.qps(), m.overlap_time * 1e3, m.pipeline_depth,
        100.0 * m.lane_busy_frac(crate::runtime::Lane::Llm),
        100.0 * m.lane_busy_frac(crate::runtime::Lane::Gnn)
    )
}

/// One serving report as a `BENCH_serving.json` result row: the wall/qps
/// throughput summary plus the overlap and per-lane splits — the numbers
/// PRs are compared on, in the same file shape as `BENCH_engine.json`.
pub fn serving_row(name: &str, r: &ServeReport) -> JsonRow {
    let m = &r.metrics;
    JsonRow::new(name)
        .int("queries", m.per_query.len() as u64)
        .num("wall_s", m.wall_time)
        .num("qps", m.qps())
        .num("ttft_ms", m.ttft_ms())
        .num("pftt_ms", m.pftt_ms())
        .num("overlap_ms", m.overlap_time * 1e3)
        .int("pipeline_depth", m.pipeline_depth as u64)
        .num("llm_lane_device_s", m.lane_llm.device_time)
        .num("llm_lane_queue_s", m.lane_llm.queue_time)
        .num("llm_lane_window_s", m.lane_llm.window_time)
        .int("llm_device_calls", m.lane_llm.batch.device_calls)
        .int("llm_fused_calls", m.lane_llm.batch.fused_calls)
        .num("llm_mean_occupancy", m.lane_llm.batch.mean_occupancy())
        .int("llm_window_stalls", m.lane_llm.batch.window_stalls)
        .num("gnn_lane_device_s", m.lane_gnn.device_time)
        .num("gnn_lane_queue_s", m.lane_gnn.queue_time)
        .int("cache_hits", r.cache.hits)
        .int("cache_evictions", r.cache.evictions)
        .int("shared_hits", r.cache.shared_hits)
        .int("dedup_bytes_saved", r.cache.dedup_bytes_saved)
        .int("demotions", r.cache.demotions)
        .int("promotions", r.cache.promotions)
        .int("host_hits", r.cache.host_hits)
        .int("host_bytes", r.cache.host_bytes as u64)
        .int("released", r.cache.released)
        .int("archived", r.cache.archived)
        .int("recalls", r.cache.recalls)
        .int("disk_hits", r.cache.disk_hits)
        .int("disk_bytes", r.cache.disk_bytes as u64)
        .int("lane_restarts", m.reliability.restarts)
        .int("retries", m.reliability.retries)
        .int("quarantined", m.reliability.quarantined_entries)
        .int("deadline_hits", m.reliability.deadline_hits)
        .num("degraded_ms", m.reliability.degraded_secs * 1e3)
        .int("llm_queue_depth_peak", m.lane_llm.depth_peak)
        .num("llm_queue_depth_mean", m.lane_llm.mean_depth())
        .int("admitted", m.reliability.shed.admitted)
        .int("shed", m.reliability.shed.total_shed())
        .int("shed_deadline", m.reliability.shed.shed_deadline)
        .int("shed_overloaded", m.reliability.shed.shed_overloaded)
        .int("shed_brownout", m.reliability.shed.shed_brownout)
        .num("shed_rate", m.reliability.shed.shed_rate())
        .int("brownout_spans", m.reliability.brownout_spans)
        .num("brownout_ms", m.reliability.brownout_secs * 1e3)
        .int("breaker_trips", m.reliability.breaker_trips)
}

/// One multi-stream run as a `BENCH_serving.json` row: fleet wall/qps plus
/// the pool-level dedup and lock-contention counters — the numbers that say
/// whether cross-stream sharing is actually paying off.
pub fn multi_serving_row(name: &str, m: &MultiStreamReport) -> JsonRow {
    JsonRow::new(name)
        .int("streams", m.streams.len() as u64)
        .int("queries", m.total_queries() as u64)
        .num("wall_s", m.wall_time)
        .num("qps", m.qps())
        .int("pool_prefills", m.shared.prefills)
        .int("shared_hits", m.shared.shared_hits)
        .int("dedup_bytes_saved", m.shared.dedup_bytes_saved)
        .int("deferred_releases", m.shared.deferred_releases)
        .int("demotions", m.shared.demotions)
        .int("promotions", m.shared.promotions)
        .int("host_hits", m.shared.host_hits)
        .int("host_bytes", m.shared.host_bytes as u64)
        .int("released", m.shared.released)
        .int("archived", m.shared.archived)
        .int("recalls", m.shared.recalls)
        .int("disk_hits", m.shared.disk_hits)
        .int("disk_bytes", m.shared.disk_bytes as u64)
        .int("lock_acquisitions", m.lock.acquisitions)
        .int("lock_contended", m.lock.contended)
        .int("failed_streams", m.failed_streams() as u64)
        .int("lane_restarts", m.reliability.restarts)
        .int("retries", m.reliability.retries)
        .int("quarantined", m.reliability.quarantined_entries)
        .int("deadline_hits", m.reliability.deadline_hits)
        .num("degraded_ms", m.reliability.degraded_secs * 1e3)
        .int("admitted", m.reliability.shed.admitted)
        .int("shed", m.reliability.shed.total_shed())
        .int("shed_deadline", m.reliability.shed.shed_deadline)
        .int("shed_overloaded", m.reliability.shed.shed_overloaded)
        .int("shed_brownout", m.reliability.shed.shed_brownout)
        .num("shed_rate", m.reliability.shed.shed_rate())
        .int("brownout_spans", m.reliability.brownout_spans)
        .num("brownout_ms", m.reliability.brownout_secs * 1e3)
        .int("breaker_trips", m.reliability.breaker_trips)
}

/// One-line summary of a multi-stream run for the table binaries.
pub fn multi_summary(m: &MultiStreamReport) -> String {
    format!(
        "{} streams: wall {:.2}s ({:.1} q/s), {} pool prefills, {} shared hits, \
         {:.0} KiB dedup-saved, lock {}/{} contended",
        m.streams.len(), m.wall_time, m.qps(), m.shared.prefills,
        m.shared.shared_hits, m.shared.dedup_bytes_saved as f64 / 1024.0,
        m.lock.contended, m.lock.acquisitions
    )
}

/// Collector for the serving bench JSON: table harnesses push one row per
/// (cell, method) and emit on exit. Same top-level shape as
/// `BENCH_engine.json` (see `util::bench::emit_bench_json`).
pub struct ServingBench {
    mode: String,
    batch: Option<BatchConfig>,
    faults: Option<FaultPlan>,
    rows: Vec<JsonRow>,
}

impl ServingBench {
    pub fn new(mode: &str) -> ServingBench {
        ServingBench { mode: mode.to_string(), batch: None, faults: None, rows: Vec::new() }
    }

    /// Stamp the LLM-lane batch config onto every row pushed from here on,
    /// so batched and unbatched runs landing in the same `BENCH_serving.json`
    /// stay distinguishable after the fact.
    pub fn set_batch(&mut self, cfg: BatchConfig) {
        self.batch = Some(cfg);
    }

    /// Stamp the chaos plan onto every row pushed from here on
    /// (`fault_seed` / `transient_prob` / `spike_prob` / `spike_ms`), so a
    /// row from a faulty run can never be compared against a clean run's
    /// row without the difference being visible in the JSON itself.
    pub fn set_faults(&mut self, plan: &FaultPlan) {
        self.faults = Some(plan.clone());
    }

    fn stamp(&self, row: JsonRow) -> JsonRow {
        let row = match self.batch {
            Some(cfg) => row
                .int("max_batch", cfg.max_batch as u64)
                .num("batch_window_ms", cfg.max_wait.as_secs_f64() * 1e3),
            None => row,
        };
        match &self.faults {
            Some(p) => row
                .int("fault_seed", p.seed)
                .num("transient_prob", p.transient_prob)
                .num("spike_prob", p.spike_prob)
                .num("spike_ms", p.spike.as_secs_f64() * 1e3),
            None => row,
        }
    }

    pub fn push(&mut self, name: &str, report: &ServeReport) {
        let row = self.stamp(serving_row(name, report));
        self.rows.push(row);
    }

    /// Push a pre-built row (e.g. [`multi_serving_row`]); the batch config
    /// stamp from [`ServingBench::set_batch`] still applies.
    pub fn push_row(&mut self, row: JsonRow) {
        let row = self.stamp(row);
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn emit(&self, path: &str) -> anyhow::Result<()> {
        crate::util::bench::emit_bench_json(path, "serving", &self.mode, &[], &self.rows)
    }
}

/// Shared `--bench-json [PATH]` flag for the table binaries: `None` when
/// absent, `Some(path)` (defaulting to `BENCH_serving.json`) when given.
pub fn bench_json_from_args(args: &crate::util::cli::Args) -> Option<String> {
    if let Some(p) = args.get("bench-json") {
        return Some(p.to_string());
    }
    if args.flag("bench-json") {
        return Some("BENCH_serving.json".to_string());
    }
    None
}

/// Standard env-tunable batch size for the harness binaries: the paper's
/// main tables use 100; `SUBGCACHE_BATCH` overrides for quick runs.
pub fn batch_from_env(default: usize) -> usize {
    std::env::var("SUBGCACHE_BATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Parse the shared `--cache-mb` / `--cache-entries` / `--host-cache-bytes`
/// / `--disk-cache-bytes` flags into a policy (one definition for every
/// binary that exposes the cache budget). `--host-cache-bytes 0` (the
/// default) disables the host tier: device evictions destroy the entry
/// instead of demoting it. `--disk-cache-bytes 0` (the default) likewise
/// disables the archive tier: host-budget deaths destroy the copy instead
/// of spilling it to disk.
pub fn cache_policy_from_args(args: &crate::util::cli::Args)
                              -> anyhow::Result<CachePolicy> {
    let d = CachePolicy::default();
    let max_bytes = match args.get("cache-mb") {
        Some(v) => {
            let mb: usize = v.parse().map_err(|_| {
                anyhow::anyhow!("bad --cache-mb '{v}' (expected a MiB integer)")
            })?;
            mb.checked_mul(1 << 20)
                .ok_or_else(|| anyhow::anyhow!("--cache-mb {mb} overflows the budget"))?
        }
        None => d.max_bytes,
    };
    let host_bytes = match args.get("host-cache-bytes") {
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("bad --host-cache-bytes '{v}' (expected a byte \
                             count; 0 disables the host tier)")
        })?,
        None => d.host_bytes,
    };
    let disk_bytes = match args.get("disk-cache-bytes") {
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("bad --disk-cache-bytes '{v}' (expected a byte \
                             count; 0 disables the disk archive tier)")
        })?,
        None => d.disk_bytes,
    };
    Ok(CachePolicy {
        max_bytes,
        max_entries: args.usize_or("cache-entries", d.max_entries),
        host_bytes,
        disk_bytes,
        ..d
    })
}

/// Parse the shared `--max-batch` / `--batch-window` (milliseconds) flags
/// into an LLM-lane [`BatchConfig`] (one definition for every binary that
/// exposes the micro-batcher). Defaults to batching off.
pub fn batch_config_from_args(args: &crate::util::cli::Args)
                              -> anyhow::Result<BatchConfig> {
    let max_batch: usize = match args.get("max-batch") {
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("bad --max-batch '{v}' (expected a positive integer)")
        })?,
        None => 1,
    };
    let wait_ms: f64 = match args.get("batch-window") {
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("bad --batch-window '{v}' (expected milliseconds)")
        })?,
        None => 0.0,
    };
    anyhow::ensure!(wait_ms.is_finite() && wait_ms >= 0.0,
                    "--batch-window must be a finite, non-negative ms value");
    Ok(BatchConfig::new(max_batch,
                        std::time::Duration::from_secs_f64(wait_ms / 1e3)))
}

/// Parse the shared `--fault-seed` / `--transient-prob` / `--spike-prob` /
/// `--spike-ms` chaos flags into a [`FaultPlan`] (one definition for every
/// binary that can inject faults). Defaults to the empty plan — no flags,
/// no injection. Probabilities must sit in [0, 1]; the spike duration must
/// be finite and non-negative.
pub fn fault_plan_from_args(args: &crate::util::cli::Args)
                            -> anyhow::Result<FaultPlan> {
    let prob = |name: &str| -> anyhow::Result<f64> {
        match args.get(name) {
            Some(v) => {
                let p: f64 = v.parse().map_err(|_| {
                    anyhow::anyhow!("bad --{name} '{v}' (expected a probability)")
                })?;
                anyhow::ensure!(p.is_finite() && (0.0..=1.0).contains(&p),
                                "--{name} must sit in [0, 1]");
                Ok(p)
            }
            None => Ok(0.0),
        }
    };
    let seed: u64 = match args.get("fault-seed") {
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("bad --fault-seed '{v}' (expected an integer seed)")
        })?,
        None => 0,
    };
    let spike_ms: f64 = match args.get("spike-ms") {
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("bad --spike-ms '{v}' (expected milliseconds)")
        })?,
        None => 0.0,
    };
    anyhow::ensure!(spike_ms.is_finite() && spike_ms >= 0.0,
                    "--spike-ms must be a finite, non-negative ms value");
    Ok(FaultPlan {
        seed,
        transient_prob: prob("transient-prob")?,
        spike_prob: prob("spike-prob")?,
        spike: std::time::Duration::from_secs_f64(spike_ms / 1e3),
        ..FaultPlan::none()
    })
}

/// Whether any chaos flag was given at all — binaries use this to decide
/// whether to stamp fault fields onto bench rows (absent flags keep rows
/// byte-identical to pre-chaos output).
pub fn fault_flags_present(args: &crate::util::cli::Args) -> bool {
    ["fault-seed", "transient-prob", "spike-prob", "spike-ms"]
        .iter()
        .any(|&f| args.get(f).is_some())
}

/// Backbone list filtered by `SUBGCACHE_BACKBONES` (comma separated).
pub fn backbones_from_env(store: &ArtifactStore) -> Vec<String> {
    let all: Vec<String> =
        store.manifest().llm_names().iter().map(|s| s.to_string()).collect();
    match std::env::var("SUBGCACHE_BACKBONES") {
        Ok(list) => {
            let want: Vec<String> = list.split(',').map(|s| s.trim().to_string()).collect();
            all.into_iter().filter(|b| want.contains(b)).collect()
        }
        Err(_) => all,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_clusters_match_paper() {
        assert_eq!(default_clusters("scene_graph"), 1);
        assert_eq!(default_clusters("oag"), 2);
    }

    #[test]
    fn retriever_lookup() {
        assert!(retriever_by_name("g-retriever").is_ok());
        assert!(retriever_by_name("grag").is_ok());
        assert!(retriever_by_name("gpt").is_err());
    }

    #[test]
    fn cell_defaults() {
        let c = Cell::new("oag", "grag", "bb", 50);
        assert_eq!(c.n_clusters, 2);
        assert_eq!(c.linkage, Linkage::Ward);
        assert_eq!(c.pipeline_depth, ServeConfig::default().pipeline_depth);
        assert!(c.cluster_ttl.is_none());
    }

    #[test]
    fn serving_row_carries_throughput_and_lane_fields() {
        let mut r = ServeReport::default();
        r.metrics.per_query.push(crate::metrics::QueryLatency::default());
        r.metrics.wall_time = 2.0;
        r.metrics.pipeline_depth = 2;
        let row = serving_row("online k=2", &r);
        assert_eq!(row.name, "online k=2");
        let keys: Vec<&str> = row.fields.iter().map(|(k, _)| k.as_str()).collect();
        for want in ["queries", "wall_s", "qps", "overlap_ms", "pipeline_depth",
                     "llm_lane_device_s", "llm_lane_window_s", "llm_device_calls",
                     "llm_fused_calls", "llm_mean_occupancy", "llm_window_stalls",
                     "gnn_lane_device_s", "shared_hits", "dedup_bytes_saved",
                     "demotions", "promotions", "host_hits", "host_bytes",
                     "released", "archived", "recalls", "disk_hits", "disk_bytes",
                     "lane_restarts", "retries", "quarantined", "deadline_hits",
                     "degraded_ms", "llm_queue_depth_peak", "llm_queue_depth_mean",
                     "admitted", "shed", "shed_deadline", "shed_overloaded",
                     "shed_brownout", "shed_rate", "brownout_spans", "brownout_ms",
                     "breaker_trips"] {
            assert!(keys.contains(&want), "missing field {want}");
        }
    }

    #[test]
    fn cache_policy_flag_forms() {
        let parse = |s: &str| crate::util::cli::Args::parse(
            s.split_whitespace().map(String::from));
        let d = CachePolicy::default();
        let off = cache_policy_from_args(&parse("")).unwrap();
        assert_eq!(off.host_bytes, d.host_bytes);
        assert_eq!(off.disk_bytes, d.disk_bytes);
        let p = cache_policy_from_args(
            &parse("--cache-mb 2 --host-cache-bytes 1000000 \
                    --disk-cache-bytes 5000000")).unwrap();
        assert_eq!(p.max_bytes, 2 << 20);
        assert_eq!(p.host_bytes, 1_000_000);
        assert_eq!(p.disk_bytes, 5_000_000);
        assert_eq!(p.shards, d.shards, "shard count keeps the default");
        assert!(cache_policy_from_args(&parse("--host-cache-bytes lots")).is_err());
        assert!(cache_policy_from_args(&parse("--disk-cache-bytes much")).is_err());
    }

    #[test]
    fn batch_config_flag_forms() {
        let parse = |s: &str| crate::util::cli::Args::parse(
            s.split_whitespace().map(String::from));
        let off = batch_config_from_args(&parse("")).unwrap();
        assert!(!off.enabled());
        let cfg = batch_config_from_args(&parse("--max-batch 4 --batch-window 2.5"))
            .unwrap();
        assert_eq!(cfg.max_batch, 4);
        assert_eq!(cfg.max_wait, std::time::Duration::from_micros(2500));
        assert!(batch_config_from_args(&parse("--max-batch nope")).is_err());
        assert!(batch_config_from_args(&parse("--batch-window -1")).is_err());
    }

    #[test]
    fn serving_bench_stamps_batch_config_on_rows() {
        let mut b = ServingBench::new("sim-quick");
        b.set_batch(BatchConfig::new(4, std::time::Duration::from_millis(2)));
        b.push("cell", &ServeReport::default());
        b.push_row(JsonRow::new("multi"));
        for row in &b.rows {
            let keys: Vec<&str> = row.fields.iter().map(|(k, _)| k.as_str()).collect();
            assert!(keys.contains(&"max_batch"), "missing max_batch on {}", row.name);
            assert!(keys.contains(&"batch_window_ms"),
                    "missing batch_window_ms on {}", row.name);
        }
        let stamped = b.rows[0].fields.iter()
            .find(|(k, _)| k == "max_batch").unwrap().1.clone();
        assert_eq!(stamped, "4");
    }

    #[test]
    fn multi_serving_row_carries_pool_and_contention_fields() {
        let mut m = MultiStreamReport::default();
        m.streams.push(ServeReport::default());
        m.streams.push(ServeReport::default());
        m.shared.prefills = 3;
        m.shared.shared_hits = 5;
        m.lock.acquisitions = 10;
        m.wall_time = 1.0;
        let row = multi_serving_row("online streams=2", &m);
        let keys: Vec<&str> = row.fields.iter().map(|(k, _)| k.as_str()).collect();
        for want in ["streams", "queries", "wall_s", "qps", "pool_prefills",
                     "shared_hits", "dedup_bytes_saved", "deferred_releases",
                     "demotions", "promotions", "host_hits", "host_bytes",
                     "released", "archived", "recalls", "disk_hits", "disk_bytes",
                     "lock_acquisitions", "lock_contended", "failed_streams",
                     "lane_restarts", "retries", "quarantined", "deadline_hits",
                     "degraded_ms", "admitted", "shed", "shed_deadline",
                     "shed_overloaded", "shed_brownout", "shed_rate",
                     "brownout_spans", "brownout_ms", "breaker_trips"] {
            assert!(keys.contains(&want), "missing field {want}");
        }
        assert!(multi_summary(&m).contains("2 streams"));
    }

    #[test]
    fn fault_plan_flag_forms() {
        let parse = |s: &str| crate::util::cli::Args::parse(
            s.split_whitespace().map(String::from));
        let none = fault_plan_from_args(&parse("")).unwrap();
        assert_eq!(none.seed, 0);
        assert_eq!(none.transient_prob, 0.0);
        assert!(!fault_flags_present(&parse("--streams 4")));
        let p = fault_plan_from_args(&parse(
            "--fault-seed 9 --transient-prob 0.2 --spike-prob 0.05 --spike-ms 1.5"))
            .unwrap();
        assert_eq!(p.seed, 9);
        assert!((p.transient_prob - 0.2).abs() < 1e-12);
        assert!((p.spike_prob - 0.05).abs() < 1e-12);
        assert_eq!(p.spike, std::time::Duration::from_micros(1500));
        assert!(fault_flags_present(&parse("--spike-ms 1")));
        assert!(fault_plan_from_args(&parse("--transient-prob 1.5")).is_err());
        assert!(fault_plan_from_args(&parse("--spike-ms -2")).is_err());
        assert!(fault_plan_from_args(&parse("--fault-seed xyz")).is_err());
    }

    #[test]
    fn serving_bench_stamps_fault_plan_on_rows() {
        let mut b = ServingBench::new("sim-chaos");
        b.push("clean", &ServeReport::default());
        b.set_faults(&FaultPlan {
            seed: 99,
            transient_prob: 0.25,
            spike_prob: 0.1,
            spike: std::time::Duration::from_millis(3),
            ..FaultPlan::none()
        });
        b.push("faulty", &ServeReport::default());
        let keys = |r: &JsonRow| -> Vec<String> {
            r.fields.iter().map(|(k, _)| k.clone()).collect()
        };
        assert!(!keys(&b.rows[0]).contains(&"fault_seed".to_string()),
                "rows pushed before set_faults stay unstamped");
        let faulty = keys(&b.rows[1]);
        for want in ["fault_seed", "transient_prob", "spike_prob", "spike_ms"] {
            assert!(faulty.contains(&want.to_string()), "missing stamp {want}");
        }
        let seed = b.rows[1].fields.iter()
            .find(|(k, _)| k == "fault_seed").unwrap().1.clone();
        assert_eq!(seed, "99");
    }

    #[test]
    fn bench_json_flag_forms() {
        let parse = |s: &str| crate::util::cli::Args::parse(
            s.split_whitespace().map(String::from));
        assert_eq!(bench_json_from_args(&parse("")), None);
        assert_eq!(bench_json_from_args(&parse("--x 1 --bench-json")),
                   Some("BENCH_serving.json".into()));
        assert_eq!(bench_json_from_args(&parse("--bench-json out.json")),
                   Some("out.json".into()));
    }

    #[test]
    fn serving_bench_collects_and_emits() {
        let mut b = ServingBench::new("sim-quick");
        assert!(b.is_empty());
        b.push("cell", &ServeReport::default());
        assert_eq!(b.len(), 1);
        let path = std::env::temp_dir().join("subgcache_serving_bench_test.json");
        b.emit(path.to_str().unwrap()).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(s.contains("\"bench\": \"serving\""));
        assert!(s.contains("\"mode\": \"sim-quick\""));
    }
}
