//! Experiment harness shared by the table/figure binaries and benches:
//! runs one (dataset × retriever × backbone × config) cell — baseline and
//! +SubGCache — and renders paper-style tables (DESIGN.md §3).

use crate::cluster::Linkage;
use crate::coordinator::{Coordinator, ServeConfig, ServeReport};
use crate::data::Dataset;
use crate::metrics::{delta, delta_cells, metric_cells, Table};
use crate::retrieval::{GRetriever, GragRetriever, Retriever};
use crate::runtime::{ArtifactStore, Engine};

/// The paper's default cluster counts per dataset (§4.3: Scene Graph shines
/// at c=1, OAG at c=2).
pub fn default_clusters(dataset: &str) -> usize {
    match dataset {
        "scene_graph" => 1,
        _ => 2,
    }
}

pub fn retriever_by_name(name: &str) -> anyhow::Result<Box<dyn Retriever>> {
    Ok(match name {
        "g-retriever" => Box::new(GRetriever::default()),
        "grag" => Box::new(GragRetriever::default()),
        other => anyhow::bail!("unknown retriever '{other}' (g-retriever | grag)"),
    })
}

/// One experiment cell specification.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: String,
    pub retriever: String,
    pub backbone: String,
    pub batch: usize,
    pub n_clusters: usize,
    pub linkage: Linkage,
    pub seed: u64,
}

impl Cell {
    pub fn new(dataset: &str, retriever: &str, backbone: &str, batch: usize) -> Cell {
        Cell {
            dataset: dataset.into(),
            retriever: retriever.into(),
            backbone: backbone.into(),
            batch,
            n_clusters: default_clusters(dataset),
            linkage: Linkage::Ward,
            seed: 7,
        }
    }
}

/// Baseline + SubGCache reports for one cell.
pub struct CellResult {
    pub cell: Cell,
    pub baseline: ServeReport,
    pub subgcache: ServeReport,
}

/// Run one cell (both methods on the identical query sample).
pub fn run_cell(store: &ArtifactStore, engine: &Engine, cell: &Cell)
                -> anyhow::Result<CellResult> {
    let ds = store.dataset(&cell.dataset)?;
    let retriever = retriever_by_name(&cell.retriever)?;
    let queries = ds.sample_test(cell.batch, cell.seed);
    anyhow::ensure!(!queries.is_empty(), "dataset {} has no test queries", cell.dataset);

    let cfg = ServeConfig {
        backbone: cell.backbone.clone(),
        n_clusters: cell.n_clusters,
        linkage: cell.linkage,
        gnn: None,
    };
    let coord = Coordinator::new(store, engine, cfg)?;
    let baseline = coord.serve_baseline(&ds, &queries, retriever.as_ref())?;
    let subgcache = coord.serve_subgcache(&ds, &queries, retriever.as_ref())?;
    Ok(CellResult { cell: cell.clone(), baseline, subgcache })
}

/// Render one retriever block of a paper table (method, +SubGCache, Δ rows).
pub fn push_block(t: &mut Table, label: &str, r: &CellResult) {
    t.row(&metric_cells(label, &r.baseline.metrics));
    t.row(&metric_cells(&format!("{label}+SubGCache"), &r.subgcache.metrics));
    t.row(&delta_cells(&format!("Δ_{label}"), &delta(&r.baseline.metrics,
                                                     &r.subgcache.metrics)));
}

pub const METRIC_HEADER: [&str; 5] = ["Model", "ACC↑", "RT↓(ms)", "TTFT↓(ms)", "PFTT↓(ms)"];

/// Standard env-tunable batch size for the harness binaries: the paper's
/// main tables use 100; `SUBGCACHE_BATCH` overrides for quick runs.
pub fn batch_from_env(default: usize) -> usize {
    std::env::var("SUBGCACHE_BATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Backbone list filtered by `SUBGCACHE_BACKBONES` (comma separated).
pub fn backbones_from_env(store: &ArtifactStore) -> Vec<String> {
    let all: Vec<String> =
        store.manifest().llm_names().iter().map(|s| s.to_string()).collect();
    match std::env::var("SUBGCACHE_BACKBONES") {
        Ok(list) => {
            let want: Vec<String> = list.split(',').map(|s| s.trim().to_string()).collect();
            all.into_iter().filter(|b| want.contains(b)).collect()
        }
        Err(_) => all,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_clusters_match_paper() {
        assert_eq!(default_clusters("scene_graph"), 1);
        assert_eq!(default_clusters("oag"), 2);
    }

    #[test]
    fn retriever_lookup() {
        assert!(retriever_by_name("g-retriever").is_ok());
        assert!(retriever_by_name("grag").is_ok());
        assert!(retriever_by_name("gpt").is_err());
    }

    #[test]
    fn cell_defaults() {
        let c = Cell::new("oag", "grag", "bb", 50);
        assert_eq!(c.n_clusters, 2);
        assert_eq!(c.linkage, Linkage::Ward);
    }
}
