//! Runtime layer: the artifact store (datasets, vocab, manifest, HLO,
//! weights produced once by `make artifacts`) and the PJRT [`Engine`] that
//! loads and executes the AOT-compiled HLO on the request path. Python never
//! runs here.

mod engine;
mod gnn;
mod manifest;

pub use engine::{CallTiming, Engine, EngineStats, KvHandle, PendingEncode, PendingExtend,
                 PendingGenerate, PendingKv, PendingPrefill};
pub use gnn::{pack_subgraph, PackedSubgraph};
pub use manifest::{ArgSpec, Constants, EntrySpec, LlmDims, Manifest, ModuleSpec, ParamSpec};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::data::Dataset;
use crate::tokenizer::Tokenizer;

struct Inner {
    root: PathBuf,
    manifest: Manifest,
    tokenizer: Tokenizer,
}

/// Read-only view over the `artifacts/` directory. Cheap to clone.
#[derive(Clone)]
pub struct ArtifactStore(Arc<Inner>);

impl ArtifactStore {
    pub fn open<P: AsRef<Path>>(root: P) -> anyhow::Result<ArtifactStore> {
        let root = root.as_ref().to_path_buf();
        anyhow::ensure!(
            root.join("manifest.json").exists(),
            "{} has no manifest.json — run `make artifacts` first",
            root.display()
        );
        let manifest = Manifest::load(&root.join("manifest.json"))?;
        let tokenizer = Tokenizer::load(&root.join("vocab.json"))?;
        anyhow::ensure!(
            tokenizer.padded_size() == manifest.constants.vocab,
            "vocab.json ({} -> padded {}) disagrees with manifest vocab {}",
            tokenizer.len(), tokenizer.padded_size(), manifest.constants.vocab
        );
        Ok(ArtifactStore(Arc::new(Inner { root, manifest, tokenizer })))
    }

    /// Locate the artifacts dir next to the current dir or its parents
    /// (lets examples run from anywhere inside the repo).
    pub fn discover() -> anyhow::Result<ArtifactStore> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Self::open(cand);
            }
            if !dir.pop() {
                anyhow::bail!("no artifacts/ directory found — run `make artifacts`");
            }
        }
    }

    pub fn root(&self) -> &Path {
        &self.0.root
    }

    pub fn manifest(&self) -> &Manifest {
        &self.0.manifest
    }

    pub fn constants(&self) -> &Constants {
        &self.0.manifest.constants
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.0.tokenizer
    }

    pub fn dataset(&self, name: &str) -> anyhow::Result<Dataset> {
        Dataset::load(&self.0.root.join("data").join(format!("{name}.json")))
    }

    pub fn golden(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        crate::util::json::parse_file(&self.0.root.join("golden").join(name))
    }
}

impl Engine {
    /// Spawn the engine thread for an artifact store.
    pub fn start(store: &ArtifactStore) -> anyhow::Result<Engine> {
        Engine::start_at(store.root().to_path_buf(), store.manifest().clone())
    }
}
