//! Runtime layer: the artifact store (datasets, vocab, manifest, HLO,
//! weights produced once by `make artifacts`) and the execution backends
//! that serve the request path. Python never runs here.
//!
//! # The lane model
//!
//! Execution is organized into **lanes** — independent worker threads with
//! their own FIFO request queues (see [`Lane`]):
//!
//! * [`Lane::Llm`] runs everything that touches a KV cache: `prefill`,
//!   `extend`, `generate`, and KV release. KV handles are created, read and
//!   destroyed only on this lane, so no KV bytes ever cross threads.
//! * [`Lane::Gnn`] runs subgraph `encode`s. It shares nothing with the LLM
//!   lane, so an encode submitted while a prefill is in flight genuinely
//!   overlaps — the lane split is what lets `serve_online` hide query
//!   *i+1*'s GNN encode under query *i*'s prefill/extend.
//!
//! Requests on one lane execute in submission order; across lanes there is
//! no ordering. Every submission returns a ticket ([`PendingPrefill`] et
//! al.) whose `wait`/`wait_timed` blocks for the reply; a lane whose worker
//! thread has died fails submissions and outstanding waits with an error
//! instead of hanging.
//!
//! # The host KV tier
//!
//! Backends may offer a second, host-side residency for KV caches:
//! [`Backend::demote_kv`] copies a device KV to host memory and frees the
//! device copy (returning a **host-tier handle**), and
//! [`Backend::submit_promote`] copies it back into fresh device buffers
//! (ticket: [`PendingPromote`]). The contract the cache layer builds on:
//!
//! * `demote_kv` consumes its handle either way — on error the device copy
//!   has already been released, so callers never leak.
//! * `submit_promote` *borrows* the host handle; the host copy is consumed
//!   only when the promotion succeeds, so after a `LaneDead` the caller
//!   still holds a valid host copy to retry or release.
//! * Host copies live outside any lane incarnation: a lane restart stales
//!   every device handle but leaves host-tier handles current
//!   ([`Backend::kv_current`] stays true), which is what lets quarantine
//!   spare them.
//! * Both moves run on the LLM lane as control traffic (never fused, never
//!   fault-rolled in the sim), and their copy cost is real lane wall time —
//!   [`SimLatency::host_copy_per_byte`] models it per KV byte; the PJRT
//!   engine pays the actual literal transfer.
//!
//! Backends without a host tier keep the trait defaults (`Fatal`), which
//! the cache layer treats as "demotion unavailable — evict to death".
//!
//! # Error taxonomy
//!
//! Every backend failure is a typed [`BackendError`], so callers branch on
//! kind instead of string-matching:
//!
//! * [`BackendError::Transient`] — one-off failure; the lane and all KV
//!   state are intact. Resubmitting the same request may succeed.
//! * [`BackendError::LaneDead`] — the lane worker died (or was restarted)
//!   with the request queued or in flight. Every KV handle minted by that
//!   incarnation is device-garbage: check cached handles with
//!   [`Backend::kv_current`], quarantine the stale ones, recompute.
//! * [`BackendError::Overloaded`] — the lane *refused* the submission for
//!   lack of capacity: its bounded queue ([`QueueConfig`]) is full, or its
//!   circuit breaker is open. Nothing was enqueued and no state changed —
//!   distinct from `Transient`, which is a failure of accepted work.
//! * [`BackendError::Fatal`] — terminal (missing entry point, malformed
//!   output); retrying fails identically.
//!
//! `is_retryable()` is the scheduler's branch: `Transient`, `LaneDead` and
//! `Overloaded` are retryable (`LaneDead` after recomputing lost KV), and
//! `Fatal` is not — but `Overloaded` is retryable **only with backoff**
//! (`is_overloaded()` is the sub-branch): hammering a full queue or an open
//! breaker with immediate resubmits is exactly the retry storm the overload
//! plane exists to stop. The coordinator's `RetryBudget` enforces a capped
//! exponential backoff on every `Overloaded` admission.
//!
//! # Bounded queues & the overload plane
//!
//! Each lane's submit path can be bounded by a [`QueueConfig`]: `capacity`
//! caps in-flight work requests per lane, and `full_policy` picks between
//! failing fast ([`FullPolicy::Reject`] → `Overloaded`) and blocking up to
//! a timeout ([`FullPolicy::Block`] → `Overloaded` only after the timeout —
//! a submit never blocks forever). Slots are taken at submit and released
//! at worker pickup; control traffic (release/warmup/stats) bypasses the
//! bound so backpressure can never deadlock cleanup. The live gauge is
//! [`Backend::queue_depth`], which serving samples into
//! [`crate::metrics::LaneTimes`]. The sim accepts a config via
//! [`SimBackend::start_guarded`]; the PJRT engine reads
//! `SUBGCACHE_QUEUE_CAP` / `SUBGCACHE_QUEUE_BLOCK_MS` at startup, next to
//! its `SUBGCACHE_MAX_BATCH` batching vars.
//!
//! # Circuit breaker
//!
//! [`SimBackend::start_guarded`] can also arm a per-lane circuit breaker
//! ([`BreakerConfig`]): K consecutive `Transient` failures within a rolling
//! window trip the lane open — submissions then fail fast as `Overloaded`
//! (no queueing, no device work) until a cooldown elapses, after which one
//! half-open probe submission is admitted; its success closes the breaker,
//! another transient re-opens it. The breaker observes *results* only — it
//! never advances the fault plan's op counters, so arming it does not
//! perturb seeded chaos schedules. Trips are counted in
//! [`EngineStats::breaker_trips`] and surface as
//! `ReliabilityStats::breaker_trips` deltas on serving reports. The PJRT
//! engine has no breaker (no supervisor: lane death is terminal there, so
//! there is no sick-but-alive state to protect).
//!
//! # Lane supervision
//!
//! [`SimBackend`] runs each lane under a supervisor: when a lane worker
//! dies with restart budget remaining ([`SupervisorPolicy`] — capped
//! exponential backoff, bounded restart count), the supervisor fails every
//! pending ticket with `LaneDead`, re-warms the lane, bumps the lane's KV
//! *generation* (so [`Backend::kv_current`] reports pre-death handles
//! stale) and resumes service; requests submitted after the restart run
//! normally. A lane that exhausts its budget is condemned: everything
//! fails fast with `LaneDead`, nothing hangs. The PJRT [`Engine`] has no
//! supervisor today — its `kv_current` keeps the default "always current",
//! which makes caller-side quarantine a safe no-op there.
//!
//! # Injecting faults in a test
//!
//! [`FaultPlan`] makes failure deterministic: start the sim with
//! [`SimBackend::start_faulty`] and a seeded plan — `kill_llm_at_op(n)`
//! kills the LLM lane worker at its n-th executed op (the supervisor then
//! restarts it), `transient_prob` injects seeded `Transient` reply
//! failures *without* executing the op (so a retry is bit-identical), and
//! `spike_prob`/`spike` stretches latencies. Assert recovery through
//! [`SimBackend::lane_restarts`] / injected-fault counters and through the
//! coordinator's `ReliabilityStats`; `rust/tests/chaos.rs` holds the
//! worked examples.
//!
//! # Continuous micro-batching
//!
//! With a [`BatchConfig`] (`max_batch`, `max_wait`) the LLM lane worker
//! becomes a micro-batcher, entirely *below* the `Backend` ticket API —
//! schedulers and callers are unchanged. The contract:
//!
//! * **Compatibility rule** — two requests may share a fused device call
//!   iff they have the same op kind AND the same module (backbone): N
//!   `extend`s against different cached KVs fuse, an `extend` never fuses
//!   with a `prefill` or with another backbone's ops, and control traffic
//!   (release/warmup/stats) never fuses. An incompatible arrival closes the
//!   open window early and runs right after the batch (lane FIFO holds).
//! * **Timing attribution** — each member's [`CallTiming`] splits
//!   submit→reply into `queue_secs` (channel wait until pickup),
//!   `window_secs` (residency in the open batch window until launch) and
//!   `device_secs` (the batch's device span, attributed to every member).
//!   Exactly one member per launch is the [`BatchInfo::leader`]; aggregates
//!   (`metrics::LaneTimes`) count device time and occupancy through
//!   leaders only, so lane-busy sums never double-count a fused call.
//! * **Fallback counting** — a multi-member batch whose op has no batched
//!   HLO entry executes as a per-member loop and increments
//!   [`EngineStats::unbatched_fallbacks`] (the sim fuses everything and
//!   always reports 0).
//!
//! See `runtime/batch.rs` for the window mechanics and `runtime/engine.rs`
//! for the fused-HLO ABI (`prefill_batch<n>`).
//!
//! # The `Backend` contract
//!
//! [`Backend`] names the exact execution surface the coordinator consumes —
//! the four submit ops, release, KV byte sizing, warmup and stats — so
//! serving/scheduling logic is written against the trait, not a concrete
//! engine. Two implementations exist:
//!
//! * [`Engine`] — the production PJRT backend: one PJRT client, executable
//!   set and weight/KV buffer store per lane, zero-copy device-resident KV
//!   (see `engine.rs` for the HLO/transfer details).
//! * [`SimBackend`] — a deterministic simulator with configurable per-op
//!   virtual latencies ([`SimLatency`]) and hash-based but
//!   composition-faithful model outputs. It exists so pipeline ordering,
//!   lane overlap, pin-safety under eviction and hit/miss TTFT composition
//!   can be asserted in plain `cargo test` on a fresh clone.
//!
//! # Writing a SimBackend test
//!
//! Build the in-memory world with [`sim_store`] + [`sim_dataset`], start a
//! [`SimBackend`] with the latency profile your assertion needs (zero for
//! functional checks, a few ms per op for overlap/wall-time checks), and
//! drive the coordinator exactly as production code would — see the worked
//! example in `runtime/sim.rs`'s module docs and `rust/tests/sim_serving.rs`
//! for full scenarios.

mod backend;
mod batch;
mod engine;
mod gnn;
mod manifest;
mod sim;

pub use backend::{Backend, BackendError, CallTiming, EngineStats, FullPolicy, KvHandle,
                  Lane, PendingEncode, PendingExtend, PendingGenerate, PendingKv,
                  PendingPrefill, PendingPromote, QueueConfig};
pub use batch::{BatchConfig, BatchInfo};
pub use engine::Engine;
pub use gnn::{pack_subgraph, PackedSubgraph};
pub use manifest::{ArgSpec, Constants, EntrySpec, LlmDims, Manifest, ModuleSpec, ParamSpec};
pub use sim::{sim_dataset, sim_store, BatchSlope, BreakerConfig, FaultPlan, SimBackend,
              SimLatency, SupervisorPolicy, SIM_BACKBONE};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::data::Dataset;
use crate::tokenizer::Tokenizer;

struct Inner {
    root: PathBuf,
    manifest: Manifest,
    tokenizer: Tokenizer,
}

/// Read-only view over the `artifacts/` directory. Cheap to clone.
#[derive(Clone)]
pub struct ArtifactStore(Arc<Inner>);

impl ArtifactStore {
    pub fn open<P: AsRef<Path>>(root: P) -> anyhow::Result<ArtifactStore> {
        let root = root.as_ref().to_path_buf();
        anyhow::ensure!(
            root.join("manifest.json").exists(),
            "{} has no manifest.json — run `make artifacts` first",
            root.display()
        );
        let manifest = Manifest::load(&root.join("manifest.json"))?;
        let tokenizer = Tokenizer::load(&root.join("vocab.json"))?;
        anyhow::ensure!(
            tokenizer.padded_size() == manifest.constants.vocab,
            "vocab.json ({} -> padded {}) disagrees with manifest vocab {}",
            tokenizer.len(), tokenizer.padded_size(), manifest.constants.vocab
        );
        Ok(ArtifactStore(Arc::new(Inner { root, manifest, tokenizer })))
    }

    /// Purely in-memory store (no files): the backing for [`sim_store`] and
    /// any test that fabricates its own manifest + vocab. Disk-backed
    /// queries ([`ArtifactStore::dataset`], [`ArtifactStore::golden`]) will
    /// fail on such a store — sim runs build their datasets with
    /// [`sim_dataset`] instead.
    pub fn in_memory(manifest: Manifest, tokenizer: Tokenizer) -> ArtifactStore {
        assert_eq!(tokenizer.padded_size(), manifest.constants.vocab,
                   "in-memory vocab disagrees with manifest vocab");
        ArtifactStore(Arc::new(Inner {
            root: PathBuf::from("<in-memory>"),
            manifest,
            tokenizer,
        }))
    }

    /// Locate the artifacts dir next to the current dir or its parents
    /// (lets examples run from anywhere inside the repo).
    pub fn discover() -> anyhow::Result<ArtifactStore> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Self::open(cand);
            }
            if !dir.pop() {
                anyhow::bail!("no artifacts/ directory found — run `make artifacts`");
            }
        }
    }

    pub fn root(&self) -> &Path {
        &self.0.root
    }

    pub fn manifest(&self) -> &Manifest {
        &self.0.manifest
    }

    pub fn constants(&self) -> &Constants {
        &self.0.manifest.constants
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.0.tokenizer
    }

    pub fn dataset(&self, name: &str) -> anyhow::Result<Dataset> {
        Dataset::load(&self.0.root.join("data").join(format!("{name}.json")))
    }

    pub fn golden(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        crate::util::json::parse_file(&self.0.root.join("golden").join(name))
    }
}

impl Engine {
    /// Spawn the engine lane threads for an artifact store (LLM-lane batch
    /// config from the environment; see [`Engine::start_at`]).
    pub fn start(store: &ArtifactStore) -> anyhow::Result<Engine> {
        Engine::start_at(store.root().to_path_buf(), store.manifest().clone())
    }

    /// Spawn the engine lane threads with an explicit LLM-lane
    /// [`BatchConfig`].
    pub fn start_with(store: &ArtifactStore, cfg: BatchConfig) -> anyhow::Result<Engine> {
        Engine::start_at_with(store.root().to_path_buf(), store.manifest().clone(), cfg)
    }
}
