//! PJRT execution engine: device-resident KV caches behind the ticketed
//! [`Backend`] submit/wait API, executed on per-lane worker threads.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each
//! **lane** is a dedicated worker thread that owns its own client, its
//! lazily-compiled executables, weight buffers and — on the LLM lane — the
//! resident KV caches; the rest of the system talks to the lanes over
//! channels. Two lanes exist ([`Lane::Llm`] and [`Lane::Gnn`]): prefill /
//! extend / generate execute on the LLM lane (they share KV state, which
//! never crosses lanes), GNN encodes on their own lane. A GNN encode
//! submitted while an LLM prefill is in flight therefore runs concurrently
//! instead of queueing behind it — the overlap `serve_online` exploits.
//! Requests on one lane execute in FIFO submission order.
//!
//! # Zero-copy KV
//!
//! `prefill`/`extend` keep their K/V outputs **on the device**: when PJRT
//! hands back the executable's root tuple as one buffer per leaf (the
//! flattened form), the K and V buffers go straight into the LLM lane's
//! handle map without ever visiting the host. Only logits travel host-ward:
//! prefill's HLO already emits the single `[V]` next-token row (selected by
//! `plen` on device); extend's `[Q,V]` matrix crosses to the host once, the
//! lane slices the `qlen` row there, and only `[V]` floats go over the
//! reply channel (moving that slice into the HLO is a documented ROADMAP
//! follow-on). If the binding instead returns a single tuple-shaped buffer,
//! the only untuple path it offers runs through a host literal — that
//! fallback (the seed's original behaviour) is kept, and every KV byte it
//! bounces is counted in [`EngineStats::host_kv_bytes`] so the regression is
//! visible. `SUBGCACHE_KV_HOST_BOUNCE=1` forces the bounce for parity
//! testing.
//!
//! # Host KV tier
//!
//! [`Engine::demote_kv`] copies a device KV's k/v buffers to host literals
//! on the LLM lane and frees the device copy; [`Engine::submit_promote`]
//! re-uploads them into fresh device buffers later. Both moves are control
//! traffic (never fused) and their copy cost lands in lane wall time.
//! Demotion bytes are deliberately *not* counted in
//! [`EngineStats::host_kv_bytes`]: that counter flags the tuple-literal
//! store *fallback* regression, while a demotion is an intentional tier
//! move requested by the cache layer.
//!
//! # Submit/wait
//!
//! Every execute request can be issued without blocking: `submit_prefill` /
//! `submit_extend` / `submit_generate` / `submit_encode` enqueue the call
//! on its lane and return a ticket. The caller overlaps host work (and the
//! other lane's device work) with execution and collects the result with
//! `wait` (or `wait_timed`, which adds the lane-side [`CallTiming`]).
//! Dropping an unawaited KV-producing ticket abandons its handle until
//! engine shutdown (a bounded leak, same class as an error-path unwind), so
//! pipelined callers should always wait. A lane whose worker thread has
//! died fails `submit_*` (send error) and outstanding `wait`s (dropped
//! reply sender) with an error instead of hanging.
//!
//! KV caches never leave the LLM lane: `prefill`/`extend` return opaque
//! [`KvHandle`]s that later calls reference, so the coordinator moves tokens
//! and one logits row per call. Environment flags (`SUBGCACHE_TRACE`,
//! `SUBGCACHE_KV_HOST_BOUNCE`) are read once at [`Engine::start_at`] on the
//! caller's thread — never on the hot path.
//!
//! # Micro-batching
//!
//! With a [`BatchConfig`] (explicit via [`Engine::start_at_with`], or from
//! `SUBGCACHE_MAX_BATCH` / `SUBGCACHE_BATCH_WAIT_MS`), the LLM lane drains
//! its queue under a time/size window and fuses compatible requests (same
//! op + module) into one device call — see [`crate::runtime::batch`] for
//! the full contract. Ops with a batched HLO entry (`prefill_batch<n>`)
//! execute genuinely fused; a multi-member batch without one runs as a
//! per-member loop and increments [`EngineStats::unbatched_fallbacks`].
//!
//! # Bounded queues
//!
//! Each lane's submit path runs through the same [`QueueConfig`] contract
//! as the sim backend: `SUBGCACHE_QUEUE_CAP` bounds the number of *work*
//! requests (prefill/extend/generate/encode) queued per lane, and
//! `SUBGCACHE_QUEUE_BLOCK_MS` selects the `Block{timeout}` full policy
//! (unset = `Reject`). A full queue fails the submit with
//! [`BackendError::Overloaded`] — retryable only with backoff — instead of
//! growing the mpsc channel without bound. Control traffic
//! (release/demote/promote/warmup/stats/shutdown) always bypasses the
//! bound so the cache and stats planes keep working under overload. A
//! queued request occupies its slot until the lane worker picks it into a
//! batch window, so [`Backend::queue_depth`] gauges waiting work, not
//! in-flight work. The engine has **no circuit breaker**: unlike the sim
//! backend it has no lane supervisor, so a sick lane is terminal
//! ([`BackendError::LaneDead`]) rather than a transient source worth
//! tripping on.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::backend::{merge_stats, Backend, BackendError, CallTiming, EngineStats,
                     KvHandle, Lane, PendingEncode, PendingExtend, PendingGenerate,
                     PendingKv, PendingPrefill, PendingPromote, QueueConfig, QueueGate,
                     Ticket};
use super::batch::{collect_window, BatchConfig, BatchInfo, Collected};
use super::manifest::{EntrySpec, Manifest, ModuleSpec};

type KvReply = Sender<Result<(u64, Vec<f32>, CallTiming), BackendError>>;

enum Req {
    Prefill {
        module: String,
        tokens: Vec<i32>,
        plen: i32,
        submitted: Instant,
        reply: KvReply,
    },
    Extend {
        module: String,
        kv: u64,
        plen: i32,
        q_tokens: Vec<i32>,
        qlen: i32,
        submitted: Instant,
        reply: KvReply,
    },
    Generate {
        module: String,
        kv: u64,
        cur_len: i32,
        first_tok: i32,
        submitted: Instant,
        reply: Sender<Result<(Vec<i32>, CallTiming), BackendError>>,
    },
    Encode {
        module: String,
        x: Vec<f32>,
        adj: Vec<f32>,
        mask: Vec<f32>,
        submitted: Instant,
        reply: Sender<Result<(Vec<f32>, CallTiming), BackendError>>,
    },
    Release {
        kv: u64,
    },
    ReleaseMany {
        kvs: Vec<u64>,
    },
    /// Copy a device KV's k/v buffers to host literals, free the device
    /// copy, and hand back a host-tier id (control traffic: never fuses).
    Demote {
        kv: u64,
        submitted: Instant,
        reply: Sender<Result<(u64, CallTiming), BackendError>>,
    },
    /// Re-upload a host-tier KV's literals to fresh device buffers; the
    /// host copy is consumed only on success.
    Promote {
        host: u64,
        submitted: Instant,
        reply: Sender<Result<(u64, CallTiming), BackendError>>,
    },
    /// Serialize a host-tier KV to archive bytes, consuming the host copy
    /// either way (control traffic: never fuses).
    Archive {
        host: u64,
        reply: Sender<Result<Vec<u8>, BackendError>>,
    },
    /// Rebuild a host-tier KV from archive bytes, minting a fresh host id.
    Recall {
        bytes: Vec<u8>,
        reply: Sender<Result<u64, BackendError>>,
    },
    Warmup {
        module: String,
        reply: Sender<Result<(), BackendError>>,
    },
    Stats {
        reply: Sender<EngineStats>,
    },
    Shutdown,
}

/// Flags resolved once at engine start (no hot-path env lookups).
#[derive(Debug, Clone, Copy)]
struct EngineOpts {
    trace: bool,
    host_bounce: bool,
}

/// One worker lane: its request sender plus the join handle.
struct LaneHandle {
    tx: Sender<Req>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Thread-safe handle to the per-lane engine workers. Request senders are
/// held directly (mpsc senders are `Send` + `Sync` over `Send` payloads), so
/// enqueuing a call costs one channel push — no lock, no poisoned-mutex
/// failure mode.
pub struct Engine {
    /// Indexed by `Lane as usize` ([`Lane::Llm`] = 0, [`Lane::Gnn`] = 1).
    lanes: [LaneHandle; 2],
    /// Per-lane admission gates bounding queued *work* requests (shared
    /// with each lane worker, which frees slots at batch pickup).
    gates: [Arc<QueueGate>; 2],
    /// Copy of the manifest kept on the handle side so byte-sizing and
    /// lane-routing queries need no worker-thread roundtrip.
    manifest: Manifest,
}

/// Default [`BatchConfig`] from the environment (`SUBGCACHE_MAX_BATCH`,
/// `SUBGCACHE_BATCH_WAIT_MS`); batching off when unset/unparsable.
fn batch_config_from_env() -> BatchConfig {
    let max_batch = std::env::var("SUBGCACHE_MAX_BATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let wait_ms = std::env::var("SUBGCACHE_BATCH_WAIT_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0u64);
    BatchConfig::new(max_batch, Duration::from_millis(wait_ms))
}

/// Default per-lane [`QueueConfig`] from the environment: unbounded unless
/// `SUBGCACHE_QUEUE_CAP` sets a capacity; `SUBGCACHE_QUEUE_BLOCK_MS`
/// selects the blocking full policy (otherwise a full queue rejects).
fn queue_config_from_env() -> QueueConfig {
    queue_config_from(
        std::env::var("SUBGCACHE_QUEUE_CAP").ok().as_deref(),
        std::env::var("SUBGCACHE_QUEUE_BLOCK_MS").ok().as_deref(),
    )
}

/// Pure core of [`queue_config_from_env`]: unset/unparsable/zero capacity
/// means unbounded (the seed's behaviour); a capacity with no (or
/// unparsable) block window means reject-when-full.
fn queue_config_from(cap: Option<&str>, block_ms: Option<&str>) -> QueueConfig {
    let cap: usize = cap.and_then(|v| v.parse().ok()).unwrap_or(0);
    if cap == 0 {
        return QueueConfig::unbounded();
    }
    match block_ms.and_then(|v| v.parse::<u64>().ok()) {
        Some(ms) => QueueConfig::block(cap, Duration::from_millis(ms)),
        None => QueueConfig::reject(cap),
    }
}

impl Engine {
    /// Spawn both lane worker threads over an artifact directory. The LLM
    /// lane's batch config comes from the environment
    /// (`SUBGCACHE_MAX_BATCH` / `SUBGCACHE_BATCH_WAIT_MS`; off when unset).
    pub fn start_at(root: PathBuf, manifest: Manifest) -> anyhow::Result<Engine> {
        let cfg = batch_config_from_env();
        Engine::start_at_with(root, manifest, cfg)
    }

    /// Like [`start_at`](Self::start_at) with an explicit LLM-lane batch
    /// config (the GNN lane never batches).
    pub fn start_at_with(root: PathBuf, manifest: Manifest, cfg: BatchConfig)
                         -> anyhow::Result<Engine> {
        // Environment is read here, once, on the caller's thread: hot-path
        // calls never touch the environment, and tests can flip the flags
        // between engine starts without racing the worker threads.
        let opts = EngineOpts {
            trace: std::env::var("SUBGCACHE_TRACE").is_ok(),
            host_bounce: std::env::var("SUBGCACHE_KV_HOST_BOUNCE").is_ok(),
        };
        let queue = queue_config_from_env();
        let gates = [Arc::new(QueueGate::new(queue)), Arc::new(QueueGate::new(queue))];
        let spawn = |lane: Lane| -> anyhow::Result<LaneHandle> {
            let (tx, rx) = channel::<Req>();
            let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
            let root = root.clone();
            let thread_manifest = manifest.clone();
            let lane_cfg = if lane == Lane::Llm { cfg } else { BatchConfig::off() };
            let gate = gates[lane as usize].clone();
            let thread = std::thread::Builder::new()
                .name(format!("pjrt-{}", lane.name()))
                .spawn(move || {
                    lane_main(root, thread_manifest, opts, lane_cfg, gate, rx, ready_tx)
                })?;
            ready_rx.recv().map_err(|_| {
                anyhow::anyhow!("engine {} lane died during startup", lane.name())
            })??;
            Ok(LaneHandle { tx, thread: Some(thread) })
        };
        let llm = spawn(Lane::Llm)?;
        let gnn = spawn(Lane::Gnn)?;
        Ok(Engine { lanes: [llm, gnn], gates, manifest })
    }

    /// Lane a module executes on, derived from its manifest kind.
    fn lane_for_module(&self, module: &str) -> Result<Lane, BackendError> {
        let kind = &self
            .manifest
            .module(module)
            .map_err(BackendError::from_anyhow)?
            .kind;
        lane_for_kind(kind).ok_or_else(|| {
            BackendError::fatal(format!("module {module}: no lane for its kind"))
        })
    }

    /// Enqueue a request on a lane. Work requests (the fusible ops) pass
    /// the lane's admission gate first: a full bounded queue yields
    /// [`BackendError::Overloaded`] without enqueuing anything, while
    /// control traffic always goes through. A dead lane yields
    /// [`BackendError::LaneDead`] (failing the one request) instead of
    /// panicking the caller's thread; the PJRT engine has no supervisor
    /// today, so lane death is terminal here.
    fn send(&self, lane: Lane, req: Req) -> Result<(), BackendError> {
        let is_work = req_key(&req).is_some();
        if is_work {
            self.gates[lane as usize].admit(lane)?;
        }
        let sent = self.lanes[lane as usize].tx.send(req).map_err(|_| {
            BackendError::lane_dead(
                lane,
                format!("engine {} lane worker has shut down", lane.name()),
            )
        });
        if is_work && sent.is_err() {
            self.gates[lane as usize].release(1);
        }
        sent
    }

    /// Submit a prefill of `tokens` (padded to S, real length `plen`) on
    /// the LLM lane without blocking; the ticket yields the new KV handle
    /// and the next-token logits row after position `plen - 1`.
    pub fn submit_prefill(&self, module: &str, tokens: &[i32], plen: i32)
                          -> Result<PendingPrefill, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Prefill {
            module: module.into(), tokens: tokens.to_vec(), plen,
            submitted: Instant::now(), reply,
        })?;
        Ok(PendingKv(Ticket { rx, lane: Lane::Llm }))
    }

    /// Blocking prefill: [`Engine::submit_prefill`] + wait.
    pub fn prefill(&self, module: &str, tokens: &[i32], plen: i32)
                   -> Result<(KvHandle, Vec<f32>), BackendError> {
        self.submit_prefill(module, tokens, plen)?.wait()
    }

    /// Submit an extend of `q_tokens` (padded to Q, real length `qlen`) at
    /// position `plen` on top of `kv` (which is NOT consumed — it stays
    /// reusable, the SubGCache property) without blocking. The ticket yields
    /// a new handle and the `[V]` logits row after the last real question
    /// token (row `qlen - 1`, clamped — an empty question selects row 0
    /// instead of panicking).
    pub fn submit_extend(&self, module: &str, kv: &KvHandle, plen: i32,
                         q_tokens: &[i32], qlen: i32)
                         -> Result<PendingExtend, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Extend {
            module: module.into(), kv: kv.0, plen, q_tokens: q_tokens.to_vec(), qlen,
            submitted: Instant::now(), reply,
        })?;
        Ok(PendingKv(Ticket { rx, lane: Lane::Llm }))
    }

    /// Blocking extend: [`Engine::submit_extend`] + wait.
    pub fn extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32],
                  qlen: i32) -> Result<(KvHandle, Vec<f32>), BackendError> {
        self.submit_extend(module, kv, plen, q_tokens, qlen)?.wait()
    }

    /// Submit a greedy decode of up to G tokens starting from `first_tok`
    /// at `cur_len`. `kv` is not consumed.
    pub fn submit_generate(&self, module: &str, kv: &KvHandle, cur_len: i32,
                           first_tok: i32) -> Result<PendingGenerate, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Generate {
            module: module.into(), kv: kv.0, cur_len, first_tok,
            submitted: Instant::now(), reply,
        })?;
        Ok(PendingGenerate(Ticket { rx, lane: Lane::Llm }))
    }

    /// Blocking generate: [`Engine::submit_generate`] + wait.
    pub fn generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                    -> Result<Vec<i32>, BackendError> {
        self.submit_generate(module, kv, cur_len, first_tok)?.wait()
    }

    /// Submit a GNN subgraph embedding — x [N,F], adj [N,N], mask [N]
    /// (row-major flat) — on the GNN lane without blocking.
    pub fn submit_encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>,
                         mask: Vec<f32>) -> Result<PendingEncode, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Gnn, Req::Encode {
            module: module.into(), x, adj, mask, submitted: Instant::now(), reply,
        })?;
        Ok(PendingEncode(Ticket { rx, lane: Lane::Gnn }))
    }

    /// Blocking encode: [`Engine::submit_encode`] + wait.
    pub fn encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
                  -> Result<Vec<f32>, BackendError> {
        self.submit_encode(module, x, adj, mask)?.wait()
    }

    /// Demote a device KV cache to the LLM lane's host tier: its k/v
    /// buffers cross to host literals, the device copy is freed, and the
    /// returned host handle can later be promoted back (or released). The
    /// copy runs on the LLM lane, so its cost lands in lane wall time like
    /// any other call. On error the device copy is already gone — the
    /// handle is consumed either way.
    pub fn demote_kv(&self, kv: KvHandle) -> Result<KvHandle, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Demote {
            kv: kv.0, submitted: Instant::now(), reply,
        })?;
        let (id, _t) = (Ticket { rx, lane: Lane::Llm }).wait()?;
        Ok(KvHandle(id))
    }

    /// Submit a host→device promotion of a handle minted by
    /// [`Engine::demote_kv`] on the LLM lane without blocking. The host
    /// literals are consumed only when the re-upload succeeds.
    pub fn submit_promote(&self, kv: &KvHandle) -> Result<PendingPromote, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Promote {
            host: kv.0, submitted: Instant::now(), reply,
        })?;
        Ok(PendingPromote(Ticket { rx, lane: Lane::Llm }))
    }

    /// Serialize a host-tier KV (minted by [`Engine::demote_kv`]) to
    /// archive bytes on the LLM lane, freeing the host copy either way.
    pub fn archive_kv(&self, kv: KvHandle) -> Result<Vec<u8>, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Archive { host: kv.0, reply })?;
        Ticket { rx, lane: Lane::Llm }.wait()
    }

    /// Rebuild a host-tier KV handle from [`Engine::archive_kv`] bytes on
    /// the LLM lane; feed it to [`Engine::submit_promote`] to finish the
    /// disk → host → device recall walk.
    pub fn recall_kv(&self, bytes: &[u8]) -> Result<KvHandle, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, Req::Recall { bytes: bytes.to_vec(), reply })?;
        Ok(KvHandle(Ticket { rx, lane: Lane::Llm }.wait()?))
    }

    /// Return a KV cache to the engine (KV lives on the LLM lane).
    /// Best-effort: a dead lane has already dropped its device buffers, so
    /// failure to enqueue is ignored.
    pub fn release(&self, kv: KvHandle) {
        let _ = self.send(Lane::Llm, Req::Release { kv: kv.0 });
    }

    /// Return a batch of KV caches in one lane message (the cache layer's
    /// eviction/drain path). Best-effort, like [`Engine::release`].
    pub fn release_many(&self, kvs: Vec<KvHandle>) {
        if kvs.is_empty() {
            return;
        }
        let _ = self.send(Lane::Llm, Req::ReleaseMany {
            kvs: kvs.into_iter().map(|h| h.0).collect(),
        });
    }

    /// Resident bytes of one KV cache of `module` (k + v buffers, f32),
    /// sized from the manifest. Errors for non-LLM modules.
    pub fn kv_bytes(&self, module: &str) -> Result<usize, BackendError> {
        let dims = self
            .manifest
            .module(module)
            .map_err(BackendError::from_anyhow)?
            .dims
            .ok_or_else(|| {
                BackendError::fatal(format!("{module}: not an llm module, no KV geometry"))
            })?;
        Ok(2 * dims.kv_bytes_each())
    }

    /// Load weights + compile all entries of `module` ahead of timing runs,
    /// on the lane the module executes on.
    pub fn warmup(&self, module: &str) -> Result<(), BackendError> {
        let lane = self.lane_for_module(module)?;
        let (reply, rx) = channel();
        self.send(lane, Req::Warmup { module: module.into(), reply })?;
        Ticket { rx, lane }.wait()
    }

    /// Merged execution counters across both lanes.
    pub fn stats(&self) -> Result<EngineStats, BackendError> {
        let mut parts = Vec::with_capacity(Lane::ALL.len());
        for lane in Lane::ALL {
            let (reply, rx) = channel();
            self.send(lane, Req::Stats { reply })?;
            parts.push(rx.recv().map_err(|_| {
                BackendError::lane_dead(
                    lane,
                    format!("engine {} lane died before replying to stats", lane.name()),
                )
            })?);
        }
        Ok(merge_stats(parts))
    }
}

impl Backend for Engine {
    fn submit_prefill(&self, module: &str, tokens: &[i32], plen: i32)
                      -> Result<PendingPrefill, BackendError> {
        Engine::submit_prefill(self, module, tokens, plen)
    }

    fn submit_extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32],
                     qlen: i32) -> Result<PendingExtend, BackendError> {
        Engine::submit_extend(self, module, kv, plen, q_tokens, qlen)
    }

    fn submit_generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                       -> Result<PendingGenerate, BackendError> {
        Engine::submit_generate(self, module, kv, cur_len, first_tok)
    }

    fn submit_encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
                     -> Result<PendingEncode, BackendError> {
        Engine::submit_encode(self, module, x, adj, mask)
    }

    fn release(&self, kv: KvHandle) {
        Engine::release(self, kv)
    }

    fn demote_kv(&self, kv: KvHandle) -> Result<KvHandle, BackendError> {
        Engine::demote_kv(self, kv)
    }

    fn submit_promote(&self, kv: &KvHandle) -> Result<PendingPromote, BackendError> {
        Engine::submit_promote(self, kv)
    }

    fn archive_kv(&self, kv: KvHandle) -> Result<Vec<u8>, BackendError> {
        Engine::archive_kv(self, kv)
    }

    fn recall_kv(&self, bytes: &[u8]) -> Result<KvHandle, BackendError> {
        Engine::recall_kv(self, bytes)
    }

    fn release_many(&self, kvs: Vec<KvHandle>) {
        Engine::release_many(self, kvs)
    }

    fn kv_bytes(&self, module: &str) -> Result<usize, BackendError> {
        Engine::kv_bytes(self, module)
    }

    fn warmup(&self, module: &str) -> Result<(), BackendError> {
        Engine::warmup(self, module)
    }

    fn stats(&self) -> Result<EngineStats, BackendError> {
        Engine::stats(self)
    }

    fn queue_depth(&self, lane: Lane) -> usize {
        self.gates[lane as usize].depth()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for lane in &mut self.lanes {
            let _ = lane.tx.send(Req::Shutdown);
        }
        for lane in &mut self.lanes {
            if let Some(t) = lane.thread.take() {
                let _ = t.join();
            }
        }
    }
}

/// Lane routing by manifest module kind (shared with the sim backend).
pub(crate) fn lane_for_kind(kind: &str) -> Option<Lane> {
    match kind {
        "llm" => Some(Lane::Llm),
        "gnn" => Some(Lane::Gnn),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Lane worker internals
// ---------------------------------------------------------------------------

struct LoadedModule {
    spec: ModuleSpec,
    weights: Vec<xla::PjRtBuffer>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// A lane-resident KV cache (k & v device buffers).
struct KvEntry {
    k: xla::PjRtBuffer,
    v: xla::PjRtBuffer,
}

/// A host-side f32 tensor (flat data + dims) — the parked form of a KV
/// buffer rebuilt from archive bytes, ready for re-upload.
struct HostTensor {
    data: Vec<f32>,
    dims: Vec<usize>,
}

/// A demoted KV cache parked in lane-thread host memory, awaiting
/// promotion back to device buffers, archival to bytes, or release.
/// `Literal` is the demote path's form (buffers crossed as literals);
/// `Raw` is a recall rebuilt from disk-archive bytes.
enum HostKvEntry {
    Literal { k: xla::Literal, v: xla::Literal },
    Raw { k: HostTensor, v: HostTensor },
}

struct State {
    root: PathBuf,
    manifest: Manifest,
    client: xla::PjRtClient,
    modules: HashMap<String, LoadedModule>,
    kvs: HashMap<u64, KvEntry>,
    /// Host tier: demoted KVs, keyed by ids from the same counter as
    /// device handles (so release can probe both maps unambiguously).
    host_kvs: HashMap<u64, HostKvEntry>,
    next_id: u64,
    counters: HashMap<String, (u64, f64)>,
    compile_secs: f64,
    host_kv_bytes: u64,
    /// Multi-member batches with no batched HLO entry for their op,
    /// executed as a per-member loop instead of one fused device call.
    unbatched_fallbacks: u64,
    opts: EngineOpts,
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// Row of a `[rows, V]` logits matrix holding the next-token distribution
/// after the last real question token: `qlen - 1`, clamped into range so a
/// zero-length question (empty text tokenizes to nothing) selects row 0
/// instead of underflowing, and an overlong count cannot index past the end.
pub(crate) fn logits_row(qlen: i32, rows: usize) -> usize {
    debug_assert!(rows > 0, "logits matrix must have at least one row");
    (qlen.max(1) as usize).min(rows) - 1
}

/// Fusibility key: op kind + module (backbone). Two requests may share a
/// batch iff their keys are equal; control traffic (release / warmup /
/// stats / shutdown) has no key and never fuses.
fn req_key(r: &Req) -> Option<(u8, &str)> {
    match r {
        Req::Prefill { module, .. } => Some((0, module)),
        Req::Extend { module, .. } => Some((1, module)),
        Req::Generate { module, .. } => Some((2, module)),
        Req::Encode { module, .. } => Some((3, module)),
        _ => None,
    }
}

/// Lane-side timing of one tier move (demote/promote): queue wait up to
/// `picked`, then everything since `picked` (the host↔device copy) as the
/// device span. Tier moves never ride a batch window.
fn tier_timing(submitted: Instant, picked: Instant) -> CallTiming {
    CallTiming {
        queue_secs: picked.saturating_duration_since(submitted).as_secs_f64(),
        device_secs: picked.elapsed().as_secs_f64(),
        ..Default::default()
    }
}

fn lane_main(root: PathBuf, manifest: Manifest, opts: EngineOpts, cfg: BatchConfig,
             gate: Arc<QueueGate>, rx: Receiver<Req>, ready: Sender<anyhow::Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(xerr(e)));
            return;
        }
    };
    let mut st = State {
        root,
        manifest,
        client,
        modules: HashMap::new(),
        kvs: HashMap::new(),
        host_kvs: HashMap::new(),
        next_id: 1,
        counters: HashMap::new(),
        compile_secs: 0.0,
        host_kv_bytes: 0,
        unbatched_fallbacks: 0,
        opts,
    };
    let _ = ready.send(Ok(()));

    // An incompatible request that closed the previous batch window; it is
    // processed before anything newer (lane FIFO).
    let mut carry: Option<Req> = None;
    loop {
        let req = match carry.take() {
            Some(r) => r,
            None => match rx.recv() {
                Ok(r) => r,
                Err(_) => return,
            },
        };
        if req_key(&req).is_none() {
            match req {
                Req::Release { kv } => {
                    if st.kvs.remove(&kv).is_none() {
                        st.host_kvs.remove(&kv);
                    }
                }
                Req::ReleaseMany { kvs } => {
                    for kv in kvs {
                        if st.kvs.remove(&kv).is_none() {
                            st.host_kvs.remove(&kv);
                        }
                    }
                }
                Req::Demote { kv, submitted, reply } => {
                    let picked = Instant::now();
                    let r = st.demote(kv).map_err(BackendError::from_anyhow);
                    let _ = reply.send(r.map(|id| (id, tier_timing(submitted, picked))));
                }
                Req::Promote { host, submitted, reply } => {
                    let picked = Instant::now();
                    let r = st.promote(host).map_err(BackendError::from_anyhow);
                    let _ = reply.send(r.map(|id| (id, tier_timing(submitted, picked))));
                }
                Req::Archive { host, reply } => {
                    let _ = reply.send(st.archive(host).map_err(BackendError::from_anyhow));
                }
                Req::Recall { bytes, reply } => {
                    let _ = reply.send(st.recall(&bytes).map_err(BackendError::from_anyhow));
                }
                Req::Warmup { module, reply } => {
                    let _ = reply.send(st.warmup(&module).map_err(BackendError::from_anyhow));
                }
                Req::Stats { reply } => {
                    let mut calls: Vec<(String, u64, f64)> = st
                        .counters
                        .iter()
                        .map(|(k, &(n, s))| (k.clone(), n, s))
                        .collect();
                    calls.sort_by(|a, b| a.0.cmp(&b.0));
                    let _ = reply.send(EngineStats {
                        calls,
                        live_kv: st.kvs.len() + st.host_kvs.len(),
                        compile_secs: st.compile_secs,
                        host_kv_bytes: st.host_kv_bytes,
                        unbatched_fallbacks: st.unbatched_fallbacks,
                        lane_restarts: 0, // the engine has no lane supervisor
                        breaker_trips: 0, // ... and therefore no circuit breaker
                    });
                }
                Req::Shutdown => return,
                _ => unreachable!("fusible requests are handled below"),
            }
            continue;
        }
        let mut col = collect_window(&rx, req, cfg, |a, b| req_key(a) == req_key(b));
        carry = col.carry.take();
        // Free the admission slots of everything picked into this batch:
        // queue depth gauges *waiting* work. A carried request keeps its
        // slot until the batch it actually executes in.
        gate.release(col.members.len());
        st.run_batch(col);
    }
}

/// Per-member staged result + reply slot (all members of one batch share a
/// variant, but the reply channel types differ per variant). Worker-side
/// execution errors are `anyhow` internally and become
/// [`BackendError::Fatal`] at the staging boundary — a malformed output or
/// bad argument fails the one ticket, never the lane worker.
enum BatchOut {
    Kv(Result<(u64, Vec<f32>), BackendError>, KvReply),
    Gen(Result<Vec<i32>, BackendError>,
        Sender<Result<(Vec<i32>, CallTiming), BackendError>>),
    Enc(Result<Vec<f32>, BackendError>,
        Sender<Result<(Vec<f32>, CallTiming), BackendError>>),
}

/// Outputs of one entry-point execution.
enum ExecOut {
    /// PJRT flattened the root tuple: one device buffer per output leaf.
    /// This is the zero-copy path — KV leaves go straight back into the
    /// handle map without visiting the host.
    Leaves(Vec<xla::PjRtBuffer>),
    /// A single result buffer holding the whole output tuple: the binding
    /// can only untuple it through a host literal (the seed's original
    /// path, kept as a fallback and surfaced via
    /// [`EngineStats::host_kv_bytes`]).
    HostTuple(Vec<xla::Literal>),
}

impl State {
    /// Execute one collected batch: one fused device call when the op has a
    /// batched HLO entry (currently `prefill_batch<n>`), otherwise a
    /// counted per-member fallback loop; then scatter per-member replies
    /// with the timing split described in [`crate::runtime::batch`]
    /// (`device_secs` = the whole batch's lane-thread span, for every
    /// member; the leader flag lets aggregates count it once).
    fn run_batch(&mut self, mut col: Collected<Req>) {
        let n = col.members.len();
        let t0 = Instant::now();
        let mut outs: Vec<(BatchOut, Instant, Instant)> = Vec::with_capacity(n);
        let fused_entry = if n > 1 {
            match &col.members[0].0 {
                Req::Prefill { module, .. } => {
                    let entry = format!("prefill_batch{n}");
                    self.manifest
                        .module(module)
                        .ok()
                        .filter(|m| m.entries.contains_key(&entry))
                        .map(|_| entry)
                }
                _ => None,
            }
        } else {
            None
        };
        if let Some(entry) = fused_entry {
            let mut module = String::new();
            let mut inputs = Vec::with_capacity(n);
            let mut slots = Vec::with_capacity(n);
            for (req, picked) in col.members.drain(..) {
                match req {
                    Req::Prefill { module: m, tokens, plen, submitted, reply } => {
                        module = m;
                        inputs.push((tokens, plen));
                        slots.push((reply, submitted, picked));
                    }
                    _ => unreachable!("fused batches are homogeneous"),
                }
            }
            match self.prefill_fused(&module, &entry, &inputs) {
                Ok(results) => {
                    for (r, (reply, submitted, picked)) in results.into_iter().zip(slots) {
                        outs.push((BatchOut::Kv(Ok(r), reply), submitted, picked));
                    }
                }
                Err(e) => {
                    // BackendError clones, so every member gets the full text
                    let err = BackendError::fatal(
                        format!("fused {module}.{entry} failed: {e:#}"));
                    for (reply, submitted, picked) in slots {
                        outs.push((BatchOut::Kv(Err(err.clone()), reply),
                                   submitted, picked));
                    }
                }
            }
        } else {
            if n > 1 {
                self.unbatched_fallbacks += 1;
            }
            for (req, picked) in col.members.drain(..) {
                let (out, submitted) = match req {
                    Req::Prefill { module, tokens, plen, submitted, reply } => {
                        (BatchOut::Kv(self.prefill(&module, &tokens, plen)
                                          .map_err(BackendError::from_anyhow),
                                      reply),
                         submitted)
                    }
                    Req::Extend { module, kv, plen, q_tokens, qlen, submitted, reply } => {
                        (BatchOut::Kv(self.extend(&module, kv, plen, &q_tokens, qlen)
                                          .map_err(BackendError::from_anyhow),
                                      reply),
                         submitted)
                    }
                    Req::Generate { module, kv, cur_len, first_tok, submitted, reply } => {
                        (BatchOut::Gen(self.generate(&module, kv, cur_len, first_tok)
                                           .map_err(BackendError::from_anyhow),
                                       reply),
                         submitted)
                    }
                    Req::Encode { module, x, adj, mask, submitted, reply } => {
                        (BatchOut::Enc(self.encode(&module, &x, &adj, &mask)
                                           .map_err(BackendError::from_anyhow),
                                       reply),
                         submitted)
                    }
                    _ => unreachable!("control requests never enter a batch"),
                };
                outs.push((out, submitted, picked));
            }
        }
        let device_secs = t0.elapsed().as_secs_f64();
        for (i, (out, submitted, picked)) in outs.into_iter().enumerate() {
            let t = CallTiming {
                queue_secs: picked.saturating_duration_since(submitted).as_secs_f64(),
                window_secs: col.launched.saturating_duration_since(picked).as_secs_f64(),
                device_secs,
                batch: BatchInfo::member(i, n, col.stalled),
            };
            match out {
                BatchOut::Kv(r, reply) => {
                    let _ = reply.send(r.map(|(id, logits)| (id, logits, t)));
                }
                BatchOut::Gen(r, reply) => {
                    let _ = reply.send(r.map(|toks| (toks, t)));
                }
                BatchOut::Enc(r, reply) => {
                    let _ = reply.send(r.map(|emb| (emb, t)));
                }
            }
        }
    }

    /// Fused prefill over `prefill_batch<n>`: tokens stacked to `[n, S]`
    /// plus plens `[n]`, returning `2n + 1` output leaves — `(k_i, v_i)`
    /// per member in order, then a `[n, V]` logits matrix whose row `i` is
    /// member `i`'s next-token row. This is the batched-HLO ABI
    /// python/compile emits for batch-capable entries (ROADMAP follow-on);
    /// when the entry is absent the batch routes through the counted
    /// fallback loop instead of this path.
    fn prefill_fused(&mut self, module: &str, entry: &str, members: &[(Vec<i32>, i32)])
                     -> anyhow::Result<Vec<(u64, Vec<f32>)>> {
        let n = members.len();
        self.ensure_entry(module, entry)?;
        let shape = &self.entry_spec(module, entry).extra_args[0].shape;
        anyhow::ensure!(shape.len() == 2 && shape[0] == n,
                        "{module}.{entry}: tokens arg shape {shape:?}, want [{n}, S]");
        let s = shape[1];
        let mut toks = Vec::with_capacity(n * s);
        let mut plens = Vec::with_capacity(n);
        for (t, p) in members {
            anyhow::ensure!(t.len() == s, "fused prefill: {} tokens, want {s}", t.len());
            toks.extend_from_slice(t);
            plens.push(*p);
        }
        let vocab = self.manifest.module(module)?.dims
            .ok_or_else(|| anyhow::anyhow!("{module}: not an llm module"))?
            .vocab;
        let extras = vec![
            Extra::Own(self.buf_i32(&toks, &[n, s])?),
            Extra::Own(self.buf_i32(&plens, &[n])?),
        ];
        match self.call(module, entry, extras)? {
            ExecOut::Leaves(leaves) => {
                anyhow::ensure!(leaves.len() == 2 * n + 1,
                                "{module}.{entry}: {} outputs, want 2n+1 = {}",
                                leaves.len(), 2 * n + 1);
                let mut it = leaves.into_iter();
                let mut pairs = Vec::with_capacity(n);
                for i in 0..n {
                    let (Some(k), Some(v)) = (it.next(), it.next()) else {
                        anyhow::bail!(
                            "{module}.{entry}: ran out of output leaves at member {i} \
                             (malformed backend output)");
                    };
                    pairs.push((k, v));
                }
                let logits_buf = it.next().ok_or_else(|| {
                    anyhow::anyhow!("{module}.{entry}: missing fused logits leaf \
                                     (malformed backend output)")
                })?;
                let logits = logits_buf
                    .to_literal_sync().map_err(xerr)?
                    .to_vec::<f32>().map_err(xerr)?;
                anyhow::ensure!(logits.len() == n * vocab,
                                "{module}.{entry}: {} logits, want [{n}, {vocab}]",
                                logits.len());
                let mut results = Vec::with_capacity(n);
                for (i, (k, v)) in pairs.into_iter().enumerate() {
                    let id = if self.opts.host_bounce {
                        let kl = k.to_literal_sync().map_err(xerr)?;
                        let vl = v.to_literal_sync().map_err(xerr)?;
                        self.store_kv_literals(module, kl, vl)?
                    } else {
                        self.insert_kv(k, v)
                    };
                    results.push((id, logits[i * vocab..(i + 1) * vocab].to_vec()));
                }
                Ok(results)
            }
            ExecOut::HostTuple(_) => anyhow::bail!(
                "{module}.{entry}: fused prefill needs leaf outputs; the tuple-literal \
                 runtime fallback cannot keep per-member KV on device"
            ),
        }
    }

    fn ensure_module(&mut self, name: &str) -> anyhow::Result<()> {
        if self.modules.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.module(name)?.clone();
        // weights: npz -> device buffers, once. NOTE: read via Literal, not
        // PjRtBuffer::read_npz_by_name — the crate's raw-bytes buffer path
        // passes ElementType where a PrimitiveType code is expected and
        // materializes F32 arrays as F16 (observed: embed buffer at half
        // size). The literal path round-trips correctly.
        let npz = self.root.join("weights").join(format!("{name}.npz"));
        let keys: Vec<&str> = spec.params.iter().map(|p| p.key.as_str()).collect();
        let lits = <xla::Literal as xla::FromRawBytes>::read_npz_by_name(&npz, &(), &keys)
            .map_err(|e| anyhow::anyhow!("loading {}: {e}", npz.display()))?;
        anyhow::ensure!(lits.len() == spec.params.len(), "weight count mismatch");
        let mut weights = Vec::with_capacity(lits.len());
        for (lit, p) in lits.iter().zip(&spec.params) {
            let dims: Vec<usize> = xla::ArrayShape::try_from(&lit.shape().map_err(xerr)?)
                .map(|s| s.dims().iter().map(|&d| d as usize).collect())
                .unwrap_or_default();
            anyhow::ensure!(dims == p.shape,
                            "{name}.{}: npz shape {dims:?} != manifest {:?}",
                            p.key, p.shape);
            weights.push(self.buf_from_f32_literal(lit, &dims)?);
        }
        self.modules.insert(
            name.to_string(),
            LoadedModule { spec, weights, exes: HashMap::new() },
        );
        Ok(())
    }

    fn ensure_entry(&mut self, module: &str, entry: &str) -> anyhow::Result<()> {
        self.ensure_module(module)?;
        if self.modules[module].exes.contains_key(entry) {
            return Ok(());
        }
        let spec = {
            let m = &self.modules[module].spec;
            m.entries
                .get(entry)
                .ok_or_else(|| anyhow::anyhow!("{module}: no entry {entry}"))?
                .clone()
        };
        // arg order sanity: all args live and in flatten order.
        for (i, &m) in spec.arg_map.iter().enumerate() {
            anyhow::ensure!(m == i, "{module}.{entry}: non-identity arg_map at {i} -> {m}");
        }
        let path = self.root.join(&spec.hlo);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        self.compile_secs += t0.elapsed().as_secs_f64();
        self.modules
            .get_mut(module)
            .ok_or_else(|| {
                anyhow::anyhow!("{module}: vanished from the module map during compile")
            })?
            .exes
            .insert(entry.to_string(), exe);
        Ok(())
    }

    fn entry_spec(&self, module: &str, entry: &str) -> &EntrySpec {
        &self.modules[module].spec.entries[entry]
    }

    fn warmup(&mut self, module: &str) -> anyhow::Result<()> {
        self.ensure_module(module)?;
        let entries: Vec<String> =
            self.modules[module].spec.entries.keys().cloned().collect();
        for e in entries {
            self.ensure_entry(module, &e)?;
        }
        Ok(())
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xerr)
    }

    /// Literal -> device buffer via the *synchronous* host-buffer path.
    /// `buffer_from_host_literal` enqueues an async CopyFromLiteral that may
    /// run after the literal (or even the buffer) is dropped — observed as
    /// SIGSEGVs on the TFRT CPU client's worker threads. The host-buffer
    /// path uses kImmutableOnlyDuringCall semantics (copy completes before
    /// returning), so no lifetime coupling remains.
    fn buf_from_f32_literal(&self, lit: &xla::Literal, dims: &[usize])
                            -> anyhow::Result<xla::PjRtBuffer> {
        let n: usize = dims.iter().product();
        let mut host = vec![0f32; n];
        lit.copy_raw_to(&mut host).map_err(xerr)?;
        self.client.buffer_from_host_buffer(&host, dims, None).map_err(xerr)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xerr)
    }

    /// Execute `module.entry` with the module weights + `extras`, record
    /// timing, and return the outputs with device residency preserved
    /// whenever the runtime grants it (see [`ExecOut`]). KV extras are
    /// borrowed straight from the handle map — no device copies on the hot
    /// path.
    fn call(&mut self, module: &str, entry: &str, extras: Vec<Extra>)
            -> anyhow::Result<ExecOut> {
        self.ensure_entry(module, entry)?;
        let (parts, dt) = {
            let m = &self.modules[module];
            let spec = &m.spec.entries[entry];
            let n_out = spec.outputs;
            let mut inputs: Vec<&xla::PjRtBuffer> = m.weights.iter().collect();
            for e in &extras {
                match e {
                    Extra::Own(b) => inputs.push(b),
                    Extra::Kv(id) => {
                        let e = self
                            .kvs
                            .get(id)
                            .ok_or_else(|| anyhow::anyhow!("unknown/released KV handle {id}"))?;
                        inputs.push(&e.k);
                        inputs.push(&e.v);
                    }
                }
            }
            anyhow::ensure!(
                inputs.len() == m.weights.len() + spec.extra_args.len(),
                "{module}.{entry}: got {} inputs, want {}",
                inputs.len(), m.weights.len() + spec.extra_args.len()
            );
            let t0 = Instant::now();
            let exe = &m.exes[entry];
            if self.opts.trace {
                eprintln!("[engine] exec {module}.{entry} with {} inputs", inputs.len());
            }
            let mut out = exe.execute_b(&inputs).map_err(xerr)?;
            if self.opts.trace {
                eprintln!("[engine] exec done");
            }
            anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "empty execute output");
            let mut bufs = out.remove(0);
            let parts = if bufs.len() == n_out && n_out > 1 {
                ExecOut::Leaves(bufs)
            } else if bufs.len() == 1 {
                let lit = bufs.remove(0).to_literal_sync().map_err(xerr)?;
                // the single buffer is either the whole output tuple or —
                // for single-output entries the runtime already untupled —
                // the lone leaf itself; the literal's shape disambiguates.
                let leaf = n_out == 1
                    && xla::ArrayShape::try_from(&lit.shape().map_err(xerr)?).is_ok();
                let parts = if leaf {
                    vec![lit]
                } else if n_out == 1 {
                    vec![lit.to_tuple1().map_err(xerr)?]
                } else {
                    lit.to_tuple().map_err(xerr)?
                };
                anyhow::ensure!(parts.len() == n_out,
                                "{module}.{entry}: {} outputs, want {n_out}", parts.len());
                ExecOut::HostTuple(parts)
            } else {
                anyhow::bail!("{module}.{entry}: {} result buffers, want {n_out} or 1 tuple",
                              bufs.len());
            };
            (parts, t0.elapsed().as_secs_f64())
        };
        let c = self.counters.entry(format!("{module}.{entry}")).or_insert((0, 0.0));
        c.0 += 1;
        c.1 += dt;
        Ok(parts)
    }

    /// Insert device-resident K/V buffers under a fresh handle id.
    fn insert_kv(&mut self, k: xla::PjRtBuffer, v: xla::PjRtBuffer) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.kvs.insert(id, KvEntry { k, v });
        id
    }

    /// Demote a device KV to the host tier: both buffers cross to host
    /// literals synchronously, the device copy is dropped, and a fresh id
    /// (same counter as device handles) names the parked copy. Deliberately
    /// NOT counted in `host_kv_bytes` — that counter flags the *fallback*
    /// store path regression; a demotion is an intentional tier move.
    fn demote(&mut self, kv: u64) -> anyhow::Result<u64> {
        let e = self
            .kvs
            .remove(&kv)
            .ok_or_else(|| anyhow::anyhow!("unknown/released KV handle {kv}"))?;
        let k = e.k.to_literal_sync().map_err(xerr)?;
        let v = e.v.to_literal_sync().map_err(xerr)?;
        let id = self.next_id;
        self.next_id += 1;
        self.host_kvs.insert(id, HostKvEntry::Literal { k, v });
        Ok(id)
    }

    /// Promote a host-tier KV back to device buffers, re-minting a device
    /// handle. The host copy is consumed only after both uploads succeed,
    /// so a failed promote leaves it retryable.
    fn promote(&mut self, host: u64) -> anyhow::Result<u64> {
        let (kb, vb) = {
            let e = self.host_kvs.get(&host).ok_or_else(|| {
                anyhow::anyhow!("unknown host-tier KV handle {host}")
            })?;
            match e {
                HostKvEntry::Literal { k, v } => {
                    let kd = literal_dims(k)?;
                    let vd = literal_dims(v)?;
                    (self.buf_from_f32_literal(k, &kd)?,
                     self.buf_from_f32_literal(v, &vd)?)
                }
                HostKvEntry::Raw { k, v } => {
                    (self.buf_f32(&k.data, &k.dims)?, self.buf_f32(&v.data, &v.dims)?)
                }
            }
        };
        self.host_kvs.remove(&host);
        Ok(self.insert_kv(kb, vb))
    }

    /// Serialize a host-tier KV to archive bytes: per tensor,
    /// `[ndims u32 LE][dims u64 LE × n][f32 LE data]`, k then v. Consumes
    /// the host copy either way (the `archive_kv` contract: on error the
    /// copy is already gone, the caller never leaks a handle).
    fn archive(&mut self, host: u64) -> anyhow::Result<Vec<u8>> {
        let e = self.host_kvs.remove(&host).ok_or_else(|| {
            anyhow::anyhow!("unknown host-tier KV handle {host}")
        })?;
        let (k, v) = match e {
            HostKvEntry::Literal { k, v } => (literal_tensor(&k)?, literal_tensor(&v)?),
            HostKvEntry::Raw { k, v } => (k, v),
        };
        let mut out = Vec::with_capacity(4 * (k.data.len() + v.data.len()) + 64);
        for t in [&k, &v] {
            out.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Ok(out)
    }

    /// Rebuild a host-tier KV from [`State::archive`] bytes, minting a
    /// fresh host id (same counter as device handles). Malformed bytes
    /// error out — a torn archive must never become a bogus KV.
    fn recall(&mut self, bytes: &[u8]) -> anyhow::Result<u64> {
        let mut off = 0usize;
        let k = parse_tensor(bytes, &mut off)?;
        let v = parse_tensor(bytes, &mut off)?;
        anyhow::ensure!(off == bytes.len(),
                        "archived KV payload has {} trailing bytes", bytes.len() - off);
        let id = self.next_id;
        self.next_id += 1;
        self.host_kvs.insert(id, HostKvEntry::Raw { k, v });
        Ok(id)
    }

    /// Host-bounce KV storage: literal → host vec → fresh device buffer.
    /// Only reached on the tuple-literal fallback or under forced
    /// `SUBGCACHE_KV_HOST_BOUNCE`; every byte is counted so the zero-copy
    /// property stays observable.
    fn store_kv_literals(&mut self, module: &str, k: xla::Literal, v: xla::Literal)
                         -> anyhow::Result<u64> {
        let dims = self.manifest.module(module)?.dims
            .ok_or_else(|| anyhow::anyhow!("{module}: not an llm module"))?;
        let shape = [dims.n_layers, dims.max_seq, dims.n_heads, dims.d_head];
        let kb = self.buf_from_f32_literal(&k, &shape)?;
        let vb = self.buf_from_f32_literal(&v, &shape)?;
        self.host_kv_bytes += 2 * dims.kv_bytes_each() as u64;
        Ok(self.insert_kv(kb, vb))
    }

    /// Store the (k, v, logits) outputs of a prefill/extend: KV stays on
    /// device when the runtime returned leaves, and only the needed logits
    /// row crosses to the host. `row = Some((qlen, rows))` selects row
    /// [`logits_row`]`(qlen, rows)` of a `[rows, V]` matrix; `None` means
    /// the entry already emits a single `[V]` row.
    fn finish_kv_entry(&mut self, module: &str, out: ExecOut, row: Option<(i32, usize)>)
                       -> anyhow::Result<(u64, Vec<f32>)> {
        let vocab = self.manifest.module(module)?.dims
            .ok_or_else(|| anyhow::anyhow!("{module}: not an llm module"))?
            .vocab;
        let (id, logits) = match out {
            ExecOut::Leaves(mut leaves) => {
                anyhow::ensure!(leaves.len() == 3,
                                "{module}: {} kv-entry outputs, want (k, v, logits)",
                                leaves.len());
                let (Some(logits_buf), Some(v), Some(k)) =
                    (leaves.pop(), leaves.pop(), leaves.pop())
                else {
                    anyhow::bail!(
                        "{module}: kv-entry output leaves vanished mid-unpack \
                         (malformed backend output)");
                };
                let id = if self.opts.host_bounce {
                    let kl = k.to_literal_sync().map_err(xerr)?;
                    let vl = v.to_literal_sync().map_err(xerr)?;
                    self.store_kv_literals(module, kl, vl)?
                } else {
                    self.insert_kv(k, v)
                };
                let logits = logits_buf
                    .to_literal_sync().map_err(xerr)?
                    .to_vec::<f32>().map_err(xerr)?;
                (id, logits)
            }
            ExecOut::HostTuple(mut parts) => {
                anyhow::ensure!(parts.len() == 3,
                                "{module}: {} kv-entry outputs, want (k, v, logits)",
                                parts.len());
                let logits = parts[2].to_vec::<f32>().map_err(xerr)?;
                let v = parts.swap_remove(1);
                let k = parts.swap_remove(0);
                let id = self.store_kv_literals(module, k, v)?;
                (id, logits)
            }
        };
        let logits = match row {
            None => {
                anyhow::ensure!(logits.len() == vocab,
                                "{module}: {} prefill logits, want [{vocab}]", logits.len());
                logits
            }
            Some((qlen, rows)) => {
                anyhow::ensure!(logits.len() == rows * vocab,
                                "{module}: {} extend logits, want [{rows}, {vocab}]",
                                logits.len());
                let r = logits_row(qlen, rows);
                logits[r * vocab..(r + 1) * vocab].to_vec()
            }
        };
        Ok((id, logits))
    }

    fn prefill(&mut self, module: &str, tokens: &[i32], plen: i32)
               -> anyhow::Result<(u64, Vec<f32>)> {
        self.ensure_entry(module, "prefill")?;
        let s = self.entry_spec(module, "prefill").extra_args[0].shape[0];
        anyhow::ensure!(tokens.len() == s, "prefill: {} tokens, want {s}", tokens.len());
        let extras = vec![
            Extra::Own(self.buf_i32(tokens, &[s])?),
            Extra::Own(self.buf_i32(&[plen], &[])?),
        ];
        let out = self.call(module, "prefill", extras)?;
        // prefill's HLO already selects the plen-1 logits row on device.
        self.finish_kv_entry(module, out, None)
    }

    fn extend(&mut self, module: &str, kv: u64, plen: i32, q_tokens: &[i32], qlen: i32)
              -> anyhow::Result<(u64, Vec<f32>)> {
        self.ensure_entry(module, "extend")?;
        let q = self.entry_spec(module, "extend").extra_args[3].shape[0];
        anyhow::ensure!(q_tokens.len() == q, "extend: {} tokens, want {q}", q_tokens.len());
        let extras = vec![
            Extra::Kv(kv),
            Extra::Own(self.buf_i32(&[plen], &[])?),
            Extra::Own(self.buf_i32(q_tokens, &[q])?),
        ];
        let out = self.call(module, "extend", extras)?;
        self.finish_kv_entry(module, out, Some((qlen, q)))
    }

    fn generate(&mut self, module: &str, kv: u64, cur_len: i32, first_tok: i32)
                -> anyhow::Result<Vec<i32>> {
        self.ensure_entry(module, "generate")?;
        let extras = vec![
            Extra::Kv(kv),
            Extra::Own(self.buf_i32(&[cur_len], &[])?),
            Extra::Own(self.buf_i32(&[first_tok], &[])?),
        ];
        let out = self.call(module, "generate", extras)?;
        first_output_literal(out)?.to_vec::<i32>().map_err(xerr)
    }

    fn encode(&mut self, module: &str, x: &[f32], adj: &[f32], mask: &[f32])
              -> anyhow::Result<Vec<f32>> {
        self.ensure_entry(module, "encode")?;
        let spec = self.entry_spec(module, "encode");
        let (n, f) = (spec.extra_args[0].shape[0], spec.extra_args[0].shape[1]);
        anyhow::ensure!(x.len() == n * f && adj.len() == n * n && mask.len() == n,
                        "encode: bad input sizes");
        let extras = vec![
            Extra::Own(self.buf_f32(x, &[n, f])?),
            Extra::Own(self.buf_f32(adj, &[n, n])?),
            Extra::Own(self.buf_f32(mask, &[n])?),
        ];
        let out = self.call(module, "encode", extras)?;
        first_output_literal(out)?.to_vec::<f32>().map_err(xerr)
    }
}

/// Flatten a host literal into a [`HostTensor`] (the archive path's form).
fn literal_tensor(lit: &xla::Literal) -> anyhow::Result<HostTensor> {
    let dims = literal_dims(lit)?;
    let n: usize = dims.iter().product();
    let mut data = vec![0f32; n];
    lit.copy_raw_to(&mut data).map_err(xerr)?;
    Ok(HostTensor { data, dims })
}

/// Parse one `[ndims u32 LE][dims u64 LE × n][f32 LE data]` tensor frame
/// from `bytes` at `*off`, advancing the offset. Every length is bounds-
/// checked so truncated or garbage payloads fail cleanly.
fn parse_tensor(bytes: &[u8], off: &mut usize) -> anyhow::Result<HostTensor> {
    fn take<'a>(bytes: &'a [u8], off: &mut usize, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(bytes.len() - *off >= n, "archived KV payload truncated");
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    }
    let ndims = u32::from_le_bytes(take(bytes, off, 4)?.try_into().unwrap()) as usize;
    anyhow::ensure!(ndims <= 8, "archived KV tensor claims {ndims} dims");
    let mut dims = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        let d = u64::from_le_bytes(take(bytes, off, 8)?.try_into().unwrap());
        anyhow::ensure!(d <= u32::MAX as u64, "archived KV dim {d} out of range");
        dims.push(d as usize);
    }
    let n: usize = dims.iter().product();
    anyhow::ensure!(n.checked_mul(4).is_some_and(|b| b <= bytes.len() - *off),
                    "archived KV tensor data truncated");
    let data = take(bytes, off, n * 4)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(HostTensor { data, dims })
}

/// Array dims of a host literal (for re-uploading a demoted KV with its
/// original shape).
fn literal_dims(lit: &xla::Literal) -> anyhow::Result<Vec<usize>> {
    let shape = lit.shape().map_err(xerr)?;
    let arr = xla::ArrayShape::try_from(&shape)
        .map_err(|e| anyhow::anyhow!("kv literal is not array-shaped: {e:?}"))?;
    Ok(arr.dims().iter().map(|&d| d as usize).collect())
}

/// First output of a single-output entry as a host literal. The `Leaves`
/// arm is defensive: `call` currently only returns leaves for multi-output
/// entries, but a runtime that untuples single outputs too lands here.
fn first_output_literal(out: ExecOut) -> anyhow::Result<xla::Literal> {
    match out {
        ExecOut::Leaves(mut leaves) => {
            anyhow::ensure!(!leaves.is_empty(), "no output leaves");
            leaves.swap_remove(0).to_literal_sync().map_err(xerr)
        }
        ExecOut::HostTuple(mut parts) => {
            anyhow::ensure!(!parts.is_empty(), "no output literals");
            Ok(parts.swap_remove(0))
        }
    }
}

/// An entry-point argument: an owned host-built buffer, or a KV handle
/// expanding to its (k, v) buffer pair borrowed from the lane's map.
enum Extra {
    Own(xla::PjRtBuffer),
    Kv(u64),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_row_selects_last_real_token() {
        assert_eq!(logits_row(1, 32), 0);
        assert_eq!(logits_row(12, 32), 11);
        assert_eq!(logits_row(32, 32), 31);
    }

    #[test]
    fn logits_row_clamps_degenerate_lengths() {
        // the seed panicked on (qlen - 1) with qlen = 0 — an empty question
        // must clamp to row 0, and an overlong count must not overrun.
        assert_eq!(logits_row(0, 32), 0);
        assert_eq!(logits_row(-3, 32), 0);
        assert_eq!(logits_row(99, 32), 31);
        assert_eq!(logits_row(5, 1), 0);
    }

    #[test]
    fn lane_routing_by_module_kind() {
        assert_eq!(lane_for_kind("llm"), Some(Lane::Llm));
        assert_eq!(lane_for_kind("gnn"), Some(Lane::Gnn));
        assert_eq!(lane_for_kind("tts"), None);
    }

    #[test]
    fn queue_config_parsing_matches_env_contract() {
        use crate::runtime::backend::FullPolicy;

        // unset / unparsable / zero capacity: unbounded, the seed behaviour.
        assert!(!queue_config_from(None, None).enabled());
        assert!(!queue_config_from(Some("nope"), None).enabled());
        assert!(!queue_config_from(Some("0"), Some("5")).enabled());

        // a capacity alone rejects when full.
        let cfg = queue_config_from(Some("8"), None);
        assert_eq!(cfg.capacity, 8);
        assert_eq!(cfg.full_policy, FullPolicy::Reject);

        // a capacity plus a block window blocks (bounded) when full.
        let cfg = queue_config_from(Some("8"), Some("25"));
        assert_eq!(cfg.capacity, 8);
        assert_eq!(cfg.full_policy,
                   FullPolicy::Block { timeout: Duration::from_millis(25) });

        // an unparsable block window falls back to reject, not unbounded.
        let cfg = queue_config_from(Some("8"), Some("soon"));
        assert_eq!(cfg.full_policy, FullPolicy::Reject);
    }
}
