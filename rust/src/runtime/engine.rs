//! PJRT execution engine.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so a dedicated
//! engine thread owns the client, the lazily-compiled executables, the
//! weight buffers and the resident KV caches; the rest of the system talks
//! to it over channels. This mirrors the single-engine-loop design of
//! production LLM servers (vLLM et al.) and makes the L3 side trivially
//! thread-safe.
//!
//! KV caches never leave the engine: `prefill`/`extend` return opaque
//! [`KvHandle`]s that later calls reference, so the coordinator moves tokens
//! and logits only.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use super::manifest::{EntrySpec, Manifest, ModuleSpec};

/// Opaque reference to an engine-resident KV cache (k & v buffers).
/// Deliberately not `Clone`: exactly one owner, released explicitly.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct KvHandle(pub(crate) u64);

/// Per-entry execution counters (returned by [`Engine::stats`]).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// (module.entry, calls, total seconds inside execute).
    pub calls: Vec<(String, u64, f64)>,
    pub live_kv: usize,
    pub compile_secs: f64,
}

enum Req {
    Prefill {
        module: String,
        tokens: Vec<i32>,
        plen: i32,
        reply: Sender<anyhow::Result<(u64, Vec<f32>)>>,
    },
    Extend {
        module: String,
        kv: u64,
        plen: i32,
        q_tokens: Vec<i32>,
        reply: Sender<anyhow::Result<(u64, Vec<f32>)>>,
    },
    Generate {
        module: String,
        kv: u64,
        cur_len: i32,
        first_tok: i32,
        reply: Sender<anyhow::Result<Vec<i32>>>,
    },
    Encode {
        module: String,
        x: Vec<f32>,
        adj: Vec<f32>,
        mask: Vec<f32>,
        reply: Sender<anyhow::Result<Vec<f32>>>,
    },
    Release {
        kv: u64,
    },
    ReleaseMany {
        kvs: Vec<u64>,
    },
    Warmup {
        module: String,
        reply: Sender<anyhow::Result<()>>,
    },
    Stats {
        reply: Sender<EngineStats>,
    },
    Shutdown,
}

/// Thread-safe handle to the engine thread.
pub struct Engine {
    tx: Mutex<Sender<Req>>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Copy of the manifest kept on the handle side so byte-sizing queries
    /// ([`Engine::kv_bytes`]) need no engine-thread roundtrip.
    manifest: Manifest,
}

impl Engine {
    /// Spawn the engine thread over an artifact directory.
    pub fn start_at(root: PathBuf, manifest: Manifest) -> anyhow::Result<Engine> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let thread_manifest = manifest.clone();
        let thread = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(root, thread_manifest, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Engine {
            tx: Mutex::new(tx),
            thread: Mutex::new(Some(thread)),
            manifest,
        })
    }

    /// Enqueue a request. A dead or poisoned engine yields an error (failing
    /// the one request) instead of panicking the caller's thread.
    fn send(&self, req: Req) -> anyhow::Result<()> {
        let tx = self
            .tx
            .lock()
            .map_err(|_| anyhow::anyhow!("engine sender poisoned by an earlier panic"))?;
        tx.send(req)
            .map_err(|_| anyhow::anyhow!("engine thread has shut down"))
    }

    fn roundtrip<T>(&self, make: impl FnOnce(Sender<T>) -> Req) -> anyhow::Result<T> {
        let (reply, rx) = channel();
        self.send(make(reply))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine thread died before replying"))
    }

    /// Prefill `tokens` (padded to S) with real length `plen`; returns the
    /// new KV handle and the next-token logits after position `plen - 1`.
    pub fn prefill(&self, module: &str, tokens: &[i32], plen: i32)
                   -> anyhow::Result<(KvHandle, Vec<f32>)> {
        let (id, logits) = self.roundtrip(|reply| Req::Prefill {
            module: module.into(), tokens: tokens.to_vec(), plen, reply,
        })??;
        Ok((KvHandle(id), logits))
    }

    /// Append `q_tokens` (padded to Q) at position `plen` on top of `kv`
    /// (which is NOT consumed — it stays reusable, the SubGCache property).
    /// Returns a new handle and the logits matrix `[Q, V]` flattened.
    pub fn extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32])
                  -> anyhow::Result<(KvHandle, Vec<f32>)> {
        let (id, logits) = self.roundtrip(|reply| Req::Extend {
            module: module.into(), kv: kv.0, plen, q_tokens: q_tokens.to_vec(), reply,
        })??;
        Ok((KvHandle(id), logits))
    }

    /// Greedy-decode up to G tokens starting from `first_tok` at `cur_len`.
    /// `kv` is not consumed.
    pub fn generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                    -> anyhow::Result<Vec<i32>> {
        self.roundtrip(|reply| Req::Generate {
            module: module.into(), kv: kv.0, cur_len, first_tok, reply,
        })?
    }

    /// GNN subgraph embedding: x [N,F], adj [N,N], mask [N] (row-major flat).
    pub fn encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
                  -> anyhow::Result<Vec<f32>> {
        self.roundtrip(|reply| Req::Encode { module: module.into(), x, adj, mask, reply })?
    }

    /// Return a KV cache to the engine. Best-effort: a dead engine has
    /// already dropped its device buffers, so failure to enqueue is ignored.
    pub fn release(&self, kv: KvHandle) {
        let _ = self.send(Req::Release { kv: kv.0 });
    }

    /// Return a batch of KV caches in one engine message (the cache layer's
    /// eviction/drain path). Best-effort, like [`Engine::release`].
    pub fn release_many(&self, kvs: Vec<KvHandle>) {
        if kvs.is_empty() {
            return;
        }
        let _ = self.send(Req::ReleaseMany { kvs: kvs.into_iter().map(|h| h.0).collect() });
    }

    /// Resident bytes of one KV cache of `module` (k + v buffers, f32),
    /// sized from the manifest. Errors for non-LLM modules.
    pub fn kv_bytes(&self, module: &str) -> anyhow::Result<usize> {
        let dims = self
            .manifest
            .module(module)?
            .dims
            .ok_or_else(|| anyhow::anyhow!("{module}: not an llm module, no KV geometry"))?;
        Ok(2 * dims.kv_bytes_each())
    }

    /// Load weights + compile all entries of `module` ahead of timing runs.
    pub fn warmup(&self, module: &str) -> anyhow::Result<()> {
        self.roundtrip(|reply| Req::Warmup { module: module.into(), reply })?
    }

    pub fn stats(&self) -> anyhow::Result<EngineStats> {
        self.roundtrip(|reply| Req::Stats { reply })
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // tolerate a poisoned mutex: shutdown must still reach the thread.
        if let Ok(tx) = self.tx.lock().or_else(|p| Ok::<_, ()>(p.into_inner())) {
            let _ = tx.send(Req::Shutdown);
        }
        if let Ok(mut th) = self.thread.lock().or_else(|p| Ok::<_, ()>(p.into_inner())) {
            if let Some(t) = th.take() {
                let _ = t.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Engine thread internals
// ---------------------------------------------------------------------------

struct LoadedModule {
    spec: ModuleSpec,
    weights: Vec<xla::PjRtBuffer>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// An engine-resident KV cache (k & v device buffers).
struct KvEntry {
    k: xla::PjRtBuffer,
    v: xla::PjRtBuffer,
}

struct State {
    root: PathBuf,
    manifest: Manifest,
    client: xla::PjRtClient,
    modules: HashMap<String, LoadedModule>,
    kvs: HashMap<u64, KvEntry>,
    next_id: u64,
    counters: HashMap<String, (u64, f64)>,
    compile_secs: f64,
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

fn engine_main(root: PathBuf, manifest: Manifest, rx: Receiver<Req>,
               ready: Sender<anyhow::Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(xerr(e)));
            return;
        }
    };
    let mut st = State {
        root,
        manifest,
        client,
        modules: HashMap::new(),
        kvs: HashMap::new(),
        next_id: 1,
        counters: HashMap::new(),
        compile_secs: 0.0,
    };
    let _ = ready.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Req::Prefill { module, tokens, plen, reply } => {
                let _ = reply.send(st.prefill(&module, &tokens, plen));
            }
            Req::Extend { module, kv, plen, q_tokens, reply } => {
                let _ = reply.send(st.extend(&module, kv, plen, &q_tokens));
            }
            Req::Generate { module, kv, cur_len, first_tok, reply } => {
                let _ = reply.send(st.generate(&module, kv, cur_len, first_tok));
            }
            Req::Encode { module, x, adj, mask, reply } => {
                let _ = reply.send(st.encode(&module, &x, &adj, &mask));
            }
            Req::Release { kv } => {
                st.kvs.remove(&kv);
            }
            Req::ReleaseMany { kvs } => {
                for kv in kvs {
                    st.kvs.remove(&kv);
                }
            }
            Req::Warmup { module, reply } => {
                let _ = reply.send(st.warmup(&module));
            }
            Req::Stats { reply } => {
                let mut calls: Vec<(String, u64, f64)> = st
                    .counters
                    .iter()
                    .map(|(k, &(n, s))| (k.clone(), n, s))
                    .collect();
                calls.sort_by(|a, b| a.0.cmp(&b.0));
                let _ = reply.send(EngineStats {
                    calls,
                    live_kv: st.kvs.len(),
                    compile_secs: st.compile_secs,
                });
            }
            Req::Shutdown => break,
        }
    }
}

impl State {
    fn ensure_module(&mut self, name: &str) -> anyhow::Result<()> {
        if self.modules.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.module(name)?.clone();
        // weights: npz -> device buffers, once. NOTE: read via Literal, not
        // PjRtBuffer::read_npz_by_name — the crate's raw-bytes buffer path
        // passes ElementType where a PrimitiveType code is expected and
        // materializes F32 arrays as F16 (observed: embed buffer at half
        // size). The literal path round-trips correctly.
        let npz = self.root.join("weights").join(format!("{name}.npz"));
        let keys: Vec<&str> = spec.params.iter().map(|p| p.key.as_str()).collect();
        let lits = <xla::Literal as xla::FromRawBytes>::read_npz_by_name(&npz, &(), &keys)
            .map_err(|e| anyhow::anyhow!("loading {}: {e}", npz.display()))?;
        anyhow::ensure!(lits.len() == spec.params.len(), "weight count mismatch");
        let mut weights = Vec::with_capacity(lits.len());
        for (lit, p) in lits.iter().zip(&spec.params) {
            let dims: Vec<usize> = xla::ArrayShape::try_from(&lit.shape().map_err(xerr)?)
                .map(|s| s.dims().iter().map(|&d| d as usize).collect())
                .unwrap_or_default();
            anyhow::ensure!(dims == p.shape,
                            "{name}.{}: npz shape {dims:?} != manifest {:?}",
                            p.key, p.shape);
            weights.push(self.buf_from_f32_literal(lit, &dims)?);
        }
        self.modules.insert(
            name.to_string(),
            LoadedModule { spec, weights, exes: HashMap::new() },
        );
        Ok(())
    }

    fn ensure_entry(&mut self, module: &str, entry: &str) -> anyhow::Result<()> {
        self.ensure_module(module)?;
        if self.modules[module].exes.contains_key(entry) {
            return Ok(());
        }
        let spec = {
            let m = &self.modules[module].spec;
            m.entries
                .get(entry)
                .ok_or_else(|| anyhow::anyhow!("{module}: no entry {entry}"))?
                .clone()
        };
        // arg order sanity: all args live and in flatten order.
        for (i, &m) in spec.arg_map.iter().enumerate() {
            anyhow::ensure!(m == i, "{module}.{entry}: non-identity arg_map at {i} -> {m}");
        }
        let path = self.root.join(&spec.hlo);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        self.compile_secs += t0.elapsed().as_secs_f64();
        self.modules.get_mut(module).unwrap().exes.insert(entry.to_string(), exe);
        Ok(())
    }

    fn entry_spec(&self, module: &str, entry: &str) -> &EntrySpec {
        &self.modules[module].spec.entries[entry]
    }

    fn warmup(&mut self, module: &str) -> anyhow::Result<()> {
        self.ensure_module(module)?;
        let entries: Vec<String> =
            self.modules[module].spec.entries.keys().cloned().collect();
        for e in entries {
            self.ensure_entry(module, &e)?;
        }
        Ok(())
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xerr)
    }

    /// Literal -> device buffer via the *synchronous* host-buffer path.
    /// `buffer_from_host_literal` enqueues an async CopyFromLiteral that may
    /// run after the literal (or even the buffer) is dropped — observed as
    /// SIGSEGVs on the TFRT CPU client's worker threads. The host-buffer
    /// path uses kImmutableOnlyDuringCall semantics (copy completes before
    /// returning), so no lifetime coupling remains.
    fn buf_from_f32_literal(&self, lit: &xla::Literal, dims: &[usize])
                            -> anyhow::Result<xla::PjRtBuffer> {
        let n: usize = dims.iter().product();
        let mut host = vec![0f32; n];
        lit.copy_raw_to(&mut host).map_err(xerr)?;
        self.client.buffer_from_host_buffer(&host, dims, None).map_err(xerr)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xerr)
    }

    /// Execute `module.entry` with the module weights + `extras`, untuple the
    /// result literals, record timing. KV extras are borrowed straight from
    /// the handle map — no device copies on the hot path.
    fn call(&mut self, module: &str, entry: &str, extras: Vec<Extra>)
            -> anyhow::Result<Vec<xla::Literal>> {
        self.ensure_entry(module, entry)?;
        let (parts, dt) = {
            let m = &self.modules[module];
            let spec = &m.spec.entries[entry];
            let n_out = spec.outputs;
            let mut inputs: Vec<&xla::PjRtBuffer> = m.weights.iter().collect();
            for e in &extras {
                match e {
                    Extra::Own(b) => inputs.push(b),
                    Extra::Kv(id) => {
                        let e = self
                            .kvs
                            .get(id)
                            .ok_or_else(|| anyhow::anyhow!("unknown/released KV handle {id}"))?;
                        inputs.push(&e.k);
                        inputs.push(&e.v);
                    }
                }
            }
            anyhow::ensure!(
                inputs.len() == m.weights.len() + spec.extra_args.len(),
                "{module}.{entry}: got {} inputs, want {}",
                inputs.len(), m.weights.len() + spec.extra_args.len()
            );
            let t0 = std::time::Instant::now();
            let exe = &m.exes[entry];
            if std::env::var("SUBGCACHE_TRACE").is_ok() {
                eprintln!("[engine] exec {module}.{entry} with {} inputs", inputs.len());
            }
            let mut out = exe.execute_b(&inputs).map_err(xerr)?;
            if std::env::var("SUBGCACHE_TRACE").is_ok() {
                eprintln!("[engine] exec done");
            }
            anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "empty execute output");
            let lit = out.remove(0).remove(0).to_literal_sync().map_err(xerr)?;
            let parts = if n_out == 1 {
                vec![lit.to_tuple1().map_err(xerr)?]
            } else {
                lit.to_tuple().map_err(xerr)?
            };
            anyhow::ensure!(parts.len() == n_out, "{module}.{entry}: {} outputs, want {n_out}",
                            parts.len());
            (parts, t0.elapsed().as_secs_f64())
        };
        let c = self.counters.entry(format!("{module}.{entry}")).or_insert((0, 0.0));
        c.0 += 1;
        c.1 += dt;
        Ok(parts)
    }

    fn store_kv(&mut self, module: &str, k: xla::Literal, v: xla::Literal)
                -> anyhow::Result<u64> {
        let dims = self.manifest.module(module)?.dims
            .ok_or_else(|| anyhow::anyhow!("{module}: not an llm module"))?;
        let shape = [dims.n_layers, dims.max_seq, dims.n_heads, dims.d_head];
        let kb = self.buf_from_f32_literal(&k, &shape)?;
        let vb = self.buf_from_f32_literal(&v, &shape)?;
        let id = self.next_id;
        self.next_id += 1;
        self.kvs.insert(id, KvEntry { k: kb, v: vb });
        Ok(id)
    }

    fn prefill(&mut self, module: &str, tokens: &[i32], plen: i32)
               -> anyhow::Result<(u64, Vec<f32>)> {
        self.ensure_entry(module, "prefill")?;
        let s = self.entry_spec(module, "prefill").extra_args[0].shape[0];
        anyhow::ensure!(tokens.len() == s, "prefill: {} tokens, want {s}", tokens.len());
        let extras = vec![
            Extra::Own(self.buf_i32(tokens, &[s])?),
            Extra::Own(self.buf_i32(&[plen], &[])?),
        ];
        let mut parts = self.call(module, "prefill", extras)?;
        let logits = parts[2].to_vec::<f32>().map_err(xerr)?;
        let v = parts.swap_remove(1);
        let k = parts.swap_remove(0);
        let id = self.store_kv(module, k, v)?;
        Ok((id, logits))
    }

    fn extend(&mut self, module: &str, kv: u64, plen: i32, q_tokens: &[i32])
              -> anyhow::Result<(u64, Vec<f32>)> {
        self.ensure_entry(module, "extend")?;
        let q = self.entry_spec(module, "extend").extra_args[3].shape[0];
        anyhow::ensure!(q_tokens.len() == q, "extend: {} tokens, want {q}", q_tokens.len());
        let extras = vec![
            Extra::Kv(kv),
            Extra::Own(self.buf_i32(&[plen], &[])?),
            Extra::Own(self.buf_i32(q_tokens, &[q])?),
        ];
        let mut parts = self.call(module, "extend", extras)?;
        let logits = parts[2].to_vec::<f32>().map_err(xerr)?;
        let v = parts.swap_remove(1);
        let k = parts.swap_remove(0);
        let id = self.store_kv(module, k, v)?;
        Ok((id, logits))
    }

    fn generate(&mut self, module: &str, kv: u64, cur_len: i32, first_tok: i32)
                -> anyhow::Result<Vec<i32>> {
        self.ensure_entry(module, "generate")?;
        let extras = vec![
            Extra::Kv(kv),
            Extra::Own(self.buf_i32(&[cur_len], &[])?),
            Extra::Own(self.buf_i32(&[first_tok], &[])?),
        ];
        let parts = self.call(module, "generate", extras)?;
        parts[0].to_vec::<i32>().map_err(xerr)
    }

    fn encode(&mut self, module: &str, x: &[f32], adj: &[f32], mask: &[f32])
              -> anyhow::Result<Vec<f32>> {
        self.ensure_entry(module, "encode")?;
        let spec = self.entry_spec(module, "encode");
        let (n, f) = (spec.extra_args[0].shape[0], spec.extra_args[0].shape[1]);
        anyhow::ensure!(x.len() == n * f && adj.len() == n * n && mask.len() == n,
                        "encode: bad input sizes");
        let extras = vec![
            Extra::Own(self.buf_f32(x, &[n, f])?),
            Extra::Own(self.buf_f32(adj, &[n, n])?),
            Extra::Own(self.buf_f32(mask, &[n])?),
        ];
        let parts = self.call(module, "encode", extras)?;
        parts[0].to_vec::<f32>().map_err(xerr)
    }
}

/// An entry-point argument: an owned host-built buffer, or a KV handle
/// expanding to its (k, v) buffer pair borrowed from the engine map.
enum Extra {
    Own(xla::PjRtBuffer),
    Kv(u64),
}
