//! `artifacts/manifest.json` — the contract between the Python compile path
//! and this runtime: module/entry inventory, flattened parameter order,
//! argument specs and the shape constants.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{parse_file, Json};

/// Shape/id constants shared across the stack (config.py is the source).
#[derive(Debug, Clone, Copy)]
pub struct Constants {
    pub max_seq: usize,
    pub max_q: usize,
    pub max_gen: usize,
    pub max_prefix: usize,
    pub vocab: usize,
    pub feat_dim: usize,
    pub n_max: usize,
    pub gnn_emb: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub unk_id: i32,
}

/// One flattened parameter (npz key + shape, in HLO argument order).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub key: String,
    pub path: String,
    pub shape: Vec<usize>,
}

/// One runtime-supplied argument of an entry point.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

/// One AOT entry point of a module.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub hlo: String,
    pub extra_args: Vec<ArgSpec>,
    pub outputs: usize,
    /// HLO parameter position -> flattened argument index (identity when all
    /// arguments are live; asserted complete at build time).
    pub arg_map: Vec<usize>,
}

/// LLM geometry (absent for GNN modules).
#[derive(Debug, Clone, Copy)]
pub struct LlmDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl LlmDims {
    /// Bytes of one KV side ([L, S, H, D] f32).
    pub fn kv_bytes_each(&self) -> usize {
        self.n_layers * self.max_seq * self.n_heads * self.d_head * 4
    }
}

#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub kind: String, // "llm" | "gnn"
    pub params: Vec<ParamSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
    pub dims: Option<LlmDims>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub constants: Constants,
    pub modules: BTreeMap<String, ModuleSpec>,
}

fn usz(v: &Json, key: &str) -> anyhow::Result<usize> {
    v.get(key).as_usize().ok_or_else(|| anyhow::anyhow!("manifest: missing {key}"))
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        Self::from_json(&parse_file(path)?)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Manifest> {
        let c = v.get("constants");
        let constants = Constants {
            max_seq: usz(c, "max_seq")?,
            max_q: usz(c, "max_q")?,
            max_gen: usz(c, "max_gen")?,
            max_prefix: usz(c, "max_prefix")?,
            vocab: usz(c, "vocab")?,
            feat_dim: usz(c, "feat_dim")?,
            n_max: usz(c, "n_max")?,
            gnn_emb: usz(c, "gnn_emb")?,
            pad_id: usz(c, "pad_id")? as i32,
            bos_id: usz(c, "bos_id")? as i32,
            eos_id: usz(c, "eos_id")? as i32,
            unk_id: usz(c, "unk_id")? as i32,
        };
        let mut modules = BTreeMap::new();
        let mods = v
            .get("modules")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing modules"))?;
        for (name, m) in mods {
            let params = m
                .get("params")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("module {name}: missing params"))?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        key: p.get("key").as_str().unwrap_or_default().to_string(),
                        path: p.get("path").as_str().unwrap_or_default().to_string(),
                        shape: p
                            .get("shape")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let mut entries = BTreeMap::new();
            for (ename, e) in m
                .get("entries")
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("module {name}: missing entries"))?
            {
                let extra_args = e
                    .get("extra_args")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|a| ArgSpec {
                        name: a.idx(0).as_str().unwrap_or_default().to_string(),
                        dtype: a.idx(1).as_str().unwrap_or_default().to_string(),
                        shape: a.idx(2).as_arr().unwrap_or(&[]).iter()
                            .filter_map(Json::as_usize).collect(),
                    })
                    .collect::<Vec<_>>();
                let arg_map: Vec<usize> = e
                    .get("arg_map")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                anyhow::ensure!(
                    arg_map.len() == params.len() + extra_args.len(),
                    "module {name}.{ename}: arg_map len {} != params {} + extras {}",
                    arg_map.len(), params.len(), extra_args.len()
                );
                entries.insert(
                    ename.clone(),
                    EntrySpec {
                        hlo: e.get("hlo").as_str().unwrap_or_default().to_string(),
                        extra_args,
                        outputs: usz(e, "outputs")?,
                        arg_map,
                    },
                );
            }
            let dims = if m.get("kind").as_str() == Some("llm") {
                let d = m.get("dims");
                Some(LlmDims {
                    vocab: usz(d, "vocab")?,
                    d_model: usz(d, "d_model")?,
                    n_layers: usz(d, "n_layers")?,
                    n_heads: usz(d, "n_heads")?,
                    d_head: usz(d, "d_head")?,
                    d_ff: usz(d, "d_ff")?,
                    max_seq: usz(d, "max_seq")?,
                })
            } else {
                None
            };
            modules.insert(
                name.clone(),
                ModuleSpec {
                    name: name.clone(),
                    kind: m.get("kind").as_str().unwrap_or_default().to_string(),
                    params,
                    entries,
                    dims,
                },
            );
        }
        Ok(Manifest { constants, modules })
    }

    pub fn module(&self, name: &str) -> anyhow::Result<&ModuleSpec> {
        self.modules
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown module '{name}' (have: {:?})",
                                           self.modules.keys().collect::<Vec<_>>()))
    }

    pub fn llm_names(&self) -> Vec<&str> {
        self.modules.values().filter(|m| m.kind == "llm").map(|m| m.name.as_str()).collect()
    }

    pub fn gnn_names(&self) -> Vec<&str> {
        self.modules.values().filter(|m| m.kind == "gnn").map(|m| m.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn mini_manifest() -> Json {
        parse(
            r#"{"constants":{"max_seq":768,"max_q":32,"max_gen":32,"max_prefix":704,
                 "vocab":704,"feat_dim":64,"n_max":64,"gnn_emb":64,
                 "pad_id":0,"bos_id":1,"eos_id":2,"unk_id":3},
                "modules":{"m":{"kind":"llm",
                  "params":[{"key":"p000","path":"e","shape":[704,96],"dtype":"float32"}],
                  "dims":{"vocab":704,"d_model":96,"n_layers":3,"n_heads":3,
                          "d_head":32,"d_ff":192,"max_seq":768},
                  "entries":{"prefill":{"hlo":"hlo/m.prefill.hlo.txt",
                    "extra_args":[["tokens","i32",[768]],["plen","i32",[]]],
                    "outputs":3,"arg_map":[0,1,2]}}}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&mini_manifest()).unwrap();
        assert_eq!(m.constants.max_seq, 768);
        assert_eq!(m.constants.eos_id, 2);
        let ms = m.module("m").unwrap();
        assert_eq!(ms.params.len(), 1);
        let e = &ms.entries["prefill"];
        assert_eq!(e.extra_args.len(), 2);
        assert_eq!(e.extra_args[0].shape, vec![768]);
        assert_eq!(e.outputs, 3);
        let d = ms.dims.unwrap();
        assert_eq!(d.kv_bytes_each(), 3 * 768 * 3 * 32 * 4);
    }

    #[test]
    fn rejects_inconsistent_arg_map() {
        let mut txt = mini_manifest().to_string();
        txt = txt.replace("[0,1,2]", "[0,1]");
        assert!(Manifest::from_json(&parse(&txt).unwrap()).is_err());
    }

    #[test]
    fn unknown_module_is_error() {
        let m = Manifest::from_json(&mini_manifest()).unwrap();
        assert!(m.module("nope").is_err());
        assert_eq!(m.llm_names(), vec!["m"]);
        assert!(m.gnn_names().is_empty());
    }
}
