//! Deterministic simulation backend + synthetic artifact world, so the
//! coordinator's scheduling logic (lane overlap, depth-k pipelining,
//! pin-safety under eviction, hit/miss TTFT composition, cluster TTL) runs
//! under plain `cargo test` — no `make artifacts`, no PJRT.
//!
//! # What it simulates
//!
//! [`SimBackend`] implements [`Backend`] with the same lane structure as
//! the PJRT engine: an LLM lane worker (prefill / extend / generate, owning
//! the KV map) and a GNN lane worker (encode), each a real thread with a
//! FIFO queue, so submission-order and overlap behaviour match production.
//! Each op sleeps its configured [`SimLatency`] — the "device time" — and
//! replies with a [`CallTiming`] measured exactly like the engine's.
//!
//! **Model semantics are deterministic and composition-faithful:** a KV
//! handle stores the real token sequence it was built from, `extend`
//! appends to it, and logits are a pure hash of the effective sequence.
//! Prefilling `prefix ⊕ question` in one call therefore yields bit-identical
//! logits to prefill(prefix) + extend(question) — the same parity property
//! the PJRT engine has — so the baseline / SubGCache / online answer-match
//! e2e tests run unmodified on the sim. Encode is a masked mean over the
//! packed node features (adjacency is ignored), which keeps similar
//! subgraphs close in embedding space so centroid matching behaves.
//!
//! # Writing a SimBackend test
//!
//! ```no_run
//! use subgcache::runtime::{sim_dataset, sim_store, SimBackend, SimLatency, SIM_BACKBONE};
//! use subgcache::coordinator::{Coordinator, ServeConfig};
//! use subgcache::retrieval::GRetriever;
//!
//! let store = sim_store();                         // in-memory artifact world
//! let ds = sim_dataset(4, 3);                      // 4 groups × 3 queries
//! let lat = SimLatency::from_millis(10, 4, 4, 10); // prefill/extend/gen/encode
//! let sim = SimBackend::start(&store, lat).unwrap();
//! let cfg = ServeConfig { backbone: SIM_BACKBONE.into(), ..Default::default() };
//! let coord = Coordinator::new(&store, &sim, cfg).unwrap();
//! let queries = ds.sample_test(8, 7);
//! let report = coord.serve_online(&ds, queries.iter().copied(),
//!                                 &GRetriever::default()).unwrap();
//! assert!(report.metrics.wall_time > 0.0);
//! ```
//!
//! Latencies are wall-clock sleeps, so keep them in the 1–20 ms range:
//! large enough that overlap assertions are robust against scheduler
//! jitter, small enough that suites stay fast.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::{Dataset, Query, Split};
use crate::embed::FEAT_DIM;
use crate::graph::{Edge, Node, Subgraph, TextualGraph};
use crate::tokenizer::{split_text, Tokenizer, BOS_ID, EOS_ID, PAD_ID, UNK_ID};

use super::backend::{merge_stats, Backend, BackendError, CallTiming, EngineStats,
                     KvHandle, Lane, PendingEncode, PendingExtend, PendingGenerate,
                     PendingKv, PendingPrefill, PendingPromote, QueueConfig, QueueGate,
                     Ticket};
use super::batch::{collect_window, BatchConfig, BatchInfo, Collected};
use super::engine::lane_for_kind;
use super::manifest::{Constants, LlmDims, Manifest, ModuleSpec};
use super::ArtifactStore;

/// Marginal device cost of each additional member in a fused batch: a
/// fused call of `n` compatible requests sleeps `base + per_item * (n-1)`.
/// A slope equal to the base models serial execution (batching saves
/// nothing on-device); a smaller slope models real batched-HLO wins.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchSlope {
    pub prefill: Duration,
    pub extend: Duration,
    pub generate: Duration,
    pub encode: Duration,
}

/// Virtual per-op device latencies (wall-clock sleeps on the lane worker),
/// plus the per-item batch slope the fused path adds per extra member.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimLatency {
    pub prefill: Duration,
    pub extend: Duration,
    pub generate: Duration,
    pub encode: Duration,
    pub per_item: BatchSlope,
    /// Device↔host KV copy cost per byte (both directions): a demote or
    /// promote of a KV cache sleeps `host_copy_per_byte * kv_bytes` on the
    /// LLM lane. Zero by default — tier moves are free until a test opts
    /// into modelling PCIe-ish transfer cost with
    /// [`with_host_copy_per_byte`](Self::with_host_copy_per_byte).
    pub host_copy_per_byte: Duration,
}

impl SimLatency {
    /// All-zero latencies: pure functional simulation, fastest tests.
    pub fn zero() -> Self {
        Self::default()
    }

    /// Per-op bases; the batch slope defaults to the base itself
    /// (serial-equivalent — fusion claims no device win until
    /// [`with_per_item_millis`](Self::with_per_item_millis) or a bench fit
    /// says otherwise).
    pub fn from_millis(prefill: u64, extend: u64, generate: u64, encode: u64) -> Self {
        SimLatency {
            prefill: Duration::from_millis(prefill),
            extend: Duration::from_millis(extend),
            generate: Duration::from_millis(generate),
            encode: Duration::from_millis(encode),
            per_item: BatchSlope {
                prefill: Duration::from_millis(prefill),
                extend: Duration::from_millis(extend),
                generate: Duration::from_millis(generate),
                encode: Duration::from_millis(encode),
            },
            host_copy_per_byte: Duration::ZERO,
        }
    }

    /// Override the per-item batch slopes (milliseconds, same op order as
    /// [`from_millis`](Self::from_millis)).
    pub fn with_per_item_millis(mut self, prefill: u64, extend: u64, generate: u64,
                                encode: u64) -> Self {
        self.per_item = BatchSlope {
            prefill: Duration::from_millis(prefill),
            extend: Duration::from_millis(extend),
            generate: Duration::from_millis(generate),
            encode: Duration::from_millis(encode),
        };
        self
    }

    /// Set the per-byte device↔host KV copy cost (see
    /// [`host_copy_per_byte`](Self::host_copy_per_byte)).
    pub fn with_host_copy_per_byte(mut self, per_byte: Duration) -> Self {
        self.host_copy_per_byte = per_byte;
        self
    }

    /// Device sleep of one tier move (demote or promote) of a `bytes`-sized
    /// KV cache: `host_copy_per_byte * bytes`, saturating.
    pub fn host_copy(&self, bytes: usize) -> Duration {
        let b = bytes.min(u32::MAX as usize) as u32;
        self.host_copy_per_byte.saturating_mul(b)
    }

    /// Serial per-query upper bound: one of each op back to back.
    pub fn serial_sum(&self) -> f64 {
        (self.prefill + self.extend + self.generate + self.encode).as_secs_f64()
    }

    /// Device sleep of one fused call carrying `n` members of `op`:
    /// `base + per_item * (n-1)`.
    fn batch_sleep(&self, base: Duration, slope: Duration, n: usize) -> Duration {
        base + slope * (n.saturating_sub(1) as u32)
    }

    /// Sim-vs-real calibration seed: fit per-op virtual latencies from a
    /// `BENCH_engine.json` produced by `benches/engine_hot_path.rs`, so sim
    /// wall-time numbers become predictive of the measured engine instead
    /// of hand-set. Each op's base takes the mean of the `median_ns` of
    /// result rows whose name starts with `"<op> "` and carries no
    /// `batch=` tag — e.g. `"prefill 400 tokens [device-resident]"` feeds
    /// `prefill`; composite rows like `"prefill->extend handoff"`
    /// deliberately match no op. Rows tagged `batch=<n>` (n ≥ 2, e.g.
    /// `"extend Q=24 batch=4 [fused]"`) instead fit the op's per-item
    /// batch slope as the mean of `(median - base) / (n - 1)`, clamped to
    /// ≥ 0; an op with no batched rows keeps the serial-equivalent slope
    /// (= its base), claiming no fusion win that was never measured. An op
    /// with no matching row at all keeps zero latency (functional-only).
    ///
    /// Degenerate fixtures fit conservatively instead of panicking or
    /// producing garbage: a `batch=1` (or `batch=0`) row is a single-member
    /// launch, so it feeds the **base**, never the slope — the `n - 1`
    /// divisor is only ever applied with `n ≥ 2`. Rows whose `median_ns` is
    /// missing or non-finite are skipped entirely, so a corrupt row can
    /// never poison a fit with NaN/inf. A batch-rows-only fixture (no
    /// unbatched row for the op) has no base to fit against and keeps the
    /// op unfitted. Errors if the file is unreadable, has no `results`
    /// array, or matches no op at all.
    pub fn from_bench_json(path: impl AsRef<std::path::Path>) -> anyhow::Result<SimLatency> {
        let path = path.as_ref();
        let json = crate::util::json::parse_file(path)?;
        let rows = json.get("results").as_arr().ok_or_else(|| {
            anyhow::anyhow!("{}: no results array (not a BENCH json?)", path.display())
        })?;
        // `batch=<n>` anywhere in a row name marks a fused-call measurement
        let batch_n = |name: &str| -> Option<usize> {
            let rest = &name[name.find("batch=")? + "batch=".len()..];
            let digits = &rest[..rest.chars().take_while(char::is_ascii_digit).count()];
            digits.parse().ok()
        };
        // (base, per_item) per op; None when no unbatched row names the op
        let fit = |op: &str| -> Option<(Duration, Duration)> {
            let prefix = format!("{op} ");
            let mut bases = Vec::new();
            let mut batched = Vec::new();
            for r in rows.iter() {
                let Some(name) = r.get("name").as_str() else { continue };
                if !name.starts_with(&prefix) {
                    continue;
                }
                let Some(median) = r.get("median_ns").as_f64() else { continue };
                if !median.is_finite() {
                    continue; // corrupt row: never poison the fit
                }
                match batch_n(name) {
                    // n ≥ 2 keeps the (n - 1) slope divisor nonzero; a
                    // batch=1 row is just an unbatched measurement
                    Some(n) if n >= 2 => batched.push((n, median)),
                    _ => bases.push(median),
                }
            }
            if bases.is_empty() {
                return None;
            }
            let base = bases.iter().sum::<f64>() / bases.len() as f64;
            let slopes: Vec<f64> = batched
                .iter()
                .map(|&(n, median)| ((median - base) / (n - 1) as f64).max(0.0))
                .collect();
            let per = if slopes.is_empty() {
                base // serial-equivalent: no measured fusion win
            } else {
                slopes.iter().sum::<f64>() / slopes.len() as f64
            };
            Some((Duration::from_nanos(base.max(0.0) as u64),
                  Duration::from_nanos(per.max(0.0) as u64)))
        };
        let (prefill, per_prefill) = fit("prefill").unwrap_or_default();
        let (extend, per_extend) = fit("extend").unwrap_or_default();
        let (generate, per_generate) = fit("generate").unwrap_or_default();
        let (encode, per_encode) = fit("encode").unwrap_or_default();
        let lat = SimLatency {
            prefill,
            extend,
            generate,
            encode,
            per_item: BatchSlope {
                prefill: per_prefill,
                extend: per_extend,
                generate: per_generate,
                encode: per_encode,
            },
            host_copy_per_byte: Duration::ZERO, // not measured by the bench
        };
        anyhow::ensure!(
            lat.serial_sum() > 0.0,
            "{}: no per-op rows matched (row names must start with 'prefill ', \
             'extend ', 'generate ' or 'encode ' and carry a finite median_ns)",
            path.display()
        );
        Ok(lat)
    }
}

/// Deterministic chaos-injection plan for [`SimBackend`]: which ops fail,
/// which lane dies, and when — all derived from `seed` and a per-lane op
/// counter, so a chaos run is reproducible bit for bit. The default plan
/// injects nothing and adds no work to the hot path.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Seed for the per-op injection rolls (same seed + same op index =
    /// same decision).
    pub seed: u64,
    /// Kill the LLM lane worker right before it executes its Nth fusible
    /// op (1-based, counted across incarnations). Fires at most once; the
    /// supervisor then restarts the lane with a fresh (empty) KV
    /// incarnation.
    pub kill_llm_at_op: Option<u64>,
    /// Like [`kill_llm_at_op`](Self::kill_llm_at_op) for the GNN lane.
    pub kill_gnn_at_op: Option<u64>,
    /// Per-op probability in [0, 1] of replying
    /// [`BackendError::Transient`] instead of executing (the op has no
    /// side effects when it fires — a clean retry target).
    pub transient_prob: f64,
    /// Per-op probability of sleeping an extra [`spike`](Self::spike)
    /// before executing (a latency spike, not an error).
    pub spike_prob: f64,
    /// Extra device latency added when a spike roll hits.
    pub spike: Duration,
}

impl FaultPlan {
    /// The empty plan: no kills, no transients, no spikes.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    fn is_noop(&self) -> bool {
        self.kill_llm_at_op.is_none()
            && self.kill_gnn_at_op.is_none()
            && self.transient_prob <= 0.0
            && self.spike_prob <= 0.0
    }
}

/// Lane-supervision knobs: how many times a dead lane worker may be
/// restarted and how the restart backoff grows (capped exponential).
#[derive(Debug, Clone, Copy)]
pub struct SupervisorPolicy {
    /// Restarts allowed per lane before death becomes terminal
    /// ([`BackendError::LaneDead`] with an exhausted-budget message).
    pub max_restarts: u32,
    /// Backoff before the first restart; doubles on each consecutive one.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        SupervisorPolicy {
            max_restarts: 4,
            backoff_base: Duration::from_millis(2),
            backoff_cap: Duration::from_millis(50),
        }
    }
}

impl SupervisorPolicy {
    /// Capped exponential backoff before restart number `n` (1-based):
    /// `base * 2^(n-1)`, clamped to `backoff_cap`.
    fn backoff(&self, n: u32) -> Duration {
        let doublings = n.saturating_sub(1).min(16);
        self.backoff_base
            .saturating_mul(1u32 << doublings)
            .min(self.backoff_cap)
    }
}

/// Lane circuit-breaker knobs: `threshold` *consecutive* transient failures
/// within `window` of each other trip the lane's breaker open. While open,
/// work submissions on the lane fail fast as [`BackendError::Overloaded`]
/// (nothing is enqueued, so a retry storm can't pile onto a sick lane).
/// After `cooldown`, exactly one **half-open probe** submission is admitted:
/// its success closes the breaker, another transient re-opens it for a
/// fresh cooldown. Control traffic (release/warmup/stats/tier moves) is
/// never gated.
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive transients that trip the breaker.
    pub threshold: u32,
    /// Two failures further apart than this do not count as consecutive.
    pub window: Duration,
    /// How long the breaker stays open before admitting a half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            threshold: 5,
            window: Duration::from_secs(1),
            cooldown: Duration::from_millis(25),
        }
    }
}

#[derive(Default)]
struct BreakerInner {
    consecutive: u32,
    last_failure: Option<Instant>,
    /// `Some` while the breaker is open (or half-open, once the deadline
    /// has passed and a probe is eligible).
    open_until: Option<Instant>,
    /// A half-open probe is in flight; further submits stay rejected until
    /// its outcome is recorded.
    probing: bool,
    trips: u64,
}

/// Per-lane circuit-breaker state, shared between the submit path (which
/// checks it) and the lane workers (which record executed-op outcomes into
/// it). Observing *results* — never [`FaultState::on_op`] decisions — keeps
/// the fault-roll op indices identical with and without a breaker, so
/// seeded chaos runs stay bit-reproducible.
struct BreakerState {
    cfg: Option<BreakerConfig>,
    lanes: [Mutex<BreakerInner>; 2],
}

impl BreakerState {
    fn new(cfg: Option<BreakerConfig>) -> BreakerState {
        BreakerState { cfg, lanes: [Mutex::default(), Mutex::default()] }
    }

    fn lock(&self, lane: Lane) -> std::sync::MutexGuard<'_, BreakerInner> {
        match self.lanes[lane as usize].lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Gate one work submission: `Err(Overloaded)` while the breaker is
    /// open, except for the single half-open probe after the cooldown.
    fn check(&self, lane: Lane) -> Result<(), BackendError> {
        if self.cfg.is_none() {
            return Ok(());
        }
        let mut b = self.lock(lane);
        let Some(until) = b.open_until else { return Ok(()) };
        let now = Instant::now();
        if now < until {
            return Err(BackendError::overloaded(
                lane,
                format!("circuit breaker open after {} consecutive transients \
                         (half-open probe in {:?})",
                        b.consecutive, until - now),
            ));
        }
        if b.probing {
            return Err(BackendError::overloaded(
                lane, "circuit breaker half-open; probe already in flight"));
        }
        b.probing = true;
        Ok(())
    }

    /// Record one executed work op's outcome (lane-worker side). `ok`
    /// closes the breaker and zeroes the consecutive count; a transient
    /// failure counts toward the threshold and re-opens a probing breaker.
    fn record(&self, lane: Lane, ok: bool) {
        let Some(cfg) = self.cfg else { return };
        let mut b = self.lock(lane);
        if ok {
            let trips = b.trips;
            *b = BreakerInner { trips, ..BreakerInner::default() };
            return;
        }
        let now = Instant::now();
        let within = b
            .last_failure
            .is_some_and(|t| now.duration_since(t) <= cfg.window);
        b.consecutive = if within { b.consecutive + 1 } else { 1 };
        b.last_failure = Some(now);
        let open = b.open_until.is_some();
        if b.probing || (!open && b.consecutive >= cfg.threshold) {
            // closed -> open on threshold, or half-open -> open on a failed
            // probe; each transition counts as a trip
            b.open_until = Some(now + cfg.cooldown);
            b.probing = false;
            b.trips += 1;
        }
    }

    /// Forget everything for `lane` (keeping the trip counter): a restarted
    /// worker is a fresh incarnation and deserves a closed breaker.
    fn reset(&self, lane: Lane) {
        if self.cfg.is_none() {
            return;
        }
        let mut b = self.lock(lane);
        *b = BreakerInner { trips: b.trips, ..BreakerInner::default() };
    }

    fn trips(&self) -> u64 {
        if self.cfg.is_none() {
            return 0;
        }
        Lane::ALL.iter().map(|&l| self.lock(l).trips).sum()
    }
}

/// Feed one executed op's outcome to the breaker: success closes it, a
/// `Transient` counts toward the trip threshold, and anything else (Fatal
/// misuse, staleness) is not a lane-health signal and is ignored.
fn observe_breaker<T>(breaker: &BreakerState, lane: Lane,
                      r: &Result<T, BackendError>) {
    match r {
        Ok(_) => breaker.record(lane, true),
        Err(BackendError::Transient { .. }) => breaker.record(lane, false),
        Err(_) => {}
    }
}

/// What [`FaultState::on_op`] decided for one op.
enum Inject {
    None,
    /// Reply `Transient` without executing.
    Transient,
    /// The worker must exit now, dropping every undelivered reply.
    Kill,
}

/// Shared fault-injection state: the plan plus per-lane op counters that
/// survive lane restarts (so a kill scheduled at op N fires exactly once
/// no matter how submissions interleave).
struct FaultState {
    plan: FaultPlan,
    noop: bool,
    ops: [AtomicU64; 2],
    killed: [AtomicBool; 2],
    transients: AtomicU64,
    spikes: AtomicU64,
}

impl FaultState {
    fn new(plan: FaultPlan) -> FaultState {
        let noop = plan.is_noop();
        FaultState {
            plan,
            noop,
            ops: [AtomicU64::new(0), AtomicU64::new(0)],
            killed: [AtomicBool::new(false), AtomicBool::new(false)],
            transients: AtomicU64::new(0),
            spikes: AtomicU64::new(0),
        }
    }

    /// Uniform roll in [0, 1) from (seed, salt) — pure, deterministic.
    fn roll(seed: u64, salt: u64) -> f64 {
        (splitmix(seed ^ salt) >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Advance `lane`'s op counter and decide this op's fate. Latency
    /// spikes are applied here (the sleep lands inside the lane worker's
    /// device span). A no-op plan returns immediately.
    fn on_op(&self, lane: Lane) -> Inject {
        if self.noop {
            return Inject::None;
        }
        let li = lane as usize;
        let idx = self.ops[li].fetch_add(1, Ordering::SeqCst) + 1;
        let kill_at = match lane {
            Lane::Llm => self.plan.kill_llm_at_op,
            Lane::Gnn => self.plan.kill_gnn_at_op,
        };
        if kill_at == Some(idx) && !self.killed[li].swap(true, Ordering::SeqCst) {
            return Inject::Kill;
        }
        let lane_salt = (li as u64 + 1) << 56;
        if self.plan.spike_prob > 0.0
            && Self::roll(self.plan.seed ^ 0x5350_494b, lane_salt | idx)
                < self.plan.spike_prob
        {
            self.spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.plan.spike);
        }
        if self.plan.transient_prob > 0.0
            && Self::roll(self.plan.seed ^ 0x544e_5354, lane_salt | idx)
                < self.plan.transient_prob
        {
            self.transients.fetch_add(1, Ordering::Relaxed);
            return Inject::Transient;
        }
        Inject::None
    }
}

/// KV handle ids carry their lane incarnation in the high bits, so a
/// handle minted before a lane restart is recognizably stale afterwards
/// (the quarantine signal for [`Backend::kv_current`]).
const GEN_SHIFT: u32 = 48;

fn handle_gen(id: u64) -> u64 {
    id >> GEN_SHIFT
}

/// High bit tags a **host-tier** handle id (minted by `demote_kv`). Host
/// copies live outside any lane incarnation, so the tag also marks the id
/// as exempt from generation staleness: a host handle survives lane
/// restarts and is always [`Backend::kv_current`]. Device ids can never
/// collide with the tag — their generation field would have to reach
/// 2^15 restarts first.
const HOST_BIT: u64 = 1 << 63;

fn is_host_handle(id: u64) -> bool {
    id & HOST_BIT != 0
}

/// The sim's host KV tier: demoted token sequences keyed by host handle
/// id. Owned by the [`SimBackend`] (not a lane worker), so host copies
/// survive lane deaths and restarts — exactly the property the cache
/// layer's quarantine path relies on.
#[derive(Default)]
struct SimHostStore {
    kvs: Mutex<HashMap<u64, Vec<i32>>>,
    next: AtomicU64,
}

impl SimHostStore {
    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, Vec<i32>>> {
        match self.kvs.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    fn len(&self) -> usize {
        self.lock().len()
    }
}

type KvReply = Sender<Result<(u64, Vec<f32>, CallTiming), BackendError>>;

enum SReq {
    Prefill {
        module: String,
        tokens: Vec<i32>,
        plen: i32,
        submitted: Instant,
        reply: KvReply,
    },
    Extend {
        module: String,
        kv: u64,
        plen: i32,
        q_tokens: Vec<i32>,
        qlen: i32,
        submitted: Instant,
        reply: KvReply,
    },
    Generate {
        module: String,
        kv: u64,
        first_tok: i32,
        submitted: Instant,
        reply: Sender<Result<(Vec<i32>, CallTiming), BackendError>>,
    },
    Encode {
        module: String,
        x: Vec<f32>,
        mask: Vec<f32>,
        submitted: Instant,
        reply: Sender<Result<(Vec<f32>, CallTiming), BackendError>>,
    },
    Release {
        kvs: Vec<u64>,
    },
    /// Copy a device KV to the host store and free the device copy
    /// (control traffic: never fuses, never rolls FaultPlan injections, so
    /// chaos op indices stay stable with or without a host tier).
    Demote {
        kv: u64,
        submitted: Instant,
        reply: Sender<Result<(u64, CallTiming), BackendError>>,
    },
    /// Copy a host-store KV back onto the device; the host copy is
    /// consumed only on success.
    Promote {
        host: u64,
        submitted: Instant,
        reply: Sender<Result<(u64, CallTiming), BackendError>>,
    },
    Warmup {
        module: String,
        reply: Sender<Result<(), BackendError>>,
    },
    Stats {
        reply: Sender<EngineStats>,
    },
    Shutdown,
}

/// One lane's live link to its current worker incarnation, owned by the
/// supervisor (every field behind the lane mutex).
struct LaneLink {
    tx: Sender<SReq>,
    /// Test hook: set before a shutdown nudge to make the worker exit
    /// *before* draining its queue, dropping queued reply senders.
    poison: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Worker/KV incarnation, bumped on every supervisor restart and
    /// encoded into the high bits of every handle this lane mints.
    generation: u64,
    restarts: u32,
    /// [`SimBackend::kill_lane_for_test`] is terminal: a condemned lane is
    /// never resurrected (the dead-lane regression tests pin that a killed
    /// lane rejects submits forever).
    condemned: bool,
    /// Modules warmed on this lane; re-warmed onto fresh incarnations.
    warmed: Vec<String>,
}

struct SimLane {
    link: Mutex<LaneLink>,
}

/// The deterministic simulation [`Backend`]. See the module docs.
///
/// Lane workers are **supervised**: when a worker dies unexpectedly (a
/// [`FaultPlan`] kill, or a panic), the next submission detects the dead
/// channel, restarts the lane under [`SupervisorPolicy`] (capped
/// exponential backoff, bounded restart budget, re-warmup of previously
/// warmed modules) and retries the enqueue. In-flight tickets of the dead
/// incarnation fail with [`BackendError::LaneDead`]; KV handles it minted
/// become stale ([`Backend::kv_current`] turns false) and extend/generate
/// against them also report `LaneDead`. Only
/// [`kill_lane_for_test`](Self::kill_lane_for_test) is terminal.
pub struct SimBackend {
    lanes: [SimLane; 2],
    manifest: Manifest,
    lat: SimLatency,
    cfg: BatchConfig,
    faults: Arc<FaultState>,
    policy: SupervisorPolicy,
    /// Host KV tier — backend-level (not lane-level) so demoted copies
    /// survive lane restarts.
    host: Arc<SimHostStore>,
    /// Per-lane bounded-queue gates (unbounded by default); work submits
    /// take a slot here, the lane worker frees it at pickup.
    gates: [Arc<QueueGate>; 2],
    /// Per-lane circuit breakers (inert unless started with a
    /// [`BreakerConfig`]).
    breaker: Arc<BreakerState>,
}

/// Spawn one sim lane worker incarnation.
#[allow(clippy::too_many_arguments)]
fn spawn_sim_worker(manifest: &Manifest, lat: SimLatency, cfg: BatchConfig, lane: Lane,
                    generation: u64, faults: &Arc<FaultState>, host: &Arc<SimHostStore>,
                    gate: &Arc<QueueGate>, breaker: &Arc<BreakerState>)
                    -> anyhow::Result<(Sender<SReq>, Arc<AtomicBool>,
                                       std::thread::JoinHandle<()>)> {
    let (tx, rx) = channel::<SReq>();
    let poison = Arc::new(AtomicBool::new(false));
    let worker_poison = Arc::clone(&poison);
    let worker_manifest = manifest.clone();
    let worker_faults = Arc::clone(faults);
    let worker_host = Arc::clone(host);
    let worker_gate = Arc::clone(gate);
    let worker_breaker = Arc::clone(breaker);
    let lane_cfg = if lane == Lane::Llm { cfg } else { BatchConfig::off() };
    let thread = std::thread::Builder::new()
        .name(format!("sim-{}-g{generation}", lane.name()))
        .spawn(move || {
            sim_lane_main(worker_manifest, lat, lane_cfg, lane, generation, rx,
                          worker_poison, worker_faults, worker_host, worker_gate,
                          worker_breaker)
        })?;
    Ok((tx, poison, thread))
}

impl SimBackend {
    /// Spawn both sim lane workers over `store`'s manifest (use
    /// [`sim_store`] for a self-contained in-memory world) with batching
    /// off — every request its own device call.
    pub fn start(store: &ArtifactStore, lat: SimLatency) -> anyhow::Result<SimBackend> {
        SimBackend::start_with(store, lat, BatchConfig::off())
    }

    /// Like [`start`](Self::start), but the LLM lane micro-batches under
    /// `cfg` (the GNN lane never batches — encodes already overlap the LLM
    /// lane and see no cross-stream convergence).
    pub fn start_with(store: &ArtifactStore, lat: SimLatency, cfg: BatchConfig)
                      -> anyhow::Result<SimBackend> {
        SimBackend::start_faulty(store, lat, cfg, FaultPlan::none(),
                                 SupervisorPolicy::default())
    }

    /// Like [`start_with`](Self::start_with), plus a [`FaultPlan`] and an
    /// explicit [`SupervisorPolicy`] — the chaos-test entry point. Queues
    /// stay unbounded and no circuit breaker is armed (the pre-overload
    /// behaviour); see [`start_guarded`](Self::start_guarded).
    pub fn start_faulty(store: &ArtifactStore, lat: SimLatency, cfg: BatchConfig,
                        plan: FaultPlan, policy: SupervisorPolicy)
                        -> anyhow::Result<SimBackend> {
        SimBackend::start_guarded(store, lat, cfg, plan, policy,
                                  QueueConfig::unbounded(), None)
    }

    /// The full overload-plane entry point: [`start_faulty`] plus bounded
    /// lane queues ([`QueueConfig`] — applied to both lanes) and an
    /// optional per-lane circuit breaker ([`BreakerConfig`]). A full queue
    /// or an open breaker fails work submissions with
    /// [`BackendError::Overloaded`]; control traffic (release / warmup /
    /// stats / tier moves) always passes. The breaker observes executed-op
    /// *outcomes* only, so arming it never perturbs [`FaultPlan`] op
    /// indices — seeded chaos runs stay bit-reproducible.
    #[allow(clippy::too_many_arguments)]
    pub fn start_guarded(store: &ArtifactStore, lat: SimLatency, cfg: BatchConfig,
                         plan: FaultPlan, policy: SupervisorPolicy,
                         queue: QueueConfig, breaker: Option<BreakerConfig>)
                         -> anyhow::Result<SimBackend> {
        let manifest = store.manifest().clone();
        let faults = Arc::new(FaultState::new(plan));
        let host = Arc::new(SimHostStore::default());
        let gates = [Arc::new(QueueGate::new(queue)), Arc::new(QueueGate::new(queue))];
        let breaker = Arc::new(BreakerState::new(breaker));
        let spawn = |lane: Lane| -> anyhow::Result<SimLane> {
            let (tx, poison, thread) =
                spawn_sim_worker(&manifest, lat, cfg, lane, 0, &faults, &host,
                                 &gates[lane as usize], &breaker)?;
            Ok(SimLane {
                link: Mutex::new(LaneLink {
                    tx,
                    poison,
                    thread: Some(thread),
                    generation: 0,
                    restarts: 0,
                    condemned: false,
                    warmed: Vec::new(),
                }),
            })
        };
        let lanes = [spawn(Lane::Llm)?, spawn(Lane::Gnn)?];
        Ok(SimBackend {
            lanes,
            manifest,
            lat,
            cfg,
            faults,
            policy,
            host,
            gates,
            breaker,
        })
    }

    fn link(&self, lane: Lane) -> std::sync::MutexGuard<'_, LaneLink> {
        // a panic while holding the lane lock leaves no partial state worth
        // protecting — recover the guard and keep serving
        match self.lanes[lane as usize].link.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Enqueue on a lane: overload-gate work requests (circuit breaker
    /// check, then a bounded-queue slot), then hand to the supervised
    /// enqueue. A refused submission ([`BackendError::Overloaded`]) touches
    /// no lane state — nothing to undo, retry only after backing off.
    fn send(&self, lane: Lane, req: SReq) -> Result<(), BackendError> {
        let is_work = sreq_key(&req).is_some();
        if is_work {
            self.breaker.check(lane)?;
            // take the queue slot BEFORE the link mutex: a Block-policy
            // wait must never hold the lane lock (control traffic and
            // other submitters keep flowing while this caller waits)
            self.gates[lane as usize].admit(lane)?;
        }
        let sent = self.send_supervised(lane, req);
        if is_work && sent.is_err() {
            // the request never reached the queue; give its slot back
            self.gates[lane as usize].release(1);
        }
        sent
    }

    /// Supervised enqueue: a dead (non-condemned) worker is restarted —
    /// capped exponential backoff, bumped generation, re-warmup — and the
    /// enqueue retried, until the restart budget runs out.
    fn send_supervised(&self, lane: Lane, req: SReq) -> Result<(), BackendError> {
        let mut link = self.link(lane);
        let mut req = req;
        loop {
            req = match link.tx.send(req) {
                Ok(()) => return Ok(()),
                // the send hands the request back on failure; supervise
                Err(e) => e.0,
            };
            if link.condemned {
                return Err(BackendError::lane_dead(
                    lane,
                    format!("sim {} lane worker has shut down", lane.name()),
                ));
            }
            if link.restarts >= self.policy.max_restarts {
                return Err(BackendError::lane_dead(
                    lane,
                    format!("sim {} lane worker died and its restart budget ({}) \
                             is exhausted",
                            lane.name(), self.policy.max_restarts),
                ));
            }
            if let Some(t) = link.thread.take() {
                let _ = t.join();
            }
            link.restarts += 1;
            link.generation += 1;
            let backoff = self.policy.backoff(link.restarts);
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            let (tx, poison, thread) =
                spawn_sim_worker(&self.manifest, self.lat, self.cfg, lane,
                                 link.generation, &self.faults, &self.host,
                                 &self.gates[lane as usize], &self.breaker)
                    .map_err(|e| {
                        BackendError::lane_dead(lane, format!("lane restart failed: {e}"))
                    })?;
            link.tx = tx;
            link.poison = poison;
            link.thread = Some(thread);
            // the dead incarnation's channel dropped every queued request,
            // so the slots they held are meaningless — free them (and any
            // blocked submitters) and give the fresh worker a closed breaker
            self.gates[lane as usize].reset();
            self.breaker.reset(lane);
            // re-warm what the dead incarnation had warmed, then retry the
            // original request on the fresh worker
            for m in &link.warmed {
                let (reply, _rx) = channel();
                let _ = link.tx.send(SReq::Warmup { module: m.clone(), reply });
            }
        }
    }

    /// Best-effort enqueue that never triggers a restart (KV releases: a
    /// dead lane already dropped the buffers being returned).
    fn send_casual(&self, lane: Lane, req: SReq) {
        let _ = self.link(lane).tx.send(req);
    }

    /// Test hook: kill one lane's worker thread *without* draining its
    /// queue, **terminally** — the supervisor never resurrects a condemned
    /// lane. Requests already being processed complete; requests still
    /// queued get their reply senders dropped (so `wait` errors with
    /// [`BackendError::LaneDead`]), and later `submit_*` calls on the lane
    /// fail. This is how the dead-lane regression tests exercise the
    /// multi-lane ticket contract. For *recoverable* lane death, schedule a
    /// kill through [`FaultPlan`] instead.
    pub fn kill_lane_for_test(&self, lane: Lane) {
        let mut link = self.link(lane);
        link.condemned = true;
        link.poison.store(true, Ordering::SeqCst);
        let _ = link.tx.send(SReq::Shutdown); // nudge an idle worker awake
        if let Some(t) = link.thread.take() {
            let _ = t.join();
        }
        // wake any Block-policy submitters still waiting on a queue slot:
        // their retried enqueue then fails fast with LaneDead instead of
        // blocking out the full timeout
        self.gates[lane as usize].reset();
    }

    /// Supervisor restarts performed so far (summed across lanes).
    pub fn lane_restarts(&self) -> u64 {
        Lane::ALL.iter().map(|&l| self.link(l).restarts as u64).sum()
    }

    /// Injected faults so far: (transient errors, latency spikes).
    pub fn injected_faults(&self) -> (u64, u64) {
        (self.faults.transients.load(Ordering::Relaxed),
         self.faults.spikes.load(Ordering::Relaxed))
    }

    /// Circuit-breaker trips so far (summed across lanes; 0 when no
    /// breaker was armed).
    pub fn breaker_trips(&self) -> u64 {
        self.breaker.trips()
    }
}

impl Backend for SimBackend {
    fn submit_prefill(&self, module: &str, tokens: &[i32], plen: i32)
                      -> Result<PendingPrefill, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, SReq::Prefill {
            module: module.into(), tokens: tokens.to_vec(), plen,
            submitted: Instant::now(), reply,
        })?;
        Ok(PendingKv(Ticket { rx, lane: Lane::Llm }))
    }

    fn submit_extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32],
                     qlen: i32) -> Result<PendingExtend, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, SReq::Extend {
            module: module.into(), kv: kv.0, plen, q_tokens: q_tokens.to_vec(), qlen,
            submitted: Instant::now(), reply,
        })?;
        Ok(PendingKv(Ticket { rx, lane: Lane::Llm }))
    }

    fn submit_generate(&self, module: &str, kv: &KvHandle, _cur_len: i32, first_tok: i32)
                       -> Result<PendingGenerate, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Llm, SReq::Generate {
            module: module.into(), kv: kv.0, first_tok,
            submitted: Instant::now(), reply,
        })?;
        Ok(PendingGenerate(Ticket { rx, lane: Lane::Llm }))
    }

    fn submit_encode(&self, module: &str, x: Vec<f32>, _adj: Vec<f32>, mask: Vec<f32>)
                     -> Result<PendingEncode, BackendError> {
        let (reply, rx) = channel();
        self.send(Lane::Gnn, SReq::Encode {
            module: module.into(), x, mask, submitted: Instant::now(), reply,
        })?;
        Ok(PendingEncode(Ticket { rx, lane: Lane::Gnn }))
    }

    fn release(&self, kv: KvHandle) {
        // host-tier handles live in the backend-level store — drop them
        // directly, no lane round-trip
        if is_host_handle(kv.0) {
            self.host.lock().remove(&kv.0);
            return;
        }
        // best-effort and never restart-triggering: a dead lane has already
        // dropped the buffers being returned
        self.send_casual(Lane::Llm, SReq::Release { kvs: vec![kv.0] });
    }

    fn release_many(&self, kvs: Vec<KvHandle>) {
        if kvs.is_empty() {
            return;
        }
        let (host, device): (Vec<u64>, Vec<u64>) =
            kvs.into_iter().map(|h| h.0).partition(|&id| is_host_handle(id));
        if !host.is_empty() {
            let mut g = self.host.lock();
            for id in host {
                g.remove(&id);
            }
        }
        if !device.is_empty() {
            self.send_casual(Lane::Llm, SReq::Release { kvs: device });
        }
    }

    fn demote_kv(&self, kv: KvHandle) -> Result<KvHandle, BackendError> {
        if is_host_handle(kv.0) {
            return Err(BackendError::fatal(format!(
                "demote_kv: handle {} is already host-resident", kv.0)));
        }
        let (reply, rx) = channel();
        self.send(Lane::Llm, SReq::Demote {
            kv: kv.0, submitted: Instant::now(), reply,
        })?;
        let (id, _t) = (Ticket { rx, lane: Lane::Llm }).wait()?;
        Ok(KvHandle(id))
    }

    fn submit_promote(&self, kv: &KvHandle) -> Result<PendingPromote, BackendError> {
        if !is_host_handle(kv.0) {
            return Err(BackendError::fatal(format!(
                "promote: handle {} is device-resident, not host-tier", kv.0)));
        }
        let (reply, rx) = channel();
        self.send(Lane::Llm, SReq::Promote {
            host: kv.0, submitted: Instant::now(), reply,
        })?;
        Ok(PendingPromote(Ticket { rx, lane: Lane::Llm }))
    }

    fn archive_kv(&self, kv: KvHandle) -> Result<Vec<u8>, BackendError> {
        if !is_host_handle(kv.0) {
            self.release(kv);
            return Err(BackendError::fatal(format!(
                "archive_kv: handle {} is device-resident, not host-tier", kv.0)));
        }
        // the host store is backend-owned (no lane traffic): serialize the
        // token sequence as little-endian i32s, consuming the host copy.
        let seq = self.host.lock().remove(&kv.0).ok_or_else(|| {
            BackendError::fatal(format!("archive_kv: unknown host-tier handle {}", kv.0))
        })?;
        let mut out = Vec::with_capacity(seq.len() * 4);
        for t in seq {
            out.extend_from_slice(&t.to_le_bytes());
        }
        Ok(out)
    }

    fn recall_kv(&self, bytes: &[u8]) -> Result<KvHandle, BackendError> {
        if bytes.len() % 4 != 0 {
            return Err(BackendError::fatal(format!(
                "recall_kv: payload length {} is not a whole token sequence",
                bytes.len())));
        }
        let seq: Vec<i32> = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let id = HOST_BIT | (self.host.next.fetch_add(1, Ordering::Relaxed) + 1);
        self.host.lock().insert(id, seq);
        Ok(KvHandle(id))
    }

    fn kv_bytes(&self, module: &str) -> Result<usize, BackendError> {
        let dims = self
            .manifest
            .module(module)
            .map_err(BackendError::from_anyhow)?
            .dims
            .ok_or_else(|| {
                BackendError::fatal(format!("{module}: not an llm module, no KV geometry"))
            })?;
        Ok(2 * dims.kv_bytes_each())
    }

    fn warmup(&self, module: &str) -> Result<(), BackendError> {
        let kind = &self
            .manifest
            .module(module)
            .map_err(BackendError::from_anyhow)?
            .kind;
        let lane = lane_for_kind(kind).ok_or_else(|| {
            BackendError::fatal(format!("module {module}: no lane for its kind"))
        })?;
        let (reply, rx) = channel();
        self.send(lane, SReq::Warmup { module: module.into(), reply })?;
        Ticket { rx, lane }.wait()?;
        // remember what was warmed so the supervisor can re-warm a fresh
        // incarnation after a restart
        let mut link = self.link(lane);
        if !link.warmed.iter().any(|m| m == module) {
            link.warmed.push(module.to_string());
        }
        Ok(())
    }

    fn stats(&self) -> Result<EngineStats, BackendError> {
        let mut parts = Vec::with_capacity(Lane::ALL.len());
        for lane in Lane::ALL {
            let (reply, rx) = channel();
            self.send(lane, SReq::Stats { reply })?;
            parts.push(rx.recv().map_err(|_| {
                BackendError::lane_dead(
                    lane,
                    format!("sim {} lane died before replying to stats", lane.name()),
                )
            })?);
        }
        let mut merged = merge_stats(parts);
        merged.lane_restarts = self.lane_restarts();
        merged.breaker_trips = self.breaker.trips();
        Ok(merged)
    }

    /// Work requests queued on `lane` (admitted but not yet picked up by
    /// the worker) — the depth gauge behind the bounded-queue policy.
    fn queue_depth(&self, lane: Lane) -> usize {
        self.gates[lane as usize].depth()
    }

    /// A device handle is current iff its generation tag matches the LLM
    /// lane's live incarnation (handles are minted only on the LLM lane).
    /// Host-tier handles live outside any incarnation and are always
    /// current — that is what lets quarantine spare host copies.
    fn kv_current(&self, kv: &KvHandle) -> bool {
        is_host_handle(kv.0) || handle_gen(kv.0) == self.link(Lane::Llm).generation
    }
}

impl Drop for SimBackend {
    fn drop(&mut self) {
        // raw sends on the live links — never supervise during teardown
        for lane in &self.lanes {
            let link = match lane.link.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let _ = link.tx.send(SReq::Shutdown);
        }
        for lane in &self.lanes {
            let mut link = match lane.link.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            if let Some(t) = link.thread.take() {
                let _ = t.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Lane worker
// ---------------------------------------------------------------------------

struct SimState {
    manifest: Manifest,
    lat: SimLatency,
    lane: Lane,
    /// This worker's incarnation; minted KV handle ids carry it in their
    /// high bits so stale handles are recognizable after a restart.
    generation: u64,
    /// KV handle -> the effective (unpadded) token sequence it encodes.
    kvs: HashMap<u64, Vec<i32>>,
    next_id: u64,
    counters: HashMap<String, (u64, f64)>,
    /// Backend-level host tier (shared across incarnations).
    host: Arc<SimHostStore>,
    /// Bytes of one backbone KV cache (k + v), for tier-copy latency.
    kv_copy_bytes: usize,
}

/// Fusibility key: op kind + module (backbone). Two requests may share a
/// batch iff their keys are equal; control traffic (release / warmup /
/// stats / shutdown) has no key and never fuses.
fn sreq_key(r: &SReq) -> Option<(u8, &str)> {
    match r {
        SReq::Prefill { module, .. } => Some((0, module)),
        SReq::Extend { module, .. } => Some((1, module)),
        SReq::Generate { module, .. } => Some((2, module)),
        SReq::Encode { module, .. } => Some((3, module)),
        _ => None,
    }
}

/// Lane-side timing of one tier move (demote/promote): queue wait up to
/// `picked`, then everything since `picked` (the copy sleep) as the device
/// span. Tier moves never ride a batch window.
fn tier_timing(submitted: Instant, picked: Instant) -> CallTiming {
    CallTiming {
        queue_secs: picked.saturating_duration_since(submitted).as_secs_f64(),
        device_secs: picked.elapsed().as_secs_f64(),
        ..Default::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn sim_lane_main(manifest: Manifest, lat: SimLatency, cfg: BatchConfig, lane: Lane,
                 generation: u64, rx: Receiver<SReq>, poison: Arc<AtomicBool>,
                 faults: Arc<FaultState>, host: Arc<SimHostStore>,
                 gate: Arc<QueueGate>, breaker: Arc<BreakerState>) {
    let kv_copy_bytes = manifest
        .llm_names()
        .first()
        .and_then(|n| manifest.module(n).ok())
        .and_then(|m| m.dims)
        .map(|d| 2 * d.kv_bytes_each())
        .unwrap_or(0);
    let mut st = SimState {
        manifest,
        lat,
        lane,
        generation,
        kvs: HashMap::new(),
        next_id: 1,
        counters: HashMap::new(),
        host,
        kv_copy_bytes,
    };
    // An incompatible request that closed the previous batch window; it is
    // processed before anything newer (lane FIFO).
    let mut carry: Option<SReq> = None;
    loop {
        let req = match carry.take() {
            Some(r) => r,
            None => match rx.recv() {
                Ok(r) => r,
                Err(_) => return,
            },
        };
        if poison.load(Ordering::SeqCst) {
            return; // test hook: die with the queue undrained
        }
        if sreq_key(&req).is_none() {
            match req {
                SReq::Release { kvs } => {
                    for kv in kvs {
                        st.kvs.remove(&kv);
                    }
                }
                SReq::Demote { kv, submitted, reply } => {
                    let picked = Instant::now();
                    let r = st.demote(kv);
                    let _ = reply.send(r.map(|id| (id, tier_timing(submitted, picked))));
                }
                SReq::Promote { host, submitted, reply } => {
                    let picked = Instant::now();
                    let r = st.promote(host);
                    let _ = reply.send(r.map(|id| (id, tier_timing(submitted, picked))));
                }
                SReq::Warmup { module, reply } => {
                    let _ = reply.send(
                        st.manifest
                            .module(&module)
                            .map(|_| ())
                            .map_err(BackendError::from_anyhow),
                    );
                }
                SReq::Stats { reply } => {
                    let mut calls: Vec<(String, u64, f64)> = st
                        .counters
                        .iter()
                        .map(|(k, &(n, s))| (k.clone(), n, s))
                        .collect();
                    calls.sort_by(|a, b| a.0.cmp(&b.0));
                    // the LLM lane reports the shared host tier (exactly
                    // one lane must, or merge_stats would double-count)
                    let host_kv = if st.lane == Lane::Llm { st.host.len() } else { 0 };
                    let _ = reply.send(EngineStats {
                        calls,
                        live_kv: st.kvs.len() + host_kv,
                        compile_secs: 0.0,
                        host_kv_bytes: 0,
                        unbatched_fallbacks: 0,
                        lane_restarts: 0, // accounted by the supervisor, not per worker
                        breaker_trips: 0, // likewise backend-level, not per worker
                    });
                }
                SReq::Shutdown => return,
                _ => unreachable!("fusible requests are handled below"),
            }
            continue;
        }
        let mut col = collect_window(&rx, req, cfg, |a, b| sreq_key(a) == sreq_key(b));
        carry = col.carry.take();
        // every member has left the channel: free its queue slot now (a
        // carried work request frees its slot in the batch it executes in,
        // where it is counted as a member)
        gate.release(col.members.len());
        if poison.load(Ordering::SeqCst) {
            // die mid-batch: every member's reply sender drops here, so
            // each ticket's wait errors instead of hanging
            return;
        }
        if !st.run_batch(col, &faults, &breaker) {
            // FaultPlan kill: abandon the batch (all reply senders drop, so
            // every member's wait reports LaneDead) and exit the worker —
            // the supervisor restarts the lane on the next submission
            return;
        }
    }
}

/// Per-member staged result + reply slot (all members of one batch share a
/// variant, but the reply channel types differ per variant).
enum BatchOut {
    Kv(Result<(u64, Vec<f32>), BackendError>, KvReply),
    Gen(Result<Vec<i32>, BackendError>,
        Sender<Result<(Vec<i32>, CallTiming), BackendError>>),
    Enc(Result<Vec<f32>, BackendError>,
        Sender<Result<(Vec<f32>, CallTiming), BackendError>>),
}

impl SimState {
    /// Execute one collected batch as ONE fused device call: a single
    /// sleep of `base + per_item * (n-1)`, then every member's semantic op
    /// in arrival order (determinism: results are bit-identical to the
    /// unbatched path), then scatter per-member replies with the timing
    /// split described in [`crate::runtime::batch`].
    ///
    /// Consults [`FaultState::on_op`] once per member: a `Transient` stages
    /// a typed error for that one member *without executing it* (no side
    /// effects — retrying it is clean and the rest of the batch is
    /// unaffected), and a `Kill` returns `false` — the worker must exit,
    /// dropping every reply sender of the batch.
    fn run_batch(&mut self, mut col: Collected<SReq>, faults: &FaultState,
                 breaker: &BreakerState) -> bool {
        let n = col.members.len();
        let (op, base, slope) = match &col.members[0].0 {
            SReq::Prefill { .. } => ("prefill", self.lat.prefill, self.lat.per_item.prefill),
            SReq::Extend { .. } => ("extend", self.lat.extend, self.lat.per_item.extend),
            SReq::Generate { .. } => {
                ("generate", self.lat.generate, self.lat.per_item.generate)
            }
            SReq::Encode { .. } => ("encode", self.lat.encode, self.lat.per_item.encode),
            _ => unreachable!("control requests never enter a batch"),
        };
        let module = match &col.members[0].0 {
            SReq::Prefill { module, .. }
            | SReq::Extend { module, .. }
            | SReq::Generate { module, .. }
            | SReq::Encode { module, .. } => module.clone(),
            _ => unreachable!(),
        };
        let t0 = Instant::now();
        let sleep = self.lat.batch_sleep(base, slope, n);
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        let mut outs = Vec::with_capacity(n);
        for (req, picked) in col.members.drain(..) {
            let inject = faults.on_op(self.lane);
            if matches!(inject, Inject::Kill) {
                return false; // abandon the batch; the worker dies here
            }
            fn transient<T>(op: &'static str) -> Result<T, BackendError> {
                Err(BackendError::transient(op, "injected fault (FaultPlan)"))
            }
            let hit = matches!(inject, Inject::Transient);
            let (out, submitted) = match req {
                SReq::Prefill { module, tokens, plen, submitted, reply } => {
                    let r = if hit { transient("prefill") }
                            else { self.prefill(&module, &tokens, plen) };
                    observe_breaker(breaker, self.lane, &r);
                    (BatchOut::Kv(r, reply), submitted)
                }
                SReq::Extend { module, kv, plen, q_tokens, qlen, submitted, reply } => {
                    let r = if hit { transient("extend") }
                            else { self.extend(&module, kv, plen, &q_tokens, qlen) };
                    observe_breaker(breaker, self.lane, &r);
                    (BatchOut::Kv(r, reply), submitted)
                }
                SReq::Generate { module, kv, first_tok, submitted, reply } => {
                    let r = if hit { transient("generate") }
                            else { self.generate(&module, kv, first_tok) };
                    observe_breaker(breaker, self.lane, &r);
                    (BatchOut::Gen(r, reply), submitted)
                }
                SReq::Encode { module, x, mask, submitted, reply } => {
                    let r = if hit { transient("encode") }
                            else { self.encode(&module, &x, &mask) };
                    observe_breaker(breaker, self.lane, &r);
                    (BatchOut::Enc(r, reply), submitted)
                }
                _ => unreachable!("control requests never enter a batch"),
            };
            outs.push((out, submitted, picked));
        }
        let device_secs = t0.elapsed().as_secs_f64();
        let c = self.counters.entry(format!("{module}.{op}")).or_insert((0, 0.0));
        c.0 += n as u64; // members executed
        c.1 += device_secs; // device span counted once per launch
        for (i, (out, submitted, picked)) in outs.into_iter().enumerate() {
            let t = CallTiming {
                queue_secs: picked.saturating_duration_since(submitted).as_secs_f64(),
                window_secs: col.launched.saturating_duration_since(picked).as_secs_f64(),
                device_secs,
                batch: BatchInfo::member(i, n, col.stalled),
            };
            match out {
                BatchOut::Kv(r, reply) => {
                    let _ = reply.send(r.map(|(id, logits)| (id, logits, t)));
                }
                BatchOut::Gen(r, reply) => {
                    let _ = reply.send(r.map(|toks| (toks, t)));
                }
                BatchOut::Enc(r, reply) => {
                    let _ = reply.send(r.map(|emb| (emb, t)));
                }
            }
        }
        true
    }

    fn llm_dims(&self, module: &str) -> Result<LlmDims, BackendError> {
        self.manifest
            .module(module)
            .map_err(BackendError::from_anyhow)?
            .dims
            .ok_or_else(|| BackendError::fatal(format!("{module}: not an llm module")))
    }

    fn insert_kv(&mut self, seq: Vec<i32>) -> u64 {
        // the id carries this worker's incarnation in its high bits, so
        // handles outlive restarts recognizably stale (see `handle_gen`)
        let id = (self.generation << GEN_SHIFT) | self.next_id;
        self.next_id += 1;
        self.kvs.insert(id, seq);
        id
    }

    /// Resolve a KV handle, distinguishing "belongs to a dead incarnation"
    /// (`LaneDead` — the caller should quarantine and recompute) from
    /// "never existed / already released in this incarnation" (`Fatal`).
    fn lookup_kv(&self, kv: u64) -> Result<&Vec<i32>, BackendError> {
        if let Some(seq) = self.kvs.get(&kv) {
            return Ok(seq);
        }
        if is_host_handle(kv) {
            return Err(BackendError::fatal(format!(
                "KV handle {kv} is host-resident; promote it before use")));
        }
        if handle_gen(kv) != self.generation {
            Err(BackendError::lane_dead(
                self.lane,
                format!("KV handle {kv} belongs to dead incarnation {} (lane is at \
                         {}); its device state died with the worker",
                        handle_gen(kv), self.generation),
            ))
        } else {
            Err(BackendError::fatal(format!("unknown/released KV handle {kv}")))
        }
    }

    /// Demote `kv` to the host store: sleep the per-byte copy cost, free
    /// the device copy, mint a [`HOST_BIT`]-tagged host id.
    fn demote(&mut self, kv: u64) -> Result<u64, BackendError> {
        self.lookup_kv(kv)?; // classify stale/unknown before any copy work
        let copy = self.lat.host_copy(self.kv_copy_bytes);
        if !copy.is_zero() {
            std::thread::sleep(copy);
        }
        let seq = self.kvs.remove(&kv).expect("looked up above");
        let id = HOST_BIT | (self.host.next.fetch_add(1, Ordering::Relaxed) + 1);
        self.host.lock().insert(id, seq);
        Ok(id)
    }

    /// Promote a host-store KV back onto the device. The host copy is
    /// consumed only on success — an error (or a lane death before this
    /// runs) leaves it intact for the caller to retry or release.
    fn promote(&mut self, host: u64) -> Result<u64, BackendError> {
        let seq = self.host.lock().get(&host).cloned().ok_or_else(|| {
            BackendError::fatal(format!("unknown host-tier KV handle {host}"))
        })?;
        let copy = self.lat.host_copy(self.kv_copy_bytes);
        if !copy.is_zero() {
            std::thread::sleep(copy);
        }
        self.host.lock().remove(&host);
        Ok(self.insert_kv(seq))
    }

    fn prefill(&mut self, module: &str, tokens: &[i32], plen: i32)
               -> Result<(u64, Vec<f32>), BackendError> {
        let dims = self.llm_dims(module)?;
        let c = self.manifest.constants;
        if tokens.len() != c.max_seq {
            return Err(BackendError::fatal(format!(
                "sim prefill: {} tokens, want {}", tokens.len(), c.max_seq)));
        }
        if plen < 0 || plen as usize > tokens.len() {
            return Err(BackendError::fatal(format!(
                "sim prefill: plen {plen} out of range")));
        }
        let seq = tokens[..plen as usize].to_vec();
        let logits = sim_logits(&seq, dims.vocab);
        Ok((self.insert_kv(seq), logits))
    }

    fn extend(&mut self, module: &str, kv: u64, _plen: i32, q_tokens: &[i32], qlen: i32)
              -> Result<(u64, Vec<f32>), BackendError> {
        let dims = self.llm_dims(module)?;
        let c = self.manifest.constants;
        if q_tokens.len() != c.max_q {
            return Err(BackendError::fatal(format!(
                "sim extend: {} tokens, want {}", q_tokens.len(), c.max_q)));
        }
        let qlen = (qlen.max(0) as usize).min(q_tokens.len()); // clamp like the engine
        let mut seq = self.lookup_kv(kv)?.clone();
        seq.extend_from_slice(&q_tokens[..qlen]);
        let logits = sim_logits(&seq, dims.vocab);
        Ok((self.insert_kv(seq), logits))
    }

    fn generate(&mut self, module: &str, kv: u64, first_tok: i32)
                -> Result<Vec<i32>, BackendError> {
        let dims = self.llm_dims(module)?;
        let c = self.manifest.constants;
        let seq = self.lookup_kv(kv)?.clone();
        // greedy roll-forward, like the generate HLO: the output includes
        // `first_tok` and stops at max_gen (decode stops at EOS host-side).
        let mut out = vec![first_tok];
        let mut cur = seq;
        cur.push(first_tok);
        while out.len() < c.max_gen {
            let next = crate::coordinator::argmax(&sim_logits(&cur, dims.vocab));
            out.push(next);
            cur.push(next);
            if next == c.eos_id {
                break;
            }
        }
        Ok(out)
    }

    fn encode(&mut self, module: &str, x: &[f32], mask: &[f32])
              -> Result<Vec<f32>, BackendError> {
        let m = self.manifest.module(module).map_err(BackendError::from_anyhow)?;
        if m.kind != "gnn" {
            return Err(BackendError::fatal(format!("{module}: not a gnn module")));
        }
        let c = self.manifest.constants;
        let (n, f) = (c.n_max, c.feat_dim);
        if x.len() != n * f || mask.len() != n {
            return Err(BackendError::fatal("sim encode: bad input sizes"));
        }
        // masked mean over packed node features: similar subgraphs land
        // close, disjoint ones far — enough signal for centroid matching.
        let mut out = vec![0f32; c.gnn_emb];
        let mut cnt = 0f32;
        for (i, &mi) in mask.iter().enumerate() {
            if mi > 0.0 {
                cnt += 1.0;
                for (j, &v) in x[i * f..(i + 1) * f].iter().enumerate() {
                    out[j % c.gnn_emb] += v;
                }
            }
        }
        if cnt > 0.0 {
            for o in &mut out {
                *o /= cnt;
            }
        }
        Ok(out)
    }
}

fn splitmix(z: u64) -> u64 {
    crate::util::rng::splitmix64(z.wrapping_add(0x9E3779B97F4A7C15))
}

/// Deterministic next-token logits for an effective token sequence: a pure
/// hash of the sequence, so any two call paths that assemble the same
/// sequence (full prefill vs prefill + extend) get bit-identical rows.
fn sim_logits(seq: &[i32], vocab: usize) -> Vec<f32> {
    let mut h = 0xcbf29ce484222325u64; // FNV-1a over the token ids
    for &t in seq {
        h = (h ^ t as u32 as u64).wrapping_mul(0x100000001b3);
    }
    let mut out = vec![0f32; vocab];
    for (i, o) in out.iter_mut().enumerate() {
        *o = (splitmix(h ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15)) % 1000) as f32
            / 1000.0;
    }
    // a clear, deterministic winner outside the special ids
    if vocab > 4 {
        out[4 + (splitmix(h) % (vocab as u64 - 4)) as usize] = 2.0;
    }
    out
}

// ---------------------------------------------------------------------------
// Synthetic artifact world
// ---------------------------------------------------------------------------

/// Default simulated backbone name (an "llm" module in the sim manifest).
pub const SIM_BACKBONE: &str = "sim-llm";

fn sim_constants(vocab: usize) -> Constants {
    // mirrors the real artifact relation max_prefix = max_seq - max_q -
    // max_gen, so full-prompt and prefix+extend truncation agree exactly
    // (the parity the answer-match tests rely on).
    Constants {
        max_seq: 256,
        max_q: 24,
        max_gen: 8,
        max_prefix: 256 - 24 - 8,
        vocab,
        feat_dim: FEAT_DIM,
        n_max: 32,
        gnn_emb: FEAT_DIM,
        pad_id: PAD_ID,
        bos_id: BOS_ID,
        eos_id: EOS_ID,
        unk_id: UNK_ID,
    }
}

/// In-memory artifact store for sim runs: a manifest with one LLM backbone
/// ([`SIM_BACKBONE`]) and both GNN encoders, plus a tokenizer whose vocab
/// covers the [`sim_dataset`] text. Pairs with [`SimBackend::start`].
pub fn sim_store() -> ArtifactStore {
    // absorb the full topic/color cycles so any sim_dataset(..) tokenizes
    // without <unk> surprises
    let ds = sim_dataset(8, 4);
    let mut words: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    let mut absorb = |text: &str| {
        for w in split_text(text) {
            words.insert(w);
        }
    };
    absorb("graph : ; question : answer :");
    for n in &ds.graph.nodes {
        absorb(&n.name);
        absorb(&n.text);
    }
    for e in &ds.graph.edges {
        absorb(&e.text);
    }
    for q in &ds.queries {
        absorb(&q.text);
        absorb(&q.answer);
    }
    let mut vocab: HashMap<String, i32> = HashMap::new();
    for (sp, id) in [("<pad>", PAD_ID), ("<bos>", BOS_ID), ("<eos>", EOS_ID),
                     ("<unk>", UNK_ID)] {
        vocab.insert(sp.to_string(), id);
    }
    for w in words {
        let next = vocab.len() as i32;
        vocab.entry(w).or_insert(next);
    }
    let tokenizer = Tokenizer::from_vocab(vocab).expect("sim vocab is well-formed");
    let constants = sim_constants(tokenizer.padded_size());

    let llm_dims = LlmDims {
        vocab: constants.vocab,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_head: 8,
        d_ff: 64,
        max_seq: constants.max_seq,
    };
    let module = |name: &str, kind: &str, dims: Option<LlmDims>| ModuleSpec {
        name: name.to_string(),
        kind: kind.to_string(),
        params: Vec::new(),
        entries: std::collections::BTreeMap::new(),
        dims,
    };
    let mut modules = std::collections::BTreeMap::new();
    modules.insert(SIM_BACKBONE.into(), module(SIM_BACKBONE, "llm", Some(llm_dims)));
    modules.insert("graph_transformer".into(), module("graph_transformer", "gnn", None));
    modules.insert("gat".into(), module("gat", "gnn", None));
    ArtifactStore::in_memory(Manifest { constants, modules }, tokenizer)
}

/// Deterministic synthetic dataset: `n_groups` lexically distinct node
/// groups, `per_group` test queries each. Queries of one group retrieve
/// subgraphs inside that group, so GNN embeddings cluster by group — which
/// gives the online path real hit/miss structure to schedule around.
pub fn sim_dataset(n_groups: usize, per_group: usize) -> Dataset {
    let topics = ["river", "forest", "engine", "museum", "harbor", "signal",
                  "castle", "market"];
    let colors = ["red", "blue", "green", "amber", "violet", "teal", "ivory",
                  "coral"];
    let nodes_per_group = 4usize;
    let mut nodes = Vec::new();
    let mut edges = Vec::new();
    for g in 0..n_groups {
        let topic = topics[g % topics.len()];
        let base = g * nodes_per_group;
        for i in 0..nodes_per_group {
            let color = colors[(g + i) % colors.len()];
            nodes.push(Node {
                id: base + i,
                name: format!("{topic}_{i}"),
                text: format!("{topic}_{i} kind {topic} color {color}"),
            });
            if i > 0 {
                edges.push(Edge {
                    src: base + i - 1,
                    dst: base + i,
                    text: format!("near the {topic}"),
                });
            }
        }
    }
    let graph = TextualGraph::new("sim", nodes, edges).expect("sim graph is valid");

    let mut queries = Vec::new();
    for g in 0..n_groups {
        let topic = topics[g % topics.len()];
        for i in 0..per_group {
            let ni = i % nodes_per_group;
            let color = colors[(g + ni) % colors.len()];
            queries.push(Query {
                id: queries.len(),
                text: format!("what color is {topic}_{ni} of the {topic} ?"),
                answer: color.to_string(),
                split: Split::Test,
                support: Subgraph::from_parts([g * nodes_per_group + ni], 0..0),
            });
        }
    }
    Dataset { graph, queries }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> (ArtifactStore, SimBackend) {
        let store = sim_store();
        let sim = SimBackend::start(&store, SimLatency::zero()).unwrap();
        (store, sim)
    }

    #[test]
    fn sim_world_is_consistent() {
        let store = sim_store();
        assert_eq!(store.tokenizer().padded_size(), store.constants().vocab);
        assert_eq!(store.manifest().llm_names(), vec![SIM_BACKBONE]);
        let mut gnns = store.manifest().gnn_names();
        gnns.sort_unstable();
        assert_eq!(gnns, vec!["gat", "graph_transformer"]);
        let ds = sim_dataset(3, 5);
        assert_eq!(ds.sample_test(100, 1).len(), 15, "all queries are test split");
    }

    #[test]
    fn prefill_extend_composes_like_full_prefill() {
        // The parity property the PJRT engine has and every answer-match
        // e2e test relies on: prefix ⊕ question in one prefill must match
        // prefill(prefix) + extend(question) bit for bit.
        let (store, sim) = sim();
        let c = *store.constants();
        let mut full = vec![c.pad_id; c.max_seq];
        let mut prefix = vec![c.pad_id; c.max_seq];
        let mut q = vec![c.pad_id; c.max_q];
        for i in 0..40 {
            full[i] = 5 + i as i32;
            prefix[i] = 5 + i as i32;
        }
        for i in 0..6 {
            full[40 + i] = 100 + i as i32;
            q[i] = 100 + i as i32;
        }
        let (kv_full, row_full) = sim.prefill(SIM_BACKBONE, &full, 46).unwrap();
        let (kv_pre, _) = sim.prefill(SIM_BACKBONE, &prefix, 40).unwrap();
        let (kv_ext, row_ext) = sim.extend(SIM_BACKBONE, &kv_pre, 40, &q, 6).unwrap();
        assert_eq!(row_full, row_ext, "composed sequence must hash identically");
        // extend must not consume its input (the SubGCache property)
        let (kv_ext2, row_ext2) = sim.extend(SIM_BACKBONE, &kv_pre, 40, &q, 6).unwrap();
        assert_eq!(row_ext, row_ext2);
        sim.release_many(vec![kv_full, kv_pre, kv_ext, kv_ext2]);
        let st = sim.stats().unwrap();
        assert_eq!(st.live_kv, 0, "all sim KV entries released");
        assert_eq!(st.host_kv_bytes, 0);
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let (store, sim) = sim();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, row) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        let first = crate::coordinator::argmax(&row);
        let a = sim.generate(SIM_BACKBONE, &kv, 1, first).unwrap();
        let b = sim.generate(SIM_BACKBONE, &kv, 1, first).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0], first);
        assert!(a.len() <= c.max_gen);
        sim.release(kv);
    }

    #[test]
    fn encode_groups_similar_subgraphs() {
        let (store, sim) = sim();
        let c = *store.constants();
        let one = |salt: f32| {
            let mut x = vec![0f32; c.n_max * c.feat_dim];
            let mut mask = vec![0f32; c.n_max];
            for i in 0..4 {
                mask[i] = 1.0;
                for j in 0..c.feat_dim {
                    x[i * c.feat_dim + j] = salt + (j as f32) * 0.01;
                }
            }
            sim.encode("gat", x, vec![0.0; c.n_max * c.n_max], mask).unwrap()
        };
        let (a, b, far) = (one(1.0), one(1.0), one(9.0));
        assert_eq!(a.len(), c.gnn_emb);
        assert_eq!(a, b, "encode is deterministic");
        assert!(crate::embed::sq_dist(&a, &far) > 1.0, "distinct inputs separate");
    }

    #[test]
    fn unknown_kv_handle_is_an_error_not_a_hang() {
        let (store, sim) = sim();
        let q = vec![0i32; store.constants().max_q];
        let err = sim
            .extend(SIM_BACKBONE, &KvHandle(777), 4, &q, 3)
            .unwrap_err();
        assert!(err.to_string().contains("777"), "unhelpful error: {err}");
    }

    #[test]
    fn sim_latency_fits_from_bench_json_fixture() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/BENCH_engine.json");
        let lat = SimLatency::from_bench_json(path).unwrap();
        // the fixture carries two prefill rows (8 ms device-resident, 12 ms
        // host-bounce): the fit is their mean. The "prefill->extend
        // handoff" row must not contaminate either op.
        assert_eq!(lat.prefill, Duration::from_millis(10));
        assert_eq!(lat.extend, Duration::from_millis(3));
        assert_eq!(lat.generate, Duration::from_millis(5));
        assert_eq!(lat.encode, Duration::from_millis(2));
        assert!(lat.serial_sum() > 0.019 && lat.serial_sum() < 0.021);
        // batched rows (`batch=<n>` in the name) fit the per-item slope and
        // must NOT contaminate the base fit: prefill batch=4 @ 16 ms over a
        // 10 ms base → 2 ms/item; extend batch=2 @ 5 ms and batch=4 @ 9 ms
        // over a 3 ms base → 2 ms/item from both rows.
        assert_eq!(lat.per_item.prefill, Duration::from_millis(2));
        assert_eq!(lat.per_item.extend, Duration::from_millis(2));
        // ops without batched rows keep the serial-equivalent slope (= base)
        assert_eq!(lat.per_item.generate, lat.generate);
        assert_eq!(lat.per_item.encode, lat.encode);
        assert!(SimLatency::from_bench_json("/nonexistent/BENCH.json").is_err());
    }

    #[test]
    fn from_bench_json_survives_degenerate_fixture() {
        // batch=1 rows feed the base (never a zero (n-1) divisor), rows
        // with missing or non-finite median_ns are skipped, and a
        // batched-rows-only op keeps zero latency — a conservative fit,
        // never a panic or a NaN.
        let path = concat!(env!("CARGO_MANIFEST_DIR"),
                           "/tests/fixtures/BENCH_engine_degenerate.json");
        let lat = SimLatency::from_bench_json(path).unwrap();
        assert_eq!(lat.prefill, Duration::from_millis(7),
                   "batch=1 row is the base; the 1e999 row must be skipped");
        assert_eq!(lat.per_item.prefill, lat.prefill,
                   "no n>=2 rows: slope stays serial-equivalent");
        assert_eq!(lat.generate, Duration::ZERO, "median-less row is skipped");
        assert_eq!(lat.encode, Duration::ZERO,
                   "batched rows with no base row leave the op unfitted");
        assert_eq!(lat.extend, Duration::ZERO);
        assert!(lat.serial_sum() > 0.0);
    }

    #[test]
    fn host_tier_demote_promote_roundtrip_is_bit_identical() {
        let (store, sim) = sim();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        for (i, t) in toks.iter_mut().enumerate().take(30) {
            *t = 5 + i as i32;
        }
        let q = {
            let mut q = vec![c.pad_id; c.max_q];
            q[0] = 101;
            q[1] = 102;
            q
        };
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 30).unwrap();
        let (kv_ref, row_ref) = sim.extend(SIM_BACKBONE, &kv, 30, &q, 2).unwrap();
        sim.release(kv_ref);

        let host = sim.demote_kv(kv).unwrap();
        assert!(is_host_handle(host.0), "demotion mints a HOST_BIT-tagged id");
        assert!(sim.kv_current(&host), "host handles are always current");
        assert_eq!(sim.stats().unwrap().live_kv, 1, "host copy counts as live");
        // the device copy is gone: extending against the old id fails, and
        // extending against the *host* id tells the caller to promote
        let err = sim.extend(SIM_BACKBONE, &host, 30, &q, 2).unwrap_err();
        assert!(err.to_string().contains("promote"), "unhelpful error: {err}");

        let back = sim.promote_kv(&host).unwrap().0;
        assert!(!is_host_handle(back.0));
        let (kv2, row2) = sim.extend(SIM_BACKBONE, &back, 30, &q, 2).unwrap();
        assert_eq!(row2, row_ref, "roundtrip through the host tier preserves bits");
        // the host copy was consumed by the successful promotion
        sim.release_many(vec![back, kv2]);
        assert_eq!(sim.stats().unwrap().live_kv, 0);
    }

    #[test]
    fn disk_tier_archive_recall_roundtrip_is_bit_identical() {
        // archive a demoted host copy to bytes, rebuild it with recall_kv,
        // promote, and extend: results must match the never-archived run.
        let (store, sim) = sim();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        for (i, t) in toks.iter_mut().enumerate().take(24) {
            *t = 7 + i as i32;
        }
        let q = {
            let mut q = vec![c.pad_id; c.max_q];
            q[0] = 201;
            q
        };
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 24).unwrap();
        let (kv_ref, row_ref) = sim.extend(SIM_BACKBONE, &kv, 24, &q, 1).unwrap();
        sim.release(kv_ref);

        let host = sim.demote_kv(kv).unwrap();
        let bytes = sim.archive_kv(host).unwrap();
        assert!(!bytes.is_empty());
        assert_eq!(sim.stats().unwrap().live_kv, 0, "archive consumes the host copy");

        let host2 = sim.recall_kv(&bytes).unwrap();
        assert!(is_host_handle(host2.0), "recall mints a host-tier handle");
        let back = sim.promote_kv(&host2).unwrap().0;
        let (kv2, row2) = sim.extend(SIM_BACKBONE, &back, 24, &q, 1).unwrap();
        assert_eq!(row2, row_ref, "roundtrip through the archive preserves bits");
        sim.release_many(vec![back, kv2]);
        assert_eq!(sim.stats().unwrap().live_kv, 0);
    }

    #[test]
    fn archive_of_device_handle_fails_and_releases() {
        let (store, sim) = sim();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        let err = sim.archive_kv(kv).unwrap_err();
        assert!(err.to_string().contains("host-tier"), "unhelpful error: {err}");
        assert_eq!(sim.stats().unwrap().live_kv, 0,
                   "the counted fallback must release the device handle");
        // malformed payloads surface as errors, never bogus KVs.
        assert!(sim.recall_kv(&[1, 2, 3]).is_err());
    }

    #[test]
    fn host_copy_latency_scales_with_kv_bytes() {
        let store = sim_store();
        let lat = SimLatency::zero()
            .with_host_copy_per_byte(Duration::from_nanos(61));
        let sim = SimBackend::start(&store, lat).unwrap();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        // sim KV = 2 * (2 layers * 256 seq * 2 heads * 8 dhead * 4B)
        //        = 65536 bytes -> ~4 ms per copy at 61 ns/B
        let bytes = sim.kv_bytes(SIM_BACKBONE).unwrap();
        let expect = lat.host_copy(bytes);
        assert!(expect >= Duration::from_millis(3), "fixture math changed?");
        let t0 = Instant::now();
        let host = sim.demote_kv(kv).unwrap();
        assert!(t0.elapsed() >= expect, "demote must sleep the modelled copy");
        let t1 = Instant::now();
        let back = sim.promote_kv(&host).unwrap().0;
        assert!(t1.elapsed() >= expect, "promote must sleep the modelled copy");
        sim.release(back);
    }

    #[test]
    fn host_copies_survive_lane_restart() {
        let store = sim_store();
        let plan = FaultPlan { kill_llm_at_op: Some(2), ..FaultPlan::none() };
        let sim = SimBackend::start_faulty(&store, SimLatency::zero(),
                                           BatchConfig::off(), plan,
                                           SupervisorPolicy::default())
            .unwrap();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, row_ref) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        // demote is control traffic: it neither advances the fault op
        // counter nor dies with the lane
        let host = sim.demote_kv(kv).unwrap();
        // op 2 kills the worker; the supervisor restarts the lane on the
        // next submission
        assert!(sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err().is_lane_dead());
        let (kv_new, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        assert!(sim.kv_current(&host),
                "host copy is still current across the restart");
        let (back, t) = sim.promote_kv(&host).unwrap();
        assert!(t.device_secs >= 0.0);
        // the promoted KV reproduces the pre-kill sequence exactly
        let q = vec![c.pad_id; c.max_q];
        let (kv3, row3) = sim.extend(SIM_BACKBONE, &back, 1, &q, 0).unwrap();
        assert_eq!(row3, row_ref, "promoted KV must hash like the original");
        sim.release_many(vec![kv_new, back, kv3]);
        assert_eq!(sim.stats().unwrap().lane_restarts, 1);
    }

    #[test]
    fn releasing_a_host_handle_frees_the_host_copy() {
        let (store, sim) = sim();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        let host = sim.demote_kv(kv).unwrap();
        assert_eq!(sim.stats().unwrap().live_kv, 1);
        sim.release(host);
        assert_eq!(sim.stats().unwrap().live_kv, 0);
        // promoting a released host handle is a clean Fatal, not a hang
        let (kv2, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        let host2 = sim.demote_kv(kv2).unwrap();
        sim.release_many(vec![KvHandle(host2.0)]);
        assert!(!sim.promote_kv(&host2).unwrap_err().is_retryable());
    }

    #[test]
    fn from_millis_slope_is_serial_equivalent_until_overridden() {
        let lat = SimLatency::from_millis(10, 3, 5, 2);
        assert_eq!(lat.per_item.extend, lat.extend, "no free fusion win");
        let lat = lat.with_per_item_millis(2, 1, 1, 1);
        assert_eq!(lat.per_item.prefill, Duration::from_millis(2));
        assert_eq!(lat.per_item.extend, Duration::from_millis(1));
        // fused sleep follows base + per_item * (n-1)
        assert_eq!(lat.batch_sleep(lat.extend, lat.per_item.extend, 4),
                   Duration::from_millis(6));
        assert_eq!(lat.batch_sleep(lat.extend, lat.per_item.extend, 1), lat.extend);
    }

    #[test]
    fn killed_lane_fails_tickets_and_submits() {
        let store = sim_store();
        let sim = SimBackend::start(&store, SimLatency::from_millis(0, 0, 0, 40)).unwrap();
        let c = *store.constants();
        let x = vec![0f32; c.n_max * c.feat_dim];
        let adj = vec![0f32; c.n_max * c.n_max];
        let mask = vec![0f32; c.n_max];
        // first encode occupies the worker (40 ms); the second sits queued
        // behind it and must be dropped unanswered when the lane dies.
        let busy = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone()).unwrap();
        // give the worker time to pick `busy` up before the poison lands
        std::thread::sleep(std::time::Duration::from_millis(10));
        let queued = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone()).unwrap();
        sim.kill_lane_for_test(Lane::Gnn);
        assert!(busy.wait().is_ok(), "in-flight request completes");
        let err = queued.wait().unwrap_err();
        assert!(err.to_string().contains("lane"), "unhelpful error: {err}");
        // the dead lane rejects new submissions at the send
        assert!(sim.submit_encode("gat", x, adj, mask).is_err());
        // the LLM lane is unaffected
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        sim.release(kv);
    }

    #[test]
    fn faultplan_kill_restarts_lane_and_stales_old_handles() {
        let store = sim_store();
        let plan = FaultPlan { kill_llm_at_op: Some(2), ..FaultPlan::none() };
        let sim = SimBackend::start_faulty(&store, SimLatency::zero(),
                                           BatchConfig::off(), plan,
                                           SupervisorPolicy::default())
            .unwrap();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        // op 1 survives and mints a generation-0 handle
        let (kv_old, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        assert!(sim.kv_current(&kv_old));
        // op 2 triggers the kill: the worker dies mid-batch, so the ticket
        // reports LaneDead instead of hanging
        let err = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err();
        assert!(err.is_lane_dead(), "kill surfaces as LaneDead, got: {err}");
        // the next submission finds the dead channel and the supervisor
        // restarts the lane — same request succeeds on the fresh worker
        let (kv_new, row_new) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        assert!(sim.kv_current(&kv_new));
        // ...with answers bit-identical to a fault-free run
        let fresh = SimBackend::start(&store, SimLatency::zero()).unwrap();
        let (_, row_ref) = fresh.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        assert_eq!(row_new, row_ref, "restart must not change semantics");
        // the pre-restart handle is recognizably stale: kv_current says so,
        // and using it reports LaneDead (quarantine + recompute), not Fatal
        assert!(!sim.kv_current(&kv_old), "old-incarnation handle must be stale");
        let q = vec![c.pad_id; c.max_q];
        let err = sim.extend(SIM_BACKBONE, &kv_old, 1, &q, 0).unwrap_err();
        assert!(err.is_lane_dead(), "stale handle is LaneDead, got: {err}");
        assert!(err.to_string().contains("incarnation"), "unhelpful error: {err}");
        assert_eq!(sim.stats().unwrap().lane_restarts, 1);
    }

    #[test]
    fn restart_budget_exhaustion_makes_lane_death_terminal() {
        let store = sim_store();
        let plan = FaultPlan { kill_llm_at_op: Some(1), ..FaultPlan::none() };
        let policy = SupervisorPolicy { max_restarts: 0, ..Default::default() };
        let sim = SimBackend::start_faulty(&store, SimLatency::zero(),
                                           BatchConfig::off(), plan, policy)
            .unwrap();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        // op 1 kills the worker; the ticket unblocks with LaneDead
        assert!(sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err().is_lane_dead());
        // with a zero restart budget the supervisor refuses to resurrect
        let err = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err();
        assert!(err.is_lane_dead());
        assert!(err.to_string().contains("budget"), "unhelpful error: {err}");
        // the GNN lane is untouched by the LLM lane's demise
        let x = vec![0f32; c.n_max * c.feat_dim];
        assert!(sim.encode("gat", x, vec![0.0; c.n_max * c.n_max],
                           vec![0.0; c.n_max]).is_ok());
    }

    #[test]
    fn transient_injection_errs_without_side_effects() {
        let store = sim_store();
        let plan = FaultPlan { seed: 7, transient_prob: 1.0, ..FaultPlan::none() };
        let sim = SimBackend::start_faulty(&store, SimLatency::zero(),
                                           BatchConfig::off(), plan,
                                           SupervisorPolicy::default())
            .unwrap();
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let err = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err();
        assert!(err.is_retryable() && !err.is_lane_dead(),
                "transient is retryable without a lane restart: {err}");
        assert!(matches!(err, BackendError::Transient { op: "prefill", .. }));
        // the op never executed: nothing was inserted into the KV map
        assert_eq!(sim.stats().unwrap().live_kv, 0);
        assert_eq!(sim.stats().unwrap().lane_restarts, 0);
        assert!(sim.injected_faults().0 >= 1);
    }

    #[test]
    fn fault_rolls_are_deterministic_across_runs() {
        let store = sim_store();
        let c = *store.constants();
        let run = || {
            let plan = FaultPlan { seed: 42, transient_prob: 0.5, ..FaultPlan::none() };
            let sim = SimBackend::start_faulty(&store, SimLatency::zero(),
                                               BatchConfig::off(), plan,
                                               SupervisorPolicy::default())
                .unwrap();
            let mut toks = vec![c.pad_id; c.max_seq];
            toks[0] = c.bos_id;
            let outcomes: Vec<bool> = (0..16)
                .map(|_| sim.prefill(SIM_BACKBONE, &toks, 1).is_ok())
                .collect();
            outcomes
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same seed, same per-op fates");
        assert!(a.iter().any(|&ok| ok) && a.iter().any(|&ok| !ok),
                "prob 0.5 over 16 ops should mix outcomes (seed-dependent but fixed)");
    }

    /// `start_guarded` with everything defaulted except the knob under test.
    fn guarded(store: &ArtifactStore, lat: SimLatency, plan: FaultPlan,
               queue: QueueConfig, breaker: Option<BreakerConfig>) -> SimBackend {
        SimBackend::start_guarded(store, lat, BatchConfig::off(), plan,
                                  SupervisorPolicy::default(), queue, breaker)
            .unwrap()
    }

    #[test]
    fn bounded_queue_rejects_when_full_and_frees_on_pickup() {
        let store = sim_store();
        // slow encodes keep the GNN worker busy; capacity 1 means one
        // request may sit queued behind the in-flight one
        let sim = guarded(&store, SimLatency::from_millis(0, 0, 0, 60),
                          FaultPlan::none(), QueueConfig::reject(1), None);
        let c = *store.constants();
        let x = vec![0f32; c.n_max * c.feat_dim];
        let adj = vec![0f32; c.n_max * c.n_max];
        let mask = vec![0f32; c.n_max];
        let busy = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone()).unwrap();
        // let the worker pick `busy` up (its slot frees at pickup)
        std::thread::sleep(Duration::from_millis(15));
        let queued = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone())
            .unwrap();
        assert_eq!(sim.queue_depth(Lane::Gnn), 1, "one request queued");
        let err = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone())
            .unwrap_err();
        assert!(err.is_overloaded(), "full queue must refuse as Overloaded: {err}");
        assert!(err.is_retryable() && !err.is_lane_dead());
        // the LLM lane has its own gate and is unaffected
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap();
        sim.release(kv);
        // once the backlog drains, the lane admits again
        busy.wait().unwrap();
        queued.wait().unwrap();
        sim.encode("gat", x, adj, mask).expect("drained lane admits again");
    }

    #[test]
    fn bounded_queue_block_policy_never_blocks_forever() {
        let store = sim_store();
        let sim = guarded(&store, SimLatency::from_millis(0, 0, 0, 200),
                          FaultPlan::none(),
                          QueueConfig::block(1, Duration::from_millis(20)), None);
        let c = *store.constants();
        let x = vec![0f32; c.n_max * c.feat_dim];
        let adj = vec![0f32; c.n_max * c.n_max];
        let mask = vec![0f32; c.n_max];
        let busy = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(15));
        let queued = sim.submit_encode("gat", x.clone(), adj.clone(), mask.clone())
            .unwrap();
        // the worker is busy for ~200 ms, far past the 20 ms block budget:
        // the submit must give up as Overloaded, never hang
        let t0 = Instant::now();
        let err = sim.submit_encode("gat", x, adj, mask).unwrap_err();
        assert!(err.is_overloaded(), "blocked-out submit is Overloaded: {err}");
        assert!(t0.elapsed() >= Duration::from_millis(20), "Block waits its budget");
        assert!(t0.elapsed() < Duration::from_millis(150),
                "the wait is bounded by the timeout, not by the backlog");
        busy.wait().unwrap();
        queued.wait().unwrap();
    }

    #[test]
    fn control_traffic_bypasses_queue_bound() {
        let store = sim_store();
        let sim = guarded(&store, SimLatency::from_millis(60, 0, 0, 0),
                          FaultPlan::none(), QueueConfig::reject(1), None);
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        let busy = sim.submit_prefill(SIM_BACKBONE, &toks, 1).unwrap();
        std::thread::sleep(Duration::from_millis(15));
        let queued = sim.submit_prefill(SIM_BACKBONE, &toks, 1).unwrap();
        assert!(sim.submit_prefill(SIM_BACKBONE, &toks, 1).unwrap_err()
                    .is_overloaded());
        // stats and warmup are control traffic: they pass the full queue
        // (refusing a release/stats under pressure would leak KV and blind
        // the very controller that needs the numbers)
        sim.warmup(SIM_BACKBONE).expect("warmup bypasses the bound");
        let st = sim.stats().expect("stats bypasses the bound");
        assert_eq!(st.breaker_trips, 0);
        let (kv, _) = busy.wait().unwrap();
        let (kv2, _) = queued.wait().unwrap();
        sim.release_many(vec![kv, kv2]);
    }

    #[test]
    fn breaker_trips_fail_fast_without_advancing_fault_ops() {
        let store = sim_store();
        let plan = FaultPlan { seed: 7, transient_prob: 1.0, ..FaultPlan::none() };
        let breaker = BreakerConfig {
            threshold: 2,
            window: Duration::from_secs(5),
            cooldown: Duration::from_millis(30),
        };
        let sim = guarded(&store, SimLatency::zero(), plan,
                          QueueConfig::unbounded(), Some(breaker));
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        // two consecutive transients trip the breaker
        for _ in 0..2 {
            let err = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err();
            assert!(matches!(err, BackendError::Transient { .. }), "got: {err}");
        }
        assert_eq!(sim.breaker_trips(), 1, "threshold=2 trips after 2 transients");
        assert_eq!(sim.injected_faults().0, 2);
        // while open, submits fail fast as Overloaded — and never reach the
        // lane, so the fault-plan op counter must NOT advance (the property
        // that keeps seeded chaos runs reproducible under a breaker)
        let err = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err();
        assert!(err.is_overloaded(), "open breaker fails fast: {err}");
        assert_eq!(sim.injected_faults().0, 2, "fail-fast ops never roll faults");
        assert_eq!(sim.stats().unwrap().breaker_trips, 1, "trips surface in stats");
        // after the cooldown, exactly one half-open probe reaches the lane;
        // with transient_prob=1 it fails and re-trips the breaker
        std::thread::sleep(Duration::from_millis(40));
        let err = sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err();
        assert!(matches!(err, BackendError::Transient { .. }),
                "half-open probe reaches the lane: {err}");
        assert_eq!(sim.injected_faults().0, 3, "the probe rolls exactly one fault");
        assert_eq!(sim.breaker_trips(), 2, "failed probe re-opens (a new trip)");
        assert!(sim.prefill(SIM_BACKBONE, &toks, 1).unwrap_err().is_overloaded());
        // the GNN lane's breaker is independent
        let x = vec![0f32; c.n_max * c.feat_dim];
        let r = sim.encode("gat", x, vec![0.0; c.n_max * c.n_max], vec![0.0; c.n_max]);
        assert!(!matches!(r, Err(BackendError::Overloaded { .. })),
                "lanes trip independently");
    }

    #[test]
    fn breaker_closes_on_successful_probe() {
        let store = sim_store();
        // seed picked so the first LLM ops roll transient, transient,
        // then clean (prob 0.5, deterministic per seed — see
        // fault_rolls_are_deterministic_across_runs)
        let seed = first_seed_with_pattern(&[false, false, true]);
        let plan = FaultPlan { seed, transient_prob: 0.5, ..FaultPlan::none() };
        let breaker = BreakerConfig {
            threshold: 2,
            window: Duration::from_secs(5),
            cooldown: Duration::from_millis(10),
        };
        let sim = guarded(&store, SimLatency::zero(), plan,
                          QueueConfig::unbounded(), Some(breaker));
        let c = *store.constants();
        let mut toks = vec![c.pad_id; c.max_seq];
        toks[0] = c.bos_id;
        assert!(sim.prefill(SIM_BACKBONE, &toks, 1).is_err());
        assert!(sim.prefill(SIM_BACKBONE, &toks, 1).is_err());
        assert_eq!(sim.breaker_trips(), 1);
        std::thread::sleep(Duration::from_millis(15));
        // op 3 rolls clean: the half-open probe succeeds and closes the
        // breaker — subsequent submits flow normally again
        let (kv, _) = sim.prefill(SIM_BACKBONE, &toks, 1)
            .expect("successful probe closes the breaker");
        let (kv2, _) = sim.prefill(SIM_BACKBONE, &toks, 1)
            .expect("breaker closed: submits flow");
        sim.release_many(vec![kv, kv2]);
        assert_eq!(sim.breaker_trips(), 1, "no new trips after recovery");
    }

    /// Find the smallest seed whose first LLM-lane transient rolls (prob
    /// 0.5) match `pattern` (`true` = op executes, `false` = transient) —
    /// mirrors [`FaultState::on_op`]'s roll exactly.
    fn first_seed_with_pattern(pattern: &[bool]) -> u64 {
        let lane_salt = (Lane::Llm as u64 + 1) << 56;
        'seed: for seed in 0..10_000u64 {
            for (i, &ok) in pattern.iter().enumerate() {
                let idx = i as u64 + 1;
                let hit = FaultState::roll(seed ^ 0x544e_5354, lane_salt | idx) < 0.5;
                if hit == ok {
                    continue 'seed;
                }
            }
            return seed;
        }
        panic!("no seed under 10k matches {pattern:?}");
    }
}
