//! The engine's execution surface as a trait, plus the ticket types every
//! backend shares.
//!
//! # Why a trait
//!
//! The serving coordinator only ever needs six operations — prefill, extend,
//! generate, encode, release, and a handful of queries (KV byte sizing,
//! warmup, stats). [`Backend`] names exactly that surface so the scheduling
//! logic above it (lane overlap, depth-k prep queues, pin-safety under
//! eviction, hit/miss TTFT composition) is testable in plain `cargo test`
//! against the deterministic [`crate::runtime::SimBackend`], while
//! production serving runs the PJRT [`crate::runtime::Engine`] unchanged.
//!
//! # Lanes
//!
//! A backend executes requests on independent **lanes**: at minimum an
//! [`Lane::Llm`] lane (prefill / extend / generate — everything that touches
//! a KV cache) and a [`Lane::Gnn`] lane (subgraph encode). Each lane is its
//! own worker thread with its own queue, so an encode submitted while a
//! prefill is in flight genuinely overlaps instead of queueing behind it.
//! KV handles are meaningful only on the LLM lane — encode never takes or
//! returns one — which is what makes the split safe without cross-lane
//! buffer traffic.
//!
//! # Contract
//!
//! * `submit_*` enqueues without blocking and returns a ticket; `wait`
//!   blocks for the reply. A dead lane (worker thread exited) surfaces as an
//!   `Err` from `submit_*` or from `wait` — never a hang, never a panic.
//! * `prefill`/`extend` return an opaque [`KvHandle`] the caller must
//!   eventually pass to [`Backend::release`] / [`Backend::release_many`];
//!   `extend` does NOT consume its input handle (the SubGCache property).
//! * [`CallTiming`] is measured on the worker lane: `queue_secs` (submit →
//!   lane pickup, charged to the query) and `device_secs` (lane-side
//!   execution span). Timings must stay honest under pipelined submission.
//! * Requests on one lane execute in FIFO submission order; requests on
//!   different lanes are unordered with respect to each other.

use std::sync::mpsc::Receiver;

use super::batch::BatchInfo;

/// A backend execution lane (one worker thread + queue each).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// KV-touching LLM calls: prefill, extend, generate.
    Llm,
    /// GNN subgraph encodes (never touches KV state).
    Gnn,
}

impl Lane {
    pub const ALL: [Lane; 2] = [Lane::Llm, Lane::Gnn];

    /// Stable lowercase name (used in stats keys and thread names).
    pub fn name(self) -> &'static str {
        match self {
            Lane::Llm => "llm",
            Lane::Gnn => "gnn",
        }
    }
}

/// Opaque reference to a backend-resident KV cache (k & v buffers).
/// Deliberately not `Clone`: exactly one owner, released explicitly.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct KvHandle(pub(crate) u64);

/// Per-entry execution counters (returned by [`Backend::stats`]).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// (module.entry, calls, total seconds inside execute), merged across
    /// lanes and sorted by key.
    pub calls: Vec<(String, u64, f64)>,
    pub live_kv: usize,
    pub compile_secs: f64,
    /// KV bytes that moved through the host while storing prefill/extend
    /// outputs. 0 on the zero-copy path; non-zero means the tuple-literal
    /// fallback (or forced `SUBGCACHE_KV_HOST_BOUNCE`) is in effect.
    /// Always 0 for the sim backend.
    pub host_kv_bytes: u64,
    /// Multi-member batches the backend could not execute as one fused
    /// device call (no batched HLO entry for the op) and ran as a counted
    /// per-member loop instead. Always 0 for the sim backend, which fuses
    /// everything.
    pub unbatched_fallbacks: u64,
}

/// Lane-side timing of one executed call, measured on the worker thread so
/// it stays honest under pipelined submission: `queue_secs` is how long the
/// request sat in the lane's channel before pickup (charged to the query),
/// `window_secs` how long it then sat inside an open batch window waiting
/// for the fused launch (zero when batching is off), and `device_secs` the
/// lane-thread span of the call itself (execute + result materialization;
/// for a fused batch, the whole batch's span — every member really waited
/// that long). `batch` records how the request rode the lane; aggregates
/// use its `leader` flag to count the shared device span exactly once per
/// launch (see [`crate::runtime::batch`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct CallTiming {
    pub queue_secs: f64,
    pub window_secs: f64,
    pub device_secs: f64,
    pub batch: BatchInfo,
}

impl CallTiming {
    /// Total submit→reply lane time (queue + window + execution).
    pub fn secs(&self) -> f64 {
        self.queue_secs + self.window_secs + self.device_secs
    }
}

/// One in-flight reply slot. `wait` blocks until the lane answers; a
/// dropped reply sender (lane worker died, or the request was never
/// processed before shutdown) surfaces as an error instead of hanging
/// forever.
pub(crate) struct Ticket<T> {
    pub(crate) rx: Receiver<anyhow::Result<T>>,
}

impl<T> Ticket<T> {
    pub(crate) fn wait(self) -> anyhow::Result<T> {
        self.rx.recv().map_err(|_| {
            anyhow::anyhow!(
                "backend lane dropped the reply channel before answering \
                 (lane worker died or the ticket's request was never run)"
            )
        })?
    }
}

/// Ticket for an in-flight KV-producing call — `prefill`
/// ([`Backend::submit_prefill`]) or `extend` ([`Backend::submit_extend`]);
/// yields the new KV handle and the next-token logits row.
pub struct PendingKv(pub(crate) Ticket<(u64, Vec<f32>, CallTiming)>);

/// Ticket for an in-flight `prefill` (see [`Backend::submit_prefill`]).
pub type PendingPrefill = PendingKv;
/// Ticket for an in-flight `extend` (see [`Backend::submit_extend`]).
pub type PendingExtend = PendingKv;

impl PendingKv {
    /// Block for the new KV handle and the next-token logits row.
    pub fn wait(self) -> anyhow::Result<(KvHandle, Vec<f32>)> {
        let (kv, logits, _) = self.wait_timed()?;
        Ok((kv, logits))
    }

    /// Like [`wait`](Self::wait), plus the lane-side [`CallTiming`].
    pub fn wait_timed(self) -> anyhow::Result<(KvHandle, Vec<f32>, CallTiming)> {
        let (id, logits, t) = self.0.wait()?;
        Ok((KvHandle(id), logits, t))
    }
}

/// Ticket for an in-flight `generate` (see [`Backend::submit_generate`]).
pub struct PendingGenerate(pub(crate) Ticket<(Vec<i32>, CallTiming)>);

impl PendingGenerate {
    pub fn wait(self) -> anyhow::Result<Vec<i32>> {
        Ok(self.wait_timed()?.0)
    }

    pub fn wait_timed(self) -> anyhow::Result<(Vec<i32>, CallTiming)> {
        self.0.wait()
    }
}

/// Ticket for an in-flight GNN `encode` (see [`Backend::submit_encode`]).
pub struct PendingEncode(pub(crate) Ticket<(Vec<f32>, CallTiming)>);

impl PendingEncode {
    pub fn wait(self) -> anyhow::Result<Vec<f32>> {
        Ok(self.wait_timed()?.0)
    }

    pub fn wait_timed(self) -> anyhow::Result<(Vec<f32>, CallTiming)> {
        self.0.wait()
    }
}

/// The execution surface the serving coordinator is written against. See
/// the module docs for the contract; [`crate::runtime::Engine`] is the PJRT
/// implementation, [`crate::runtime::SimBackend`] the deterministic
/// simulator for scheduling tests.
///
/// `Sync` is part of the contract: the multi-stream serving path
/// (`serve_online_multi`) shares one backend across N worker threads, each
/// submitting to the lanes and waiting its own tickets concurrently. Both
/// implementations are lock-free on submission (mpsc senders are `Sync`
/// over `Send` payloads) and every ticket owns its private reply receiver,
/// so cross-thread submits interleave at the lane queue — FIFO per lane
/// across ALL threads — and concurrent `wait`s never share state. The
/// `queue_secs` a request reports may therefore include time spent behind
/// *other streams'* lane work; that is the honest number.
pub trait Backend: Sync {
    /// Submit a prefill of `tokens` (padded to S, real length `plen`) on the
    /// LLM lane without blocking; the ticket yields the new KV handle and
    /// the next-token logits row after position `plen - 1`.
    fn submit_prefill(&self, module: &str, tokens: &[i32], plen: i32)
                      -> anyhow::Result<PendingPrefill>;

    /// Submit an extend of `q_tokens` (padded to Q, real length `qlen`) at
    /// position `plen` on top of `kv` (NOT consumed — it stays reusable, the
    /// SubGCache property) on the LLM lane without blocking. The ticket
    /// yields a new handle and the `[V]` logits row after the last real
    /// question token (row `qlen - 1`, clamped).
    fn submit_extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32],
                     qlen: i32) -> anyhow::Result<PendingExtend>;

    /// Submit a greedy decode of up to G tokens starting from `first_tok`
    /// at `cur_len` on the LLM lane. `kv` is not consumed.
    fn submit_generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                       -> anyhow::Result<PendingGenerate>;

    /// Submit a GNN subgraph embedding — x [N,F], adj [N,N], mask [N]
    /// (row-major flat) — on the GNN lane without blocking.
    fn submit_encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
                     -> anyhow::Result<PendingEncode>;

    /// Return a KV cache to the backend. Best-effort: a dead lane has
    /// already dropped its buffers, so failure to enqueue is ignored.
    fn release(&self, kv: KvHandle);

    /// Return a batch of KV caches in one lane message (the cache layer's
    /// eviction/drain path). Best-effort, like [`Backend::release`].
    fn release_many(&self, kvs: Vec<KvHandle>);

    /// Resident bytes of one KV cache of `module` (k + v buffers), sized
    /// from the manifest. Errors for non-LLM modules.
    fn kv_bytes(&self, module: &str) -> anyhow::Result<usize>;

    /// Load weights + compile all entries of `module` ahead of timing runs
    /// (routed to the module's lane; a no-op for backends without compile).
    fn warmup(&self, module: &str) -> anyhow::Result<()>;

    /// Merged execution counters across all lanes.
    fn stats(&self) -> anyhow::Result<EngineStats>;

    // -- blocking conveniences (submit + wait) -------------------------------

    /// Blocking prefill: [`Backend::submit_prefill`] + wait.
    fn prefill(&self, module: &str, tokens: &[i32], plen: i32)
               -> anyhow::Result<(KvHandle, Vec<f32>)> {
        self.submit_prefill(module, tokens, plen)?.wait()
    }

    /// Blocking extend: [`Backend::submit_extend`] + wait.
    fn extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32], qlen: i32)
              -> anyhow::Result<(KvHandle, Vec<f32>)> {
        self.submit_extend(module, kv, plen, q_tokens, qlen)?.wait()
    }

    /// Blocking generate: [`Backend::submit_generate`] + wait.
    fn generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                -> anyhow::Result<Vec<i32>> {
        self.submit_generate(module, kv, cur_len, first_tok)?.wait()
    }

    /// Blocking encode: [`Backend::submit_encode`] + wait.
    fn encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
              -> anyhow::Result<Vec<f32>> {
        self.submit_encode(module, x, adj, mask)?.wait()
    }
}

/// Merge per-lane stats snapshots into one [`EngineStats`] (calls
/// concatenated and re-sorted, counters summed).
pub(crate) fn merge_stats(parts: Vec<EngineStats>) -> EngineStats {
    let mut out = EngineStats::default();
    for p in parts {
        out.calls.extend(p.calls);
        out.live_kv += p.live_kv;
        out.compile_secs += p.compile_secs;
        out.host_kv_bytes += p.host_kv_bytes;
        out.unbatched_fallbacks += p.unbatched_fallbacks;
    }
    out.calls.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn wait_on_dropped_ticket_errors_instead_of_hanging() {
        let (tx, rx) = channel::<anyhow::Result<(u64, Vec<f32>, CallTiming)>>();
        drop(tx);
        let err = PendingKv(Ticket { rx }).wait().unwrap_err();
        assert!(err.to_string().contains("lane"), "unhelpful error: {err}");

        let (tx, rx) = channel::<anyhow::Result<(u64, Vec<f32>, CallTiming)>>();
        drop(tx);
        assert!(PendingKv(Ticket { rx }).wait_timed().is_err());

        let (tx, rx) = channel::<anyhow::Result<(Vec<i32>, CallTiming)>>();
        drop(tx);
        assert!(PendingGenerate(Ticket { rx }).wait().is_err());

        let (tx, rx) = channel::<anyhow::Result<(Vec<f32>, CallTiming)>>();
        drop(tx);
        assert!(PendingEncode(Ticket { rx }).wait().is_err());
    }

    #[test]
    fn ticket_delivers_value_sent_before_drop() {
        // a reply that was already sent must still arrive after the lane
        // side dropped its sender — wait is recv, not a liveness check.
        let (tx, rx) = channel::<anyhow::Result<(u64, Vec<f32>, CallTiming)>>();
        tx.send(Ok((7, vec![1.0], CallTiming::default()))).unwrap();
        drop(tx);
        let (kv, logits, t) = PendingKv(Ticket { rx }).wait_timed().unwrap();
        assert_eq!(kv, KvHandle(7));
        assert_eq!(logits, vec![1.0]);
        assert_eq!(t.secs(), 0.0);
    }

    #[test]
    fn call_timing_sums_components() {
        let t = CallTiming { queue_secs: 0.25, device_secs: 0.5, ..Default::default() };
        assert!((t.secs() - 0.75).abs() < 1e-12);
        let w = CallTiming { queue_secs: 0.25, window_secs: 0.125, device_secs: 0.5,
                             ..Default::default() };
        assert!((w.secs() - 0.875).abs() < 1e-12, "window time counts toward secs()");
    }

    #[test]
    fn lane_names_are_stable() {
        assert_eq!(Lane::Llm.name(), "llm");
        assert_eq!(Lane::Gnn.name(), "gnn");
        assert_eq!(Lane::ALL.len(), 2);
    }

    #[test]
    fn merge_stats_sums_and_sorts() {
        let a = EngineStats {
            calls: vec![("m.prefill".into(), 2, 0.5)],
            live_kv: 3,
            compile_secs: 1.0,
            host_kv_bytes: 0,
            unbatched_fallbacks: 1,
        };
        let b = EngineStats {
            calls: vec![("gat.encode".into(), 4, 0.25)],
            live_kv: 0,
            compile_secs: 0.5,
            host_kv_bytes: 8,
            unbatched_fallbacks: 2,
        };
        let m = merge_stats(vec![a, b]);
        assert_eq!(m.live_kv, 3);
        assert!((m.compile_secs - 1.5).abs() < 1e-12);
        assert_eq!(m.host_kv_bytes, 8);
        assert_eq!(m.unbatched_fallbacks, 3);
        assert_eq!(m.calls[0].0, "gat.encode", "calls must be re-sorted");
        assert_eq!(m.calls[1].0, "m.prefill");
    }
}
