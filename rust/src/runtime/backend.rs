//! The engine's execution surface as a trait, plus the ticket types every
//! backend shares.
//!
//! # Why a trait
//!
//! The serving coordinator only ever needs six operations — prefill, extend,
//! generate, encode, release, and a handful of queries (KV byte sizing,
//! warmup, stats). [`Backend`] names exactly that surface so the scheduling
//! logic above it (lane overlap, depth-k prep queues, pin-safety under
//! eviction, hit/miss TTFT composition) is testable in plain `cargo test`
//! against the deterministic [`crate::runtime::SimBackend`], while
//! production serving runs the PJRT [`crate::runtime::Engine`] unchanged.
//!
//! # Lanes
//!
//! A backend executes requests on independent **lanes**: at minimum an
//! [`Lane::Llm`] lane (prefill / extend / generate — everything that touches
//! a KV cache) and a [`Lane::Gnn`] lane (subgraph encode). Each lane is its
//! own worker thread with its own queue, so an encode submitted while a
//! prefill is in flight genuinely overlaps instead of queueing behind it.
//! KV handles are meaningful only on the LLM lane — encode never takes or
//! returns one — which is what makes the split safe without cross-lane
//! buffer traffic.
//!
//! # Contract
//!
//! * `submit_*` enqueues without blocking and returns a ticket; `wait`
//!   blocks for the reply. A dead lane (worker thread exited) surfaces as a
//!   [`BackendError::LaneDead`] from `submit_*` or from `wait` — never a
//!   hang, never a panic. Every backend failure is a typed [`BackendError`]
//!   so callers can tell retryable (`Transient`, `LaneDead`) from terminal
//!   (`Fatal`) without string matching.
//! * `prefill`/`extend` return an opaque [`KvHandle`] the caller must
//!   eventually pass to [`Backend::release`] / [`Backend::release_many`];
//!   `extend` does NOT consume its input handle (the SubGCache property).
//! * [`CallTiming`] is measured on the worker lane: `queue_secs` (submit →
//!   lane pickup, charged to the query) and `device_secs` (lane-side
//!   execution span). Timings must stay honest under pipelined submission.
//! * Requests on one lane execute in FIFO submission order; requests on
//!   different lanes are unordered with respect to each other.

use std::sync::mpsc::Receiver;

use super::batch::BatchInfo;

/// Typed failure taxonomy at the [`Backend`] boundary, so callers can
/// distinguish retryable failures from terminal ones instead of matching
/// error strings.
///
/// * [`Transient`](BackendError::Transient) — the op failed but the lane is
///   healthy (an injected fault, a spurious device error). Resubmitting the
///   same request may succeed; no backend state was lost.
/// * [`LaneDead`](BackendError::LaneDead) — the lane worker died (or was
///   restarted by the supervisor) while the request was queued or in
///   flight. Every KV handle minted by the dead incarnation is gone; the
///   caller must treat cached handles from it as invalid (see
///   [`Backend::kv_current`]) and recompute.
/// * [`Overloaded`](BackendError::Overloaded) — the lane refused the
///   submission because its bounded queue is full (or its circuit breaker
///   is open). Nothing was enqueued and no backend state was touched.
///   Retryable **only with backoff**: an immediate resubmit lands on the
///   same full queue, so schedulers must wait (or shed the query) first —
///   unlike [`Transient`](BackendError::Transient), where an immediate
///   retry is fine.
/// * [`Fatal`](BackendError::Fatal) — not retryable: bad arguments, unknown
///   module, malformed backend output. Retrying the same request fails the
///   same way.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendError {
    /// Retryable one-off failure; the lane (and all KV state) is intact.
    Transient { op: &'static str, reason: String },
    /// The lane worker died; its KV incarnation is lost.
    LaneDead { lane: Lane, reason: String },
    /// The lane refused the submission (bounded queue full, or circuit
    /// breaker open). Nothing was enqueued; retry only after backing off.
    Overloaded { lane: Lane, reason: String },
    /// Terminal: retrying cannot succeed.
    Fatal { reason: String },
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Transient { op, reason } => {
                write!(f, "transient backend error in {op}: {reason}")
            }
            BackendError::LaneDead { lane, reason } => {
                write!(f, "{} lane dead: {reason}", lane.name())
            }
            BackendError::Overloaded { lane, reason } => {
                write!(f, "{} lane overloaded: {reason}", lane.name())
            }
            BackendError::Fatal { reason } => write!(f, "backend error: {reason}"),
        }
    }
}

impl std::error::Error for BackendError {}

impl BackendError {
    pub fn transient(op: &'static str, reason: impl Into<String>) -> BackendError {
        BackendError::Transient { op, reason: reason.into() }
    }

    pub fn lane_dead(lane: Lane, reason: impl Into<String>) -> BackendError {
        BackendError::LaneDead { lane, reason: reason.into() }
    }

    pub fn overloaded(lane: Lane, reason: impl Into<String>) -> BackendError {
        BackendError::Overloaded { lane, reason: reason.into() }
    }

    pub fn fatal(reason: impl std::fmt::Display) -> BackendError {
        BackendError::Fatal { reason: reason.to_string() }
    }

    /// Terminal wrapper for an `anyhow` chain (full context preserved).
    pub fn from_anyhow(e: anyhow::Error) -> BackendError {
        BackendError::Fatal { reason: format!("{e:#}") }
    }

    /// Whether resubmitting (possibly after recomputing lost KV state)
    /// may succeed: true for `Transient`, `LaneDead` and `Overloaded`,
    /// false for `Fatal`. `Overloaded` is retryable **only with backoff**
    /// (check [`is_overloaded`](Self::is_overloaded) before an immediate
    /// retry) — resubmitting instantly just hammers the same full queue.
    pub fn is_retryable(&self) -> bool {
        !matches!(self, BackendError::Fatal { .. })
    }

    /// Whether this failure invalidated the lane's KV incarnation.
    pub fn is_lane_dead(&self) -> bool {
        matches!(self, BackendError::LaneDead { .. })
    }

    /// Whether the lane refused the submission for lack of capacity
    /// (bounded queue full or circuit breaker open). Retry implies backoff.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, BackendError::Overloaded { .. })
    }

    /// Pull the typed taxonomy back out of an `anyhow` chain (the
    /// coordinator wraps backend errors with query context; `downcast_ref`
    /// searches the whole chain).
    pub fn classify(err: &anyhow::Error) -> Option<&BackendError> {
        err.downcast_ref::<BackendError>()
    }
}

/// A backend execution lane (one worker thread + queue each).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// KV-touching LLM calls: prefill, extend, generate.
    Llm,
    /// GNN subgraph encodes (never touches KV state).
    Gnn,
}

impl Lane {
    pub const ALL: [Lane; 2] = [Lane::Llm, Lane::Gnn];

    /// Stable lowercase name (used in stats keys and thread names).
    pub fn name(self) -> &'static str {
        match self {
            Lane::Llm => "llm",
            Lane::Gnn => "gnn",
        }
    }
}

/// What a lane does when a work submission finds its bounded queue full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FullPolicy {
    /// Wait up to `timeout` for a slot, then fail
    /// [`BackendError::Overloaded`]. A submit therefore never blocks
    /// longer than the timeout — bounded queues mean bounded waits.
    Block { timeout: std::time::Duration },
    /// Fail [`BackendError::Overloaded`] immediately.
    Reject,
}

/// Bounded-queue policy for a lane's submit path. `capacity == 0` means
/// unbounded (the pre-overload-plane behaviour, and the default): work
/// submissions are never refused. With a nonzero capacity, at most
/// `capacity` *work* requests (prefill/extend/generate/encode — anything
/// that occupies device time) may be queued or in flight on the lane at
/// once; control traffic (release/warmup/stats/tier moves) always passes,
/// since refusing a release would leak KV under the very pressure the
/// bound exists to relieve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueConfig {
    /// Maximum queued-or-executing work requests per lane; 0 = unbounded.
    pub capacity: usize,
    /// What to do when the queue is full.
    pub full_policy: FullPolicy,
}

impl Default for QueueConfig {
    fn default() -> QueueConfig {
        QueueConfig::unbounded()
    }
}

impl QueueConfig {
    /// No bound (the default): submissions always enqueue.
    pub fn unbounded() -> QueueConfig {
        QueueConfig { capacity: 0, full_policy: FullPolicy::Reject }
    }

    /// Bounded queue that fails fast when full.
    pub fn reject(capacity: usize) -> QueueConfig {
        QueueConfig { capacity, full_policy: FullPolicy::Reject }
    }

    /// Bounded queue that waits up to `timeout` for a slot before failing.
    pub fn block(capacity: usize, timeout: std::time::Duration) -> QueueConfig {
        QueueConfig { capacity, full_policy: FullPolicy::Block { timeout } }
    }

    /// Whether this config actually bounds the queue.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }
}

/// Admission gate a lane's submit path consults before enqueueing work:
/// a counted semaphore over the lane's `mpsc` channel, enforcing
/// [`QueueConfig`]. Shared by the sim backend and the PJRT engine so the
/// `Overloaded` contract doesn't fork between backends.
///
/// `admit` is called on the submitting thread (charged to the caller, like
/// the enqueue itself); `release` is called by the lane worker when it
/// *picks up* the request, so "depth" counts queued work, which is exactly
/// the backlog an admission controller wants to see.
pub(crate) struct QueueGate {
    cfg: QueueConfig,
    depth: std::sync::Mutex<usize>,
    freed: std::sync::Condvar,
}

impl QueueGate {
    pub(crate) fn new(cfg: QueueConfig) -> QueueGate {
        QueueGate { cfg, depth: std::sync::Mutex::new(0), freed: std::sync::Condvar::new() }
    }

    /// Take a queue slot for one work request, or fail `Overloaded` per
    /// the configured full policy. Unbounded configs always admit.
    pub(crate) fn admit(&self, lane: Lane) -> Result<(), BackendError> {
        let cap = self.cfg.capacity;
        let mut depth = self.depth.lock().unwrap();
        if cap == 0 {
            *depth += 1;
            return Ok(());
        }
        if *depth < cap {
            *depth += 1;
            return Ok(());
        }
        match self.cfg.full_policy {
            FullPolicy::Reject => Err(BackendError::overloaded(
                lane,
                format!("queue full ({cap} requests queued, policy: reject)"),
            )),
            FullPolicy::Block { timeout } => {
                let deadline = std::time::Instant::now() + timeout;
                loop {
                    let now = std::time::Instant::now();
                    if *depth < cap {
                        *depth += 1;
                        return Ok(());
                    }
                    if now >= deadline {
                        return Err(BackendError::overloaded(
                            lane,
                            format!("queue full ({cap} requests queued, blocked \
                                     {timeout:?} without a slot freeing)"),
                        ));
                    }
                    let (d, _) = self.freed.wait_timeout(depth, deadline - now).unwrap();
                    depth = d;
                }
            }
        }
    }

    /// Free `n` queue slots (the lane worker picked up `n` work requests).
    pub(crate) fn release(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut depth = self.depth.lock().unwrap();
        *depth = depth.saturating_sub(n);
        drop(depth);
        self.freed.notify_all();
    }

    /// Current queued-work depth (the gauge sampled into `LaneTimes`).
    pub(crate) fn depth(&self) -> usize {
        *self.depth.lock().unwrap()
    }

    /// Zero the depth and wake all blocked submitters: a lane restart drops
    /// the old channel (and every request queued in it), so the slots those
    /// requests held no longer correspond to anything.
    pub(crate) fn reset(&self) {
        *self.depth.lock().unwrap() = 0;
        self.freed.notify_all();
    }
}

/// Opaque reference to a backend-resident KV cache (k & v buffers).
/// Deliberately not `Clone`: exactly one owner, released explicitly.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct KvHandle(pub(crate) u64);

/// Per-entry execution counters (returned by [`Backend::stats`]).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// (module.entry, calls, total seconds inside execute), merged across
    /// lanes and sorted by key.
    pub calls: Vec<(String, u64, f64)>,
    pub live_kv: usize,
    pub compile_secs: f64,
    /// KV bytes that moved through the host while storing prefill/extend
    /// outputs. 0 on the zero-copy path; non-zero means the tuple-literal
    /// fallback (or forced `SUBGCACHE_KV_HOST_BOUNCE`) is in effect.
    /// Always 0 for the sim backend.
    pub host_kv_bytes: u64,
    /// Multi-member batches the backend could not execute as one fused
    /// device call (no batched HLO entry for the op) and ran as a counted
    /// per-member loop instead. Always 0 for the sim backend, which fuses
    /// everything.
    pub unbatched_fallbacks: u64,
    /// Lane worker restarts performed by the backend's supervisor (summed
    /// across lanes). 0 on a fault-free run; the PJRT engine treats lane
    /// death as terminal today and always reports 0.
    pub lane_restarts: u64,
    /// Times a lane circuit breaker tripped open (K consecutive transients
    /// within its window; submissions then fail fast as `Overloaded` until
    /// a half-open probe succeeds). Summed across lanes; always 0 for
    /// backends without a breaker (the PJRT engine today).
    pub breaker_trips: u64,
}

/// Lane-side timing of one executed call, measured on the worker thread so
/// it stays honest under pipelined submission: `queue_secs` is how long the
/// request sat in the lane's channel before pickup (charged to the query),
/// `window_secs` how long it then sat inside an open batch window waiting
/// for the fused launch (zero when batching is off), and `device_secs` the
/// lane-thread span of the call itself (execute + result materialization;
/// for a fused batch, the whole batch's span — every member really waited
/// that long). `batch` records how the request rode the lane; aggregates
/// use its `leader` flag to count the shared device span exactly once per
/// launch (see [`crate::runtime::batch`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct CallTiming {
    pub queue_secs: f64,
    pub window_secs: f64,
    pub device_secs: f64,
    pub batch: BatchInfo,
}

impl CallTiming {
    /// Total submit→reply lane time (queue + window + execution).
    pub fn secs(&self) -> f64 {
        self.queue_secs + self.window_secs + self.device_secs
    }
}

/// One in-flight reply slot. `wait` blocks until the lane answers; a
/// dropped reply sender (lane worker died, or the request was never
/// processed before shutdown) surfaces as [`BackendError::LaneDead`]
/// instead of hanging forever.
pub(crate) struct Ticket<T> {
    pub(crate) rx: Receiver<Result<T, BackendError>>,
    pub(crate) lane: Lane,
}

impl<T> Ticket<T> {
    pub(crate) fn wait(self) -> Result<T, BackendError> {
        self.rx.recv().map_err(|_| {
            BackendError::lane_dead(
                self.lane,
                "lane dropped the reply channel before answering (worker died \
                 or was restarted before the ticket's request ran)",
            )
        })?
    }
}

/// Ticket for an in-flight KV-producing call — `prefill`
/// ([`Backend::submit_prefill`]) or `extend` ([`Backend::submit_extend`]);
/// yields the new KV handle and the next-token logits row.
pub struct PendingKv(pub(crate) Ticket<(u64, Vec<f32>, CallTiming)>);

/// Ticket for an in-flight `prefill` (see [`Backend::submit_prefill`]).
pub type PendingPrefill = PendingKv;
/// Ticket for an in-flight `extend` (see [`Backend::submit_extend`]).
pub type PendingExtend = PendingKv;

impl PendingKv {
    /// Block for the new KV handle and the next-token logits row.
    pub fn wait(self) -> Result<(KvHandle, Vec<f32>), BackendError> {
        let (kv, logits, _) = self.wait_timed()?;
        Ok((kv, logits))
    }

    /// Like [`wait`](Self::wait), plus the lane-side [`CallTiming`].
    pub fn wait_timed(self) -> Result<(KvHandle, Vec<f32>, CallTiming), BackendError> {
        let (id, logits, t) = self.0.wait()?;
        Ok((KvHandle(id), logits, t))
    }
}

/// Ticket for an in-flight `generate` (see [`Backend::submit_generate`]).
pub struct PendingGenerate(pub(crate) Ticket<(Vec<i32>, CallTiming)>);

impl PendingGenerate {
    pub fn wait(self) -> Result<Vec<i32>, BackendError> {
        Ok(self.wait_timed()?.0)
    }

    pub fn wait_timed(self) -> Result<(Vec<i32>, CallTiming), BackendError> {
        self.0.wait()
    }
}

/// Ticket for an in-flight host→device KV promotion (see
/// [`Backend::submit_promote`]); yields the re-minted device handle.
///
/// Promotion is a pure copy — no logits, no token output — so the ticket
/// carries only the new handle id and the lane-side [`CallTiming`]. The
/// serving coordinator submits a promotion and then does its queue top-up
/// work in the same shadow it uses for prefill tickets, which is what makes
/// a host-tier hit cheaper than a repaid prefill: only the copy is on the
/// critical path, and the copy is far cheaper than recomputing the KV.
pub struct PendingPromote(pub(crate) Ticket<(u64, CallTiming)>);

impl PendingPromote {
    /// Block for the promoted (device-resident) KV handle.
    pub fn wait(self) -> Result<KvHandle, BackendError> {
        Ok(self.wait_timed()?.0)
    }

    /// Like [`wait`](Self::wait), plus the lane-side [`CallTiming`].
    pub fn wait_timed(self) -> Result<(KvHandle, CallTiming), BackendError> {
        let (id, t) = self.0.wait()?;
        Ok((KvHandle(id), t))
    }
}

/// Ticket for an in-flight GNN `encode` (see [`Backend::submit_encode`]).
pub struct PendingEncode(pub(crate) Ticket<(Vec<f32>, CallTiming)>);

impl PendingEncode {
    pub fn wait(self) -> Result<Vec<f32>, BackendError> {
        Ok(self.wait_timed()?.0)
    }

    pub fn wait_timed(self) -> Result<(Vec<f32>, CallTiming), BackendError> {
        self.0.wait()
    }
}

/// The execution surface the serving coordinator is written against. See
/// the module docs for the contract; [`crate::runtime::Engine`] is the PJRT
/// implementation, [`crate::runtime::SimBackend`] the deterministic
/// simulator for scheduling tests.
///
/// `Sync` is part of the contract: the multi-stream serving path
/// (`serve_online_multi`) shares one backend across N worker threads, each
/// submitting to the lanes and waiting its own tickets concurrently. Both
/// implementations are lock-free on submission (mpsc senders are `Sync`
/// over `Send` payloads) and every ticket owns its private reply receiver,
/// so cross-thread submits interleave at the lane queue — FIFO per lane
/// across ALL threads — and concurrent `wait`s never share state. The
/// `queue_secs` a request reports may therefore include time spent behind
/// *other streams'* lane work; that is the honest number.
pub trait Backend: Sync {
    /// Submit a prefill of `tokens` (padded to S, real length `plen`) on the
    /// LLM lane without blocking; the ticket yields the new KV handle and
    /// the next-token logits row after position `plen - 1`.
    fn submit_prefill(&self, module: &str, tokens: &[i32], plen: i32)
                      -> Result<PendingPrefill, BackendError>;

    /// Submit an extend of `q_tokens` (padded to Q, real length `qlen`) at
    /// position `plen` on top of `kv` (NOT consumed — it stays reusable, the
    /// SubGCache property) on the LLM lane without blocking. The ticket
    /// yields a new handle and the `[V]` logits row after the last real
    /// question token (row `qlen - 1`, clamped).
    fn submit_extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32],
                     qlen: i32) -> Result<PendingExtend, BackendError>;

    /// Submit a greedy decode of up to G tokens starting from `first_tok`
    /// at `cur_len` on the LLM lane. `kv` is not consumed.
    fn submit_generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                       -> Result<PendingGenerate, BackendError>;

    /// Submit a GNN subgraph embedding — x [N,F], adj [N,N], mask [N]
    /// (row-major flat) — on the GNN lane without blocking.
    fn submit_encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
                     -> Result<PendingEncode, BackendError>;

    /// Return a KV cache to the backend. Best-effort: a dead lane has
    /// already dropped its buffers, so failure to enqueue is ignored.
    fn release(&self, kv: KvHandle);

    /// Return a batch of KV caches in one lane message (the cache layer's
    /// eviction/drain path). Best-effort, like [`Backend::release`].
    fn release_many(&self, kvs: Vec<KvHandle>);

    /// Resident bytes of one KV cache of `module` (k + v buffers), sized
    /// from the manifest. Errors for non-LLM modules.
    fn kv_bytes(&self, module: &str) -> Result<usize, BackendError>;

    /// Load weights + compile all entries of `module` ahead of timing runs
    /// (routed to the module's lane; a no-op for backends without compile).
    fn warmup(&self, module: &str) -> Result<(), BackendError>;

    /// Merged execution counters across all lanes.
    fn stats(&self) -> Result<EngineStats, BackendError>;

    /// Work requests currently queued (or executing) on `lane` — the
    /// queue-depth gauge overload control samples into `LaneTimes`.
    /// Backends without bounded-queue accounting keep the default 0.
    fn queue_depth(&self, _lane: Lane) -> usize {
        0
    }

    /// Whether `kv` was minted by the *current* incarnation of its lane.
    /// A backend whose supervisor restarted a lane loses every KV handle
    /// that incarnation held; callers holding cached handles use this to
    /// quarantine them after a [`BackendError::LaneDead`] instead of
    /// retrying against dead device state. Backends without lane restarts
    /// (the PJRT engine today) keep the default: every handle is current.
    fn kv_current(&self, _kv: &KvHandle) -> bool {
        true
    }

    // -- host KV tier (optional) ---------------------------------------------

    /// Demote a device-resident KV cache to the backend's host tier: copy
    /// the k/v buffers to host memory, free the device copy, and return a
    /// **host-tier handle** that [`Backend::submit_promote`] (and
    /// [`Backend::release`]) accept. Consumes `kv` either way — on error the
    /// device copy must already have been released, so the caller never
    /// leaks a handle.
    ///
    /// Backends without a host tier keep this default: the handle is
    /// released and the call fails `Fatal`, which the cache layer treats as
    /// "demotion unavailable — entry dies instead of moving tiers".
    fn demote_kv(&self, kv: KvHandle) -> Result<KvHandle, BackendError> {
        self.release(kv);
        Err(BackendError::fatal("backend has no host KV tier (demote_kv unsupported)"))
    }

    /// Submit a host→device promotion of a host-tier handle (minted by
    /// [`Backend::demote_kv`]) on the LLM lane without blocking. Borrows
    /// `kv`: the host copy is consumed only when the promotion succeeds, so
    /// after a [`BackendError::LaneDead`] the caller still holds a valid
    /// host handle and can retry (or fall back to a prefill and release it).
    ///
    /// Backends without a host tier keep the default `Fatal`.
    fn submit_promote(&self, _kv: &KvHandle) -> Result<PendingPromote, BackendError> {
        Err(BackendError::fatal("backend has no host KV tier (promote unsupported)"))
    }

    // -- disk KV tier (optional) ---------------------------------------------

    /// Serialize a **host-tier** KV cache (minted by [`Backend::demote_kv`])
    /// to plain bytes for the cache layer's disk archive, freeing the host
    /// copy. Consumes `kv` either way — on error the host copy must already
    /// have been released, so the caller never leaks a handle. The bytes
    /// round-trip through [`Backend::recall_kv`] bit-identically.
    ///
    /// Backends without a disk tier keep this default: the handle is
    /// released and the call fails `Fatal`, which the cache layer treats as
    /// "archival unavailable — the spill is dropped instead of archived".
    fn archive_kv(&self, kv: KvHandle) -> Result<Vec<u8>, BackendError> {
        self.release(kv);
        Err(BackendError::fatal("backend has no disk KV tier (archive_kv unsupported)"))
    }

    /// Rebuild a host-tier KV handle from bytes produced by
    /// [`Backend::archive_kv`]. The returned handle feeds the normal
    /// promote path ([`Backend::submit_promote`] / [`Backend::promote_kv`])
    /// — the disk → host → device recall walk. Fails `Fatal` on malformed
    /// bytes (a torn archive degraded to garbage must surface as an error,
    /// never a bogus KV).
    ///
    /// Backends without a disk tier keep the default `Fatal`.
    fn recall_kv(&self, _bytes: &[u8]) -> Result<KvHandle, BackendError> {
        Err(BackendError::fatal("backend has no disk KV tier (recall_kv unsupported)"))
    }

    // -- blocking conveniences (submit + wait) -------------------------------

    /// Blocking promote: [`Backend::submit_promote`] + wait.
    fn promote_kv(&self, kv: &KvHandle) -> Result<(KvHandle, CallTiming), BackendError> {
        self.submit_promote(kv)?.wait_timed()
    }

    /// Blocking prefill: [`Backend::submit_prefill`] + wait.
    fn prefill(&self, module: &str, tokens: &[i32], plen: i32)
               -> Result<(KvHandle, Vec<f32>), BackendError> {
        self.submit_prefill(module, tokens, plen)?.wait()
    }

    /// Blocking extend: [`Backend::submit_extend`] + wait.
    fn extend(&self, module: &str, kv: &KvHandle, plen: i32, q_tokens: &[i32], qlen: i32)
              -> Result<(KvHandle, Vec<f32>), BackendError> {
        self.submit_extend(module, kv, plen, q_tokens, qlen)?.wait()
    }

    /// Blocking generate: [`Backend::submit_generate`] + wait.
    fn generate(&self, module: &str, kv: &KvHandle, cur_len: i32, first_tok: i32)
                -> Result<Vec<i32>, BackendError> {
        self.submit_generate(module, kv, cur_len, first_tok)?.wait()
    }

    /// Blocking encode: [`Backend::submit_encode`] + wait.
    fn encode(&self, module: &str, x: Vec<f32>, adj: Vec<f32>, mask: Vec<f32>)
              -> Result<Vec<f32>, BackendError> {
        self.submit_encode(module, x, adj, mask)?.wait()
    }
}

/// Merge per-lane stats snapshots into one [`EngineStats`] (calls
/// concatenated and re-sorted, counters summed).
pub(crate) fn merge_stats(parts: Vec<EngineStats>) -> EngineStats {
    let mut out = EngineStats::default();
    for p in parts {
        out.calls.extend(p.calls);
        out.live_kv += p.live_kv;
        out.compile_secs += p.compile_secs;
        out.host_kv_bytes += p.host_kv_bytes;
        out.unbatched_fallbacks += p.unbatched_fallbacks;
        out.lane_restarts += p.lane_restarts;
        out.breaker_trips += p.breaker_trips;
    }
    out.calls.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn wait_on_dropped_ticket_errors_instead_of_hanging() {
        let (tx, rx) = channel::<Result<(u64, Vec<f32>, CallTiming), BackendError>>();
        drop(tx);
        let err = PendingKv(Ticket { rx, lane: Lane::Llm }).wait().unwrap_err();
        assert!(err.to_string().contains("lane"), "unhelpful error: {err}");
        assert!(err.is_lane_dead(), "a dropped reply sender means the lane died");
        assert!(err.is_retryable(), "lane death is recoverable by recompute");

        let (tx, rx) = channel::<Result<(u64, Vec<f32>, CallTiming), BackendError>>();
        drop(tx);
        assert!(PendingKv(Ticket { rx, lane: Lane::Llm }).wait_timed().is_err());

        let (tx, rx) = channel::<Result<(Vec<i32>, CallTiming), BackendError>>();
        drop(tx);
        assert!(PendingGenerate(Ticket { rx, lane: Lane::Llm }).wait().is_err());

        let (tx, rx) = channel::<Result<(Vec<f32>, CallTiming), BackendError>>();
        drop(tx);
        assert!(PendingEncode(Ticket { rx, lane: Lane::Gnn }).wait().is_err());

        let (tx, rx) = channel::<Result<(u64, CallTiming), BackendError>>();
        drop(tx);
        let err = PendingPromote(Ticket { rx, lane: Lane::Llm }).wait().unwrap_err();
        assert!(err.is_lane_dead(), "a dropped promote ticket means the lane died");
    }

    #[test]
    fn promote_ticket_delivers_handle_and_timing() {
        let (tx, rx) = channel::<Result<(u64, CallTiming), BackendError>>();
        tx.send(Ok((42, CallTiming { device_secs: 0.125, ..Default::default() })))
            .unwrap();
        let (kv, t) =
            PendingPromote(Ticket { rx, lane: Lane::Llm }).wait_timed().unwrap();
        assert_eq!(kv, KvHandle(42));
        assert!((t.device_secs - 0.125).abs() < 1e-12);
    }

    #[test]
    fn ticket_delivers_value_sent_before_drop() {
        // a reply that was already sent must still arrive after the lane
        // side dropped its sender — wait is recv, not a liveness check.
        let (tx, rx) = channel::<Result<(u64, Vec<f32>, CallTiming), BackendError>>();
        tx.send(Ok((7, vec![1.0], CallTiming::default()))).unwrap();
        drop(tx);
        let (kv, logits, t) =
            PendingKv(Ticket { rx, lane: Lane::Llm }).wait_timed().unwrap();
        assert_eq!(kv, KvHandle(7));
        assert_eq!(logits, vec![1.0]);
        assert_eq!(t.secs(), 0.0);
    }

    #[test]
    fn error_taxonomy_classifies_through_anyhow_context() {
        use anyhow::Context as _;
        let base: Result<(), BackendError> =
            Err(BackendError::transient("extend", "injected fault"));
        let wrapped: anyhow::Result<()> = base.context("query 7 failed");
        let err = wrapped.unwrap_err();
        let be = BackendError::classify(&err).expect("taxonomy survives context");
        assert!(be.is_retryable() && !be.is_lane_dead());
        assert!(matches!(be, BackendError::Transient { op: "extend", .. }));

        let fatal = BackendError::fatal("unknown module");
        assert!(!fatal.is_retryable());
        let dead = BackendError::lane_dead(Lane::Llm, "killed");
        assert!(dead.to_string().contains("lane"), "LaneDead names the lane");

        let full = BackendError::overloaded(Lane::Llm, "queue full");
        assert!(full.is_retryable(), "overload clears — retry (with backoff) is sane");
        assert!(full.is_overloaded() && !full.is_lane_dead());
        assert!(!dead.is_overloaded() && !fatal.is_overloaded());
        assert!(full.to_string().contains("llm lane overloaded"),
                "Overloaded names the lane: {full}");
    }

    #[test]
    fn queue_gate_reject_policy_fails_fast_when_full() {
        let g = QueueGate::new(QueueConfig::reject(2));
        g.admit(Lane::Llm).unwrap();
        g.admit(Lane::Llm).unwrap();
        assert_eq!(g.depth(), 2);
        let err = g.admit(Lane::Llm).unwrap_err();
        assert!(err.is_overloaded(), "full reject queue must be Overloaded: {err}");
        g.release(1);
        assert_eq!(g.depth(), 1);
        g.admit(Lane::Llm).expect("freed slot admits again");
    }

    #[test]
    fn queue_gate_block_policy_times_out_bounded() {
        let g = QueueGate::new(QueueConfig::block(
            1, std::time::Duration::from_millis(5)));
        g.admit(Lane::Llm).unwrap();
        let t0 = std::time::Instant::now();
        let err = g.admit(Lane::Llm).unwrap_err();
        assert!(err.is_overloaded());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5),
                "Block must wait for the timeout before failing");
        assert!(t0.elapsed() < std::time::Duration::from_secs(5),
                "a full bounded queue must never block (nearly) forever");
    }

    #[test]
    fn queue_gate_block_policy_wakes_on_release() {
        use std::sync::Arc;
        let g = Arc::new(QueueGate::new(QueueConfig::block(
            1, std::time::Duration::from_secs(10))));
        g.admit(Lane::Llm).unwrap();
        let g2 = Arc::clone(&g);
        let waiter = std::thread::spawn(move || g2.admit(Lane::Llm));
        std::thread::sleep(std::time::Duration::from_millis(10));
        g.release(1);
        waiter.join().unwrap().expect("released slot must wake the blocked submit");
        assert_eq!(g.depth(), 1);
    }

    #[test]
    fn queue_gate_unbounded_tracks_depth_without_refusing() {
        let g = QueueGate::new(QueueConfig::unbounded());
        assert!(!QueueConfig::unbounded().enabled());
        for _ in 0..100 {
            g.admit(Lane::Gnn).unwrap();
        }
        assert_eq!(g.depth(), 100, "unbounded still gauges depth");
        g.release(100);
        assert_eq!(g.depth(), 0);
    }

    #[test]
    fn queue_gate_reset_frees_everything() {
        let g = QueueGate::new(QueueConfig::reject(1));
        g.admit(Lane::Llm).unwrap();
        assert!(g.admit(Lane::Llm).is_err());
        g.reset();
        assert_eq!(g.depth(), 0);
        g.admit(Lane::Llm).expect("reset gate admits again");
        g.release(5);
        assert_eq!(g.depth(), 0, "release never underflows");
    }

    #[test]
    fn call_timing_sums_components() {
        let t = CallTiming { queue_secs: 0.25, device_secs: 0.5, ..Default::default() };
        assert!((t.secs() - 0.75).abs() < 1e-12);
        let w = CallTiming { queue_secs: 0.25, window_secs: 0.125, device_secs: 0.5,
                             ..Default::default() };
        assert!((w.secs() - 0.875).abs() < 1e-12, "window time counts toward secs()");
    }

    #[test]
    fn lane_names_are_stable() {
        assert_eq!(Lane::Llm.name(), "llm");
        assert_eq!(Lane::Gnn.name(), "gnn");
        assert_eq!(Lane::ALL.len(), 2);
    }

    #[test]
    fn merge_stats_sums_and_sorts() {
        let a = EngineStats {
            calls: vec![("m.prefill".into(), 2, 0.5)],
            live_kv: 3,
            compile_secs: 1.0,
            host_kv_bytes: 0,
            unbatched_fallbacks: 1,
            lane_restarts: 1,
            breaker_trips: 1,
        };
        let b = EngineStats {
            calls: vec![("gat.encode".into(), 4, 0.25)],
            live_kv: 0,
            compile_secs: 0.5,
            host_kv_bytes: 8,
            unbatched_fallbacks: 2,
            lane_restarts: 2,
            breaker_trips: 0,
        };
        let m = merge_stats(vec![a, b]);
        assert_eq!(m.live_kv, 3);
        assert!((m.compile_secs - 1.5).abs() < 1e-12);
        assert_eq!(m.host_kv_bytes, 8);
        assert_eq!(m.unbatched_fallbacks, 3);
        assert_eq!(m.lane_restarts, 3);
        assert_eq!(m.breaker_trips, 1);
        assert_eq!(m.calls[0].0, "gat.encode", "calls must be re-sorted");
        assert_eq!(m.calls[1].0, "m.prefill");
    }
}
