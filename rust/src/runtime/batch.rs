//! Lane-side continuous micro-batching: the window/compatibility machinery
//! shared by the PJRT engine and the sim backend.
//!
//! # The batching contract
//!
//! A lane worker that receives a *fusible* request (prefill / extend /
//! generate / encode) opens a **batch window**: it keeps draining its queue
//! for up to [`BatchConfig::max_wait`], collecting further requests that are
//! *compatible* with the first — same op kind AND same module (backbone) —
//! until the batch holds [`BatchConfig::max_batch`] members, the window
//! expires, or an incompatible request arrives (which closes the window
//! early and is carried over to execute right after the batch, preserving
//! lane FIFO order). The collected members execute as ONE device call and
//! the per-member results are scattered back to each caller's ticket, so
//! nothing above the `Backend` trait changes shape.
//!
//! Members of one batch are always mutually independent: a request that
//! needs another's result (e.g. an extend on a prefill's handle) can only
//! be submitted after that ticket resolved, so it can never share a window
//! with its producer.
//!
//! # Timing attribution
//!
//! Per-request [`super::CallTiming`] stays honest inside a fused batch:
//!
//! * `queue_secs`  — submit → the moment the worker pulled the request off
//!   the channel (into the forming batch);
//! * `window_secs` — pulled → batch launch (residency inside the open
//!   window; zero when batching is off);
//! * `device_secs` — the batch's device span, attributed to **every**
//!   member (each really did wait that long for its result).
//!
//! So that aggregates don't double-count the shared device span,
//! [`BatchInfo::leader`] marks exactly one member per launch;
//! `metrics::LaneTimes` sums `device_secs` over leaders only, keeping
//! lane-busy fractions ≤ wall time no matter the occupancy.
//!
//! # Interaction with bounded queues
//!
//! When the lane runs under a [`super::QueueConfig`] bound, a queue slot is
//! taken at submit and released only when the worker *pulls* the request off
//! the channel — for a fused launch that means [`collect_window`] returning,
//! at which point the lane releases one slot per collected member in a
//! single step. Members sitting inside an open batch window therefore still
//! count against the bound (they have been picked but not yet launched for
//! under `max_wait`); this is deliberate — the bound tracks admitted,
//! unfinished submissions, so an open window cannot be used to smuggle
//! unbounded work past admission control. `Backend::queue_depth` reports
//! the same number: slots currently held, whether waiting in the channel or
//! riding a forming window.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Lane micro-batcher knobs. The default ([`BatchConfig::off`]) disables
/// fusion entirely — one request per device call, the pre-batching
/// behavior, bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Most members one fused device call may carry (≥ 1; 1 = no fusion).
    pub max_batch: usize,
    /// Longest a non-full batch window stays open waiting for more
    /// compatible work. `ZERO` with `max_batch > 1` fuses only what is
    /// already queued (opportunistic batching, no added latency).
    pub max_wait: Duration,
}

impl BatchConfig {
    /// Batching disabled: every request is its own device call.
    pub fn off() -> BatchConfig {
        BatchConfig { max_batch: 1, max_wait: Duration::ZERO }
    }

    /// `max_batch` is clamped to ≥ 1.
    pub fn new(max_batch: usize, max_wait: Duration) -> BatchConfig {
        BatchConfig { max_batch: max_batch.max(1), max_wait }
    }

    /// Whether this config can ever fuse two requests.
    pub fn enabled(&self) -> bool {
        self.max_batch > 1
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig::off()
    }
}

/// How one request rode the lane: carried on every [`super::CallTiming`]
/// so run-level metrics can reconstruct launch counts and occupancy from
/// per-request records alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchInfo {
    /// Members in the fused device call this request rode in (1 = alone).
    pub size: u32,
    /// Exactly one member per launch carries `leader = true`; aggregates
    /// count device time and occupancy once per launch through it.
    pub leader: bool,
    /// Leader only: the window expired before the batch filled (the launch
    /// paid `max_wait` without reaching `max_batch`).
    pub stalled: bool,
}

impl Default for BatchInfo {
    fn default() -> Self {
        BatchInfo { size: 1, leader: true, stalled: false }
    }
}

impl BatchInfo {
    /// Info for member `i` of an `n`-member launch.
    pub(crate) fn member(i: usize, n: usize, stalled: bool) -> BatchInfo {
        BatchInfo { size: n as u32, leader: i == 0, stalled: stalled && i == 0 }
    }
}

/// One batch window's worth of requests pulled off a lane queue.
pub(crate) struct Collected<R> {
    /// The members in arrival order, each with its pickup instant (the end
    /// of its `queue_secs`).
    pub members: Vec<(R, Instant)>,
    /// An incompatible request that closed the window early; the lane must
    /// process it immediately after the batch (FIFO preserved: it arrived
    /// after every member).
    pub carry: Option<R>,
    /// The window expired before the batch filled.
    pub stalled: bool,
    /// Batch launch instant (the end of every member's `window_secs`).
    pub launched: Instant,
}

/// Drain a lane queue under the batch window. `first` has already been
/// received; more requests are pulled while `compatible(&first, &next)`
/// holds, the batch is under `cfg.max_batch`, and the window has time left.
/// With `max_batch == 1` this returns immediately — the single-request
/// fast path costs one `Instant::now()` over the pre-batching code.
pub(crate) fn collect_window<R>(rx: &Receiver<R>, first: R, cfg: BatchConfig,
                                compatible: impl Fn(&R, &R) -> bool)
                                -> Collected<R> {
    let picked = Instant::now();
    let mut members = vec![(first, picked)];
    let mut carry = None;
    let mut stalled = false;
    if cfg.max_batch > 1 {
        let deadline = picked + cfg.max_wait;
        while members.len() < cfg.max_batch {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let next = if remaining.is_zero() {
                match rx.try_recv() {
                    Ok(r) => Some(r),
                    Err(_) => {
                        // nothing instantly available; only a window that
                        // was actually held open counts as a stall
                        stalled = !cfg.max_wait.is_zero();
                        None
                    }
                }
            } else {
                match rx.recv_timeout(remaining) {
                    Ok(r) => Some(r),
                    Err(RecvTimeoutError::Timeout) => {
                        stalled = true;
                        None
                    }
                    Err(RecvTimeoutError::Disconnected) => None,
                }
            };
            let Some(r) = next else { break };
            if compatible(&members[0].0, &r) {
                members.push((r, Instant::now()));
            } else {
                carry = Some(r);
                break;
            }
        }
    }
    Collected { members, carry, stalled, launched: Instant::now() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn config_default_is_off_and_clamps() {
        assert_eq!(BatchConfig::default(), BatchConfig::off());
        assert!(!BatchConfig::off().enabled());
        let c = BatchConfig::new(0, Duration::from_millis(5));
        assert_eq!(c.max_batch, 1, "max_batch clamps to >= 1");
        assert!(BatchConfig::new(4, Duration::ZERO).enabled());
    }

    #[test]
    fn batch_info_default_is_a_lone_leader() {
        let b = BatchInfo::default();
        assert_eq!((b.size, b.leader, b.stalled), (1, true, false));
        let m = BatchInfo::member(2, 4, true);
        assert_eq!((m.size, m.leader, m.stalled), (4, false, false));
        let l = BatchInfo::member(0, 4, true);
        assert!(l.leader && l.stalled, "only the leader carries the stall");
    }

    #[test]
    fn max_batch_one_returns_immediately() {
        let (_tx, rx) = channel::<u32>();
        let t0 = Instant::now();
        let c = collect_window(&rx, 7, BatchConfig::off(), |_, _| true);
        assert!(t0.elapsed() < Duration::from_millis(20), "no window held open");
        assert_eq!(c.members.len(), 1);
        assert!(c.carry.is_none() && !c.stalled);
    }

    #[test]
    fn collects_compatible_until_full_without_stalling() {
        let (tx, rx) = channel::<u32>();
        tx.send(2).unwrap();
        tx.send(3).unwrap();
        let cfg = BatchConfig::new(3, Duration::from_secs(5));
        let c = collect_window(&rx, 1, cfg, |_, _| true);
        assert_eq!(c.members.iter().map(|(r, _)| *r).collect::<Vec<_>>(), [1, 2, 3]);
        assert!(!c.stalled, "a full batch is not a stall");
        assert!(c.carry.is_none());
    }

    #[test]
    fn incompatible_request_closes_window_and_carries_over() {
        let (tx, rx) = channel::<u32>();
        tx.send(10).unwrap(); // compatible (same parity)
        tx.send(11).unwrap(); // incompatible — must carry, not join
        let cfg = BatchConfig::new(8, Duration::from_secs(5));
        let c = collect_window(&rx, 0, cfg, |a, b| a % 2 == b % 2);
        assert_eq!(c.members.iter().map(|(r, _)| *r).collect::<Vec<_>>(), [0, 10]);
        assert_eq!(c.carry, Some(11));
        assert!(!c.stalled);
    }

    #[test]
    fn empty_queue_expires_the_window_as_a_stall() {
        let (_tx, rx) = channel::<u32>();
        let cfg = BatchConfig::new(4, Duration::from_millis(20));
        let t0 = Instant::now();
        let c = collect_window(&rx, 1, cfg, |_, _| true);
        assert!(t0.elapsed() >= Duration::from_millis(20), "window held open");
        assert_eq!(c.members.len(), 1);
        assert!(c.stalled);
    }

    #[test]
    fn disconnected_mid_window_keeps_collected_members() {
        // every sender dropped while the window is open: the batch launches
        // with what it has — already-collected members still execute and
        // reply; nothing hangs waiting out a channel that can never deliver.
        let (tx, rx) = channel::<u32>();
        tx.send(2).unwrap();
        drop(tx);
        let cfg = BatchConfig::new(8, Duration::from_secs(5));
        let t0 = Instant::now();
        let c = collect_window(&rx, 1, cfg, |_, _| true);
        assert!(t0.elapsed() < Duration::from_millis(100),
                "disconnect must close the window, not wait it out");
        assert_eq!(c.members.iter().map(|(r, _)| *r).collect::<Vec<_>>(), [1, 2]);
        assert!(c.carry.is_none(), "a dead channel cannot carry a request");
        assert!(!c.stalled, "disconnect is not a window stall");
    }

    #[test]
    fn disconnected_before_any_arrival_launches_the_first_alone() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let cfg = BatchConfig::new(4, Duration::from_secs(5));
        let t0 = Instant::now();
        let c = collect_window(&rx, 9, cfg, |_, _| true);
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert_eq!(c.members.len(), 1, "the in-hand request still runs");
        assert!(c.carry.is_none() && !c.stalled);
    }

    #[test]
    fn zero_wait_fuses_only_whats_queued() {
        let (tx, rx) = channel::<u32>();
        tx.send(2).unwrap();
        let cfg = BatchConfig::new(8, Duration::ZERO);
        let t0 = Instant::now();
        let c = collect_window(&rx, 1, cfg, |_, _| true);
        assert!(t0.elapsed() < Duration::from_millis(20));
        assert_eq!(c.members.len(), 2);
        assert!(!c.stalled, "no window was held open");
    }
}
