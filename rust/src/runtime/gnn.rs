//! Packing retrieved subgraphs into the GNN encoder's fixed-shape inputs
//! (x [N_MAX, F], adj [N_MAX, N_MAX], mask [N_MAX]) — the request-path
//! counterpart of `python/compile/gnn.py`'s contract.

use crate::graph::{Subgraph, TextualGraph};
use crate::retrieval::GraphFeatures;

/// Dense GNN inputs for one subgraph (row-major flattened).
pub struct PackedSubgraph {
    pub x: Vec<f32>,
    pub adj: Vec<f32>,
    pub mask: Vec<f32>,
    pub n_used: usize,
}

/// Pack `sg` into fixed [n_max, feat_dim] tensors. Nodes are laid out in
/// ascending id order; subgraphs larger than `n_max` are truncated (the
/// retrievers already cap at `MAX_RETRIEVED_NODES`, asserted upstream).
pub fn pack_subgraph(g: &TextualGraph, feats: &GraphFeatures, sg: &Subgraph,
                     n_max: usize, feat_dim: usize) -> PackedSubgraph {
    let ids: Vec<usize> = sg.nodes.iter().copied().take(n_max).collect();
    let mut local = std::collections::HashMap::with_capacity(ids.len());
    for (i, &id) in ids.iter().enumerate() {
        local.insert(id, i);
    }
    let mut x = vec![0f32; n_max * feat_dim];
    let mut mask = vec![0f32; n_max];
    for (i, &id) in ids.iter().enumerate() {
        x[i * feat_dim..(i + 1) * feat_dim].copy_from_slice(&feats.node_emb[id]);
        mask[i] = 1.0;
    }
    let mut adj = vec![0f32; n_max * n_max];
    for &ei in &sg.edges {
        let e = &g.edges[ei];
        if let (Some(&a), Some(&b)) = (local.get(&e.src), local.get(&e.dst)) {
            adj[a * n_max + b] = 1.0;
            adj[b * n_max + a] = 1.0;
        }
    }
    PackedSubgraph { x, adj, mask, n_used: ids.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Edge, Node};

    fn g() -> TextualGraph {
        TextualGraph::new(
            "t",
            vec![
                Node { id: 0, name: "a".into(), text: "a red".into() },
                Node { id: 1, name: "b".into(), text: "b blue".into() },
                Node { id: 2, name: "c".into(), text: "c".into() },
            ],
            vec![
                Edge { src: 0, dst: 1, text: "r".into() },
                Edge { src: 1, dst: 2, text: "r".into() },
            ],
        )
        .unwrap()
    }

    #[test]
    fn packs_features_and_adjacency() {
        let g = g();
        let feats = GraphFeatures::build(&g);
        let sg = Subgraph::from_parts([0, 1], [0]);
        let p = pack_subgraph(&g, &feats, &sg, 4, 64);
        assert_eq!(p.n_used, 2);
        assert_eq!(p.mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&p.x[..64], feats.node_emb[0].as_slice());
        assert_eq!(p.adj[0 * 4 + 1], 1.0);
        assert_eq!(p.adj[1 * 4 + 0], 1.0);
        assert_eq!(p.adj[0 * 4 + 0], 0.0);
    }

    #[test]
    fn drops_edges_with_missing_endpoints() {
        let g = g();
        let feats = GraphFeatures::build(&g);
        // edge 1 connects node 1-2 but node 2 is not in the subgraph
        let sg = Subgraph::from_parts([0, 1], [0, 1]);
        let p = pack_subgraph(&g, &feats, &sg, 4, 64);
        assert_eq!(p.adj.iter().filter(|&&v| v != 0.0).count(), 2);
    }

    #[test]
    fn truncates_over_capacity() {
        let g = g();
        let feats = GraphFeatures::build(&g);
        let sg = Subgraph::from_parts([0, 1, 2], [0, 1]);
        let p = pack_subgraph(&g, &feats, &sg, 2, 64);
        assert_eq!(p.n_used, 2);
        assert_eq!(p.mask, vec![1.0, 1.0]);
    }
}
