//! `subgcache` — leader binary: serve an in-batch workload with or without
//! SubGCache and print the paper-style metrics.
//!
//! ```text
//! subgcache --dataset scene_graph --retriever g-retriever \
//!           --backbone llama-3.2-3b-sim --batch 100 --clusters 1 \
//!           [--baseline] [--linkage ward] [--seed 7] [--artifacts PATH]
//! ```

use subgcache::prelude::*;
use subgcache::retrieval;

fn retriever_by_name(name: &str) -> anyhow::Result<Box<dyn Retriever>> {
    Ok(match name {
        "g-retriever" => Box::new(GRetriever::default()),
        "grag" => Box::new(GragRetriever::default()),
        other => anyhow::bail!("unknown retriever '{other}' (g-retriever | grag)"),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.flag("help") {
        println!("{}", include_str!("main.rs").lines().take(8)
                 .map(|l| l.trim_start_matches("//! ").trim_start_matches("//!"))
                 .collect::<Vec<_>>().join("\n"));
        return Ok(());
    }

    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let ds = store.dataset(args.get_or("dataset", "scene_graph"))?;
    let retriever = retriever_by_name(args.get_or("retriever", "g-retriever"))?;
    let batch = args.usize_or("batch", 100);
    let seed = args.usize_or("seed", 7) as u64;
    let queries = ds.sample_test(batch, seed);

    let cfg = ServeConfig {
        backbone: args.get_or("backbone", "llama-3.2-3b-sim").to_string(),
        n_clusters: args.usize_or("clusters", 2),
        linkage: Linkage::parse(args.get_or("linkage", "ward"))
            .ok_or_else(|| anyhow::anyhow!("bad --linkage"))?,
        gnn: args.get("gnn").map(|s| s.to_string()),
    };

    let engine = Engine::start(&store)?;
    let coord = Coordinator::new(&store, &engine, cfg.clone())?;

    eprintln!(
        "serving {} queries from {} via {} on {} ({} mode, c={})",
        queries.len(),
        ds.graph.name,
        retriever.name(),
        cfg.backbone,
        if args.flag("baseline") { "baseline" } else { "subgcache" },
        cfg.n_clusters,
    );

    let report = if args.flag("baseline") {
        coord.serve_baseline(&ds, &queries, retriever.as_ref())?
    } else {
        coord.serve_subgcache(&ds, &queries, retriever.as_ref())?
    };

    let mut t = Table::new(&["metric", "value"]);
    t.row(&["ACC (%)".into(), format!("{:.2}", report.metrics.acc())]);
    t.row(&["RT (ms)".into(), format!("{:.2}", report.metrics.rt_ms())]);
    t.row(&["TTFT (ms)".into(), format!("{:.2}", report.metrics.ttft_ms())]);
    t.row(&["PFTT (ms)".into(), format!("{:.2}", report.metrics.pftt_ms())]);
    t.row(&["cluster stage (ms)".into(),
            format!("{:.2}", report.metrics.cluster_time * 1e3)]);
    if !report.cluster_sizes.is_empty() {
        t.row(&["cluster sizes".into(), format!("{:?}", report.cluster_sizes)]);
    }
    t.print();

    if args.flag("verbose") {
        for r in report.results.iter().take(10) {
            println!("[{}] q={:?} pred={:?} gold={:?} ok={}",
                     r.id, r.query, r.predicted, r.gold, r.correct);
        }
        let st = engine.stats();
        println!("engine: compile {:.2}s, live_kv {}", st.compile_secs, st.live_kv);
        for (k, n, s) in st.calls {
            println!("  {k}: {n} calls, {:.1} ms avg", s / n as f64 * 1e3);
        }
    }
    let _ = retrieval::MAX_RETRIEVED_NODES; // re-export sanity
    Ok(())
}
