//! `subgcache` — leader binary: serve a workload with or without SubGCache
//! (in-batch or streaming) and print the paper-style metrics.
//!
//! ```text
//! subgcache --dataset scene_graph --retriever g-retriever \
//!           --backbone llama-3.2-3b-sim --batch 100 --clusters 1 \
//!           [--baseline | --online] [--linkage ward] [--seed 7] \
//!           [--cache-mb N] [--cache-entries N] [--threshold D] \
//!           [--depth K] [--ttl N] [--deadline-ms N] [--max-retries N] \
//!           [--artifacts PATH]
//! ```

use subgcache::prelude::*;
use subgcache::retrieval;

fn retriever_by_name(name: &str) -> anyhow::Result<Box<dyn Retriever>> {
    Ok(match name {
        "g-retriever" => Box::new(GRetriever::default()),
        "grag" => Box::new(GragRetriever::default()),
        other => anyhow::bail!("unknown retriever '{other}' (g-retriever | grag)"),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.flag("help") {
        println!("{}", include_str!("main.rs").lines().take(11)
                 .map(|l| l.trim_start_matches("//! ").trim_start_matches("//!"))
                 .collect::<Vec<_>>().join("\n"));
        return Ok(());
    }
    // reject conflicting modes before the expensive engine startup.
    anyhow::ensure!(
        !(args.flag("baseline") && args.flag("online")),
        "--baseline and --online are mutually exclusive"
    );

    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let ds = store.dataset(args.get_or("dataset", "scene_graph"))?;
    let retriever = retriever_by_name(args.get_or("retriever", "g-retriever"))?;
    let batch = args.usize_or("batch", 100);
    let seed = args.usize_or("seed", 7) as u64;
    let queries = ds.sample_test(batch, seed);

    let default_cfg = ServeConfig::default();
    let cache = subgcache::harness::cache_policy_from_args(&args)?;
    let cfg = ServeConfig {
        backbone: args.get_or("backbone", "llama-3.2-3b-sim").to_string(),
        n_clusters: args.usize_or("clusters", 2),
        linkage: Linkage::parse(args.get_or("linkage", "ward"))
            .ok_or_else(|| anyhow::anyhow!("bad --linkage"))?,
        gnn: args.get("gnn").map(|s| s.to_string()),
        cache,
        online_threshold: args.f64_or("threshold", default_cfg.online_threshold as f64)
            as f32,
        pipeline_depth: args.usize_or("depth", default_cfg.pipeline_depth),
        cluster_ttl: args.get("ttl").map(|v| v.parse().expect("bad --ttl (arrivals)")),
        deadline: match args.get("deadline-ms") {
            Some(v) => {
                let ms: f64 = v.parse()
                    .map_err(|_| anyhow::anyhow!("bad --deadline-ms (milliseconds)"))?;
                anyhow::ensure!(ms.is_finite() && ms > 0.0,
                                "--deadline-ms must be a positive ms value");
                Some(std::time::Duration::from_secs_f64(ms / 1e3))
            }
            None => default_cfg.deadline,
        },
        max_retries: args.usize_or("max-retries", default_cfg.max_retries as usize)
            as u32,
        overload: default_cfg.overload,
    };

    let engine = Engine::start(&store)?;
    let coord = Coordinator::new(&store, &engine, cfg.clone())?;

    let mode = if args.flag("baseline") {
        "baseline"
    } else if args.flag("online") {
        "online"
    } else {
        "subgcache"
    };
    // online clusters form dynamically from --threshold; --clusters is only
    // read by the batch pipeline, so don't print an inert c.
    let mode_detail = if mode == "online" {
        format!("threshold={}", cfg.online_threshold)
    } else {
        format!("c={}", cfg.n_clusters)
    };
    eprintln!(
        "serving {} queries from {} via {} on {} ({mode} mode, {mode_detail})",
        queries.len(),
        ds.graph.name,
        retriever.name(),
        cfg.backbone,
    );

    let report = match mode {
        "baseline" => coord.serve_baseline(&ds, &queries, retriever.as_ref())?,
        "online" => coord.serve_online(&ds, queries.iter().copied(), retriever.as_ref())?,
        _ => coord.serve_subgcache(&ds, &queries, retriever.as_ref())?,
    };

    let mut t = Table::new(&["metric", "value"]);
    t.row(&["ACC (%)".into(), format!("{:.2}", report.metrics.acc())]);
    t.row(&["RT (ms)".into(), format!("{:.2}", report.metrics.rt_ms())]);
    t.row(&["TTFT (ms)".into(), format!("{:.2}", report.metrics.ttft_ms())]);
    t.row(&["PFTT (ms)".into(), format!("{:.2}", report.metrics.pftt_ms())]);
    t.row(&["cluster stage (ms)".into(),
            format!("{:.2}", report.metrics.cluster_time * 1e3)]);
    t.row(&["wall (s)".into(), format!("{:.2}", report.metrics.wall_time)]);
    t.row(&["throughput (q/s)".into(), format!("{:.2}", report.metrics.qps())]);
    if report.metrics.overlap_time > 0.0 {
        t.row(&["host prep overlapped (ms)".into(),
                format!("{:.2}", report.metrics.overlap_time * 1e3)]);
    }
    if mode == "online" {
        t.row(&["TTFT hit (ms)".into(),
                format!("{:.2}", report.metrics.ttft_hit_ms())]);
        t.row(&["TTFT miss (ms)".into(),
                format!("{:.2}", report.metrics.ttft_miss_ms())]);
        t.row(&["hits/misses".into(),
                format!("{}/{}", report.metrics.hit_count(),
                        report.metrics.miss_count())]);
        // only meaningful online: the batch pipeline's lookups always follow
        // its own installs, so its rate is trivially 100%.
        t.row(&["cache hit-rate (%)".into(),
                format!("{:.0}", 100.0 * report.cache.hit_rate())]);
    }
    if mode != "baseline" {
        t.row(&["cache evictions".into(), report.cache.evictions.to_string()]);
    }
    if !report.cluster_sizes.is_empty() {
        t.row(&["cluster sizes".into(), format!("{:?}", report.cluster_sizes)]);
    }
    t.print();

    if args.flag("verbose") {
        for r in report.results.iter().take(10) {
            println!("[{}] q={:?} pred={:?} gold={:?} ok={}",
                     r.id, r.query, r.predicted, r.gold, r.correct);
        }
        let st = engine.stats()?;
        println!("engine: compile {:.2}s, live_kv {}, host KV bytes {}",
                 st.compile_secs, st.live_kv, st.host_kv_bytes);
        for (k, n, s) in st.calls {
            println!("  {k}: {n} calls, {:.1} ms avg", s / n as f64 * 1e3);
        }
    }
    let _ = retrieval::MAX_RETRIEVED_NODES; // re-export sanity
    Ok(())
}
