//! The in-batch serving pipelines (Fig. 1a/1b), rebuilt on the session core,
//! the multi-resident KV cache, and the engine's submit/wait ticket API.
//!
//! `serve_subgcache` no longer force-releases cluster-by-cluster: each
//! representative cache is admitted pinned, unpinned once its members are
//! served, and left resident until the [`crate::cache::CachePolicy`] budget
//! evicts it (LRU) or the end-of-batch drain returns it. The cache is still
//! per-call (a private [`crate::cache::KvCacheManager`] view, drained before
//! the report returns); what the budget buys the batch path is bounded
//! memory under many clusters without the seed's forced one-resident churn.
//! Cross-request warm reuse is the online path's job ([`super::online`]),
//! which can additionally share one [`crate::cache::SharedKvCache`] pool
//! across concurrent streams.
//!
//! Pipelining: each cluster's representative prefill is *submitted* and the
//! members' question tokenization runs in its shadow, so host prompt prep
//! and device prefill overlap instead of serializing. Per-query latencies
//! are composed from component times (see [`super::session`]), so the
//! overlap shows up in `BatchMetrics::wall_time`, not as distorted TTFTs.

use crate::cache::KvCacheManager;
use crate::cluster::{cluster, groups};
use crate::data::{Dataset, Query};
use crate::graph::Subgraph;
use crate::metrics::{QueryLatency, Timer};
use crate::retrieval::{GraphFeatures, Retriever};
use crate::runtime::{pack_subgraph, KvHandle};

use super::session::PreparedQuestion;
use super::{Coordinator, ServeReport};

impl<'e> Coordinator<'e> {
    // -- baseline pipeline ---------------------------------------------------

    /// Standard graph-based RAG: retrieve → verbalize → full prefill →
    /// decode, independently per query (Fig. 1a).
    pub fn serve_baseline(&self, ds: &Dataset, queries: &[&Query],
                          retriever: &dyn Retriever) -> anyhow::Result<ServeReport> {
        self.engine.warmup(&self.cfg.backbone)?;
        let session = self.session();
        let feats = GraphFeatures::build(&ds.graph);
        let mut report = ServeReport::default();
        let mut llm_time = 0.0;
        let t_wall = Timer::start();

        for q in queries {
            let t_retr = Timer::start();
            let sg = retriever.retrieve(&ds.graph, &feats, &q.text);
            let retrieval_secs = t_retr.secs();

            let mut out = session.serve_full(&ds.graph, sg, q)?;
            out.latency.ttft += retrieval_secs;
            out.latency.rt += retrieval_secs;
            llm_time += out.llm_secs;
            report.metrics.lane_llm.add(&out.prefill_timing);
            report.metrics.lane_llm.add(&out.gen_timing);
            report.metrics.per_query.push(out.latency);
            report.results.push(out.result);
        }
        report.metrics.llm_time = llm_time;
        report.metrics.wall_time = t_wall.secs();
        Ok(report)
    }

    // -- SubGCache pipeline --------------------------------------------------

    /// The in-batch SubGCache pipeline (Fig. 1b / §3): cluster the batch,
    /// prefill each cluster's representative subgraph once, serve members by
    /// extending the shared KV cache. The representative prefill is
    /// overlapped with the cluster members' question tokenization.
    pub fn serve_subgcache(&self, ds: &Dataset, queries: &[&Query],
                           retriever: &dyn Retriever) -> anyhow::Result<ServeReport> {
        let m = queries.len();
        if m == 0 {
            return Ok(ServeReport::default());
        }
        self.engine.warmup(&self.cfg.backbone)?;
        let gnn = self.gnn_module(retriever);
        self.engine.warmup(&gnn)?;
        let c = *self.store.constants();
        let session = self.session();
        let feats = GraphFeatures::build(&ds.graph);
        let t_wall = Timer::start();

        // 1) per-query retrieval (charged individually, as in the baseline).
        let mut retrieval_secs = Vec::with_capacity(m);
        let mut subgraphs = Vec::with_capacity(m);
        for q in queries {
            let t = Timer::start();
            subgraphs.push(retriever.retrieve(&ds.graph, &feats, &q.text));
            retrieval_secs.push(t.secs());
        }

        // 2) cluster stage (Fig. 4's red series): GNN encoding + hierarchical
        //    clustering + representative construction. One-time, amortized.
        //    The encodes are pipelined onto the GNN lane: subgraph j+1 is
        //    packed host-side while subgraph j executes, then the tickets
        //    are collected in order (the lane is FIFO, so nothing reorders).
        let t_cluster = Timer::start();
        let mut pending_encs = Vec::with_capacity(m);
        for sg in &subgraphs {
            let p = pack_subgraph(&ds.graph, &feats, sg, c.n_max, c.feat_dim);
            pending_encs.push(self.engine.submit_encode(&gnn, p.x, p.adj, p.mask)?);
        }
        let mut embs = Vec::with_capacity(m);
        let mut lane_gnn = crate::metrics::LaneTimes::default();
        for pending in pending_encs {
            let (emb, enc_t) = pending.wait_timed()?;
            lane_gnn.add(&enc_t);
            embs.push(emb);
        }
        let assignment = cluster(&embs, self.cfg.n_clusters, self.cfg.linkage);
        let clusters = groups(&assignment);
        let representatives: Vec<Subgraph> = clusters
            .iter()
            .map(|members| {
                let parts: Vec<&Subgraph> = members.iter().map(|&i| &subgraphs[i]).collect();
                Subgraph::representative(&parts)
            })
            .collect();
        let cluster_secs = t_cluster.secs();
        let cluster_share = cluster_secs / m as f64;

        // 3) cluster-wise serving with subgraph-level KV cache reuse.
        let entry_bytes = self.kv_entry_bytes()?;
        let mut cache: KvCacheManager<KvHandle> = KvCacheManager::new(self.cfg.cache);
        let mut report = ServeReport {
            cluster_sizes: clusters.iter().map(|c| c.len()).collect(),
            representative_sizes: representatives.iter().map(|r| r.len()).collect(),
            results: Vec::with_capacity(m),
            metrics: crate::metrics::BatchMetrics {
                cluster_time: cluster_secs,
                // one overlap slot per cluster (members tokenize in the
                // representative prefill's shadow) = a depth-1 pipeline
                pipeline_depth: 1,
                lane_gnn,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut llm_time = 0.0;
        let mut shared_prefill_total = 0.0;
        let mut overlap_time = 0.0;
        let mut slots: Vec<Option<(QueryLatency, super::QueryResult)>> =
            (0..m).map(|_| None).collect();

        for (cid, members) in clusters.iter().enumerate() {
            // prefill the representative-subgraph prompt once per cluster;
            // while the engine executes it, tokenize every member's question
            // in its shadow (the overlap the batch path gets for free).
            let t_build = Timer::start();
            let (tokens, plen) = session.prefix_tokens(&ds.graph, &representatives[cid]);
            let build_secs = t_build.secs();
            let pending = self.engine.submit_prefill(&self.cfg.backbone, &tokens,
                                                     plen as i32)?;
            let t_shadow = Timer::start();
            let prepped: Vec<PreparedQuestion> = members
                .iter()
                .map(|&qi| session.prepare_question(&queries[qi].text))
                .collect();
            overlap_time += t_shadow.secs();
            let (kv, _logits, prefill_t) = pending.wait_timed()?;
            report.metrics.lane_llm.add(&prefill_t);
            let prefill_secs = build_secs + prefill_t.secs();
            shared_prefill_total += prefill_secs;
            let prefill_share = prefill_secs / members.len() as f64;
            // admitted pinned: the budget may evict colder representatives,
            // never this in-flight one.
            let evicted = cache.install(cid, kv, entry_bytes);
            self.engine.release_many(evicted);

            for (mi, &qi) in members.iter().enumerate() {
                let q = queries[qi];
                // the first member rides the prefill just paid above — no
                // lookup, so stats only count the genuinely avoided
                // prefills (hits = members - 1 per cluster). Later members
                // record a hit (which takes a pin, dropped again below —
                // the install pin already anchors the cluster's serving).
                if mi > 0 {
                    anyhow::ensure!(cache.lookup(cid).is_hit(), "cluster cache missing");
                }
                let out = {
                    // the extend is submitted with the representative
                    // handle borrowed under the cache lock, then waited
                    // outside it.
                    let pending = cache
                        .with_handle(cid, |kv| {
                            self.engine.submit_extend(&self.cfg.backbone, kv,
                                                      plen as i32, &prepped[mi].tokens,
                                                      prepped[mi].qlen as i32)
                        })
                        .ok_or_else(|| anyhow::anyhow!("cluster cache missing"))??;
                    session.extend_decode_submitted(pending, plen, &prepped[mi], || {})?
                };
                if mi > 0 {
                    cache.unpin(cid);
                }
                report.metrics.lane_llm.add(&out.ext_timing);
                report.metrics.lane_llm.add(&out.gen_timing);
                llm_time += out.t_done - out.t_prompt;

                // amortized accounting (App. A.3): the member's share of the
                // cluster stage and of its representative's prefill.
                let pftt = (out.t_first - out.t_prompt) + prefill_share;
                let ttft = retrieval_secs[qi] + cluster_share + out.t_prompt + pftt;
                let rt = ttft + (out.t_done - out.t_first);

                let result = session.result(q, out.predicted, cid, subgraphs[qi].clone());
                let correct = result.correct;
                slots[qi] = Some((
                    QueryLatency { rt, ttft, pftt, correct, cache_hit: None },
                    result,
                ));
            }
            // cluster complete: evictable, but stays warm while the budget
            // holds (the seed released unconditionally here).
            cache.unpin(cid);
        }

        for s in slots.into_iter() {
            let (lat, res) = s.expect("every query served");
            report.metrics.per_query.push(lat);
            report.results.push(res);
        }
        report.metrics.llm_time = llm_time + shared_prefill_total;
        report.metrics.shared_prefill_time = shared_prefill_total;
        report.metrics.overlap_time = overlap_time;
        self.engine.release_many(cache.release_all());
        report.cache = cache.stats();
        report.metrics.wall_time = t_wall.secs();
        Ok(report)
    }
}
