//! Online (streaming) SubGCache: the deployment setting the paper's §3
//! sketches but the in-batch pipeline never implements.
//!
//! Queries arrive one at a time. Each arriving query's retrieved subgraph is
//! GNN-encoded and assigned to the nearest existing cluster centroid within
//! `ServeConfig::online_threshold` (squared Euclidean over GNN embeddings);
//! farther queries open a new cluster whose representative subgraph — and
//! therefore prefix prompt — is frozen at open time, so a later warm hit
//! extends exactly the prefix that was prefilled. Centroids keep a running
//! mean of member embeddings so clusters track their query population.
//!
//! A query whose cluster's representative KV cache is still resident is a
//! **hit**: it pays only the question `extend`. A query that opens a new
//! cluster, or whose representative was evicted under the cache budget, is a
//! **miss**: it additionally pays the representative prefill in full — no
//! amortization exists online because membership is unknown at serve time.
//!
//! # The depth-k scheduler
//!
//! The stream is served as a software pipeline over the backend's two lanes
//! (`ServeConfig::pipeline_depth` = k):
//!
//! * **Prep queue** — up to k queries of engine-free host prep (retrieval,
//!   GNN input packing, question tokenization) run ahead of the query
//!   currently being served, refilled in the shadow of in-flight tickets.
//! * **Eager encode** (k ≥ 2) — a prepped query's GNN encode is submitted
//!   to the GNN lane at prep time, so query *i+1*'s encode executes while
//!   the LLM lane runs query *i*'s prefill/extend/generate. At its own turn
//!   the query only pays the *stall* it actually spends waiting for the
//!   embedding (often ~0) — not lane time that overlapped earlier work.
//! * **Decoupled decode** (k ≥ 2) — the greedy `generate` of query *i* is
//!   left in flight while query *i+1* is assigned and its `extend`
//!   submitted; the two touch different KV entries (the private
//!   prefix+question cache vs the next query's representative), so the LLM
//!   lane streams generate(i) → extend(i+1) back to back with no
//!   coordinator round-trip between them. Query *i* is finalized — decode
//!   waited, answer detokenized, latency recorded — in the shadow of query
//!   *i+1*'s extend. With k = 1 the decode is waited inline, reproducing
//!   the serial one-query-lookahead pipeline.
//!
//! Arrival order is never violated: cluster assignment, prefix
//! verbalization, cache state, and result records advance strictly in
//! stream order — only order-independent work moves into shadows.
//!
//! # Cross-stream sharing ([`Coordinator::serve_online_multi`])
//!
//! Many concurrent streams can serve against **one** [`SharedKvCache`] pool
//! and one backend: each worker thread runs the same depth-k scheduler over
//! its own [`KvCacheManager`] view. A stream that opens a cluster binds it
//! to the representative's content hash ([`RepKey`] over backbone, graph,
//! and representative node/edge ids), so identical representatives across
//! streams address one pool entry:
//!
//! * a representative stream A prefilled is a warm **shared hit** for
//!   stream B (`CacheStats::shared_hits` / `dedup_bytes_saved`);
//! * two streams missing the same representative at once are
//!   **single-flight coalesced** — the second blocks on the first's install
//!   reservation and then hits, so N racing streams pay exactly one
//!   prefill (the stall is charged to the waiting query's PFTT);
//! * eviction only reclaims entries with **zero pins across all streams**,
//!   and a TTL release of an entry another stream still pins is *deferred*
//!   (doomed, handle returned at the last unpin) — see the `cache` module
//!   docs for the full contract.
//!
//! Single-stream `serve_online` runs the identical code path over a private
//! pool, which keeps it metric-for-metric the PR 3 serial path.
//!
//! # Pin safety
//!
//! A cluster's representative entry is pinned from its lookup/install until
//! the query's *finalize* (not merely until the extend returns), so neither
//! a shadow-prep admission, budget eviction, TTL sweep, nor another
//! stream's activity can release an entry any in-flight ticket might still
//! reference. Pins nest across back-to-back queries of one cluster, and
//! count globally across streams.
//!
//! # Cluster TTL
//!
//! With `ServeConfig::cluster_ttl = Some(ttl)`, a sweep at the top of every
//! turn expires clusters whose centroid has not been opened/joined for more
//! than `ttl` arrivals: the centroid stops participating in matching and —
//! on a single-stream (private) run — its resident KV entry is released
//! back to the backend. On a shared pool the sweep only drops this stream's
//! binding: the same content may be another stream's warm hit, and one
//! stream's cluster staleness says nothing about the entry's pool-global
//! recency, so reclamation stays with the byte budget's LRU and the
//! end-of-run drain ([`KvCacheManager::expire`]). A pinned (in-flight)
//! representative — pinned by *any* stream — always survives a sweep
//! regardless of staleness; it is reconsidered once unpinned. Expired
//! clusters keep their slot (ids are stable) and are counted in
//! [`super::ServeReport::expired_clusters`].
//!
//! # Fault tolerance
//!
//! Backend failures surface as typed [`crate::runtime::BackendError`]s and
//! the scheduler degrades to recompute-and-retry instead of erroring the
//! stream — the representative KV pool is reconstructible state (RAGCache's
//! observation), so losing it costs a prefill, never an answer. A
//! [`Transient`] wait failure is retried in place; a [`LaneDead`] failure
//! additionally quarantines every cache entry whose device handle belongs
//! to the dead lane incarnation ([`KvCacheManager::quarantine_stale`]) and
//! *repays* the representative prefill — unless a host-tier copy survived
//! (host handles outlive lane incarnations, so the sweep spares them; see
//! the `cache` module docs), in which case recovery promotes the copy back
//! to the device instead of repaying. Single-flight still coalesces
//! racing repayers, and epoch-tagged pins keep a foreign stream's orphaned
//! unpin from ever stripping the repaid entry. Each backend stage of a
//! query (encode / prefill / extend / generate) draws on a bounded budget
//! ([`super::ServeConfig::max_retries`], optionally capped by the per-query
//! [`super::ServeConfig::deadline`]); exhaustion propagates the underlying
//! error and fails only this stream. Recovery work is counted in
//! [`crate::metrics::ReliabilityStats`] (retries, quarantined entries,
//! degraded spans/seconds, deadline hits, plus the lane supervisor's
//! restart delta) on `BatchMetrics` and, fleet-wide plus per-stream
//! outcomes, on [`MultiStreamReport`].
//!
//! [`Transient`]: crate::runtime::BackendError::Transient
//! [`LaneDead`]: crate::runtime::BackendError::LaneDead
//!
//! # Admission control & the brownout ladder
//!
//! With [`super::OverloadConfig`] engaged the stream runs as an *open*
//! system: a seeded [`super::ArrivalPlan`] assigns each query an arrival
//! offset, the scheduler waits for that offset before serving it, and a
//! **virtual backlog** — a deterministic single-server queue model in which
//! each admitted query occupies the server for the configured service
//! estimate — predicts the queueing delay every arrival would suffer.
//! Three mechanisms act on that prediction plus live signals:
//!
//! * **Admission control** (`shed = true`): a query whose predicted
//!   completion (`wait + estimate`) cannot meet `ServeConfig::deadline`
//!   (scaled by `headroom`) is shed at admission
//!   ([`super::QueryOutcome::Shed`] with
//!   [`super::ShedReason::Deadline`]) before any engine work is spent —
//!   the whole point, versus the post-hoc `deadline_hits` counter.
//!   Because the virtual backlog is a pure function of the arrival plan
//!   and the estimate, the shed set is bit-reproducible across same-seed
//!   runs on the sim backend. A *terminally* `Overloaded` submit (bounded
//!   queue full / breaker open past the retry budget) likewise sheds the
//!   query ([`super::ShedReason::Overloaded`]) instead of erroring the
//!   stream — a shed leader first **aborts its install reservation**
//!   ([`KvCacheManager::abort_install`]) so racing streams blocked on the
//!   single-flight discipline wake and elect a new installer. Deep
//!   lane-death recovery paths still propagate terminal errors: they mean
//!   the backend is sick, not merely busy.
//! * **Brownout ladder** ([`super::BrownoutConfig`]): the predicted wait
//!   against `backlog_steps` — bumped to level ≥ 1 by a live LLM-lane
//!   queue-depth or rolling-p95 watermark — selects a degradation level.
//!   Level 1 clamps the pipeline lookahead to 1; level 2 suspends
//!   new-cluster opens, joining the nearest live representative with the
//!   answer flagged degraded (or shedding with
//!   [`super::ShedReason::Brownout`] when no cluster exists); level 3
//!   additionally caps generate length. Entering level ≥ 1 opens a
//!   brownout span ([`crate::metrics::ReliabilityStats::brownout_spans`]);
//!   returning to level 0 closes it and accumulates `brownout_secs`.
//! * **Per-arrival gauges**: every turn samples
//!   [`crate::runtime::Backend::queue_depth`] into
//!   [`crate::metrics::LaneTimes`] (`depth_peak` / `mean_depth`), and every
//!   disposition lands in [`super::ServeReport::outcomes`] plus
//!   [`crate::metrics::ShedStats`] (admitted / shed-by-reason).
//!
//! The default [`super::OverloadConfig`] is fully inert (closed loop, no
//! shedding, no brownout), preserving the closed-loop semantics of every
//! pre-overload serving path bit for bit.
//!
//! # Latency accounting
//!
//! Each prep component is timed where it executes and charged to its own
//! query; LLM-lane stages are charged from the lane-side
//! [`crate::runtime::CallTiming`] (queue seconds — the query really did
//! wait behind earlier lane work, possibly another stream's — plus
//! execution span); the eagerly submitted encode is charged its measured
//! *stall* at the query's turn, and a lookup that blocked on another
//! stream's in-flight install of the same representative is charged that
//! stall in PFTT (it truly waited, even though the prefill itself was paid
//! elsewhere). A host-tier hit is charged its promotion copy in PFTT and
//! `llm_time` but never in `shared_prefill_time` — the tier's win is
//! exactly that pot's shrinkage at equal answers. The per-query PFTT/TTFT
//! (and their hit/miss split) therefore
//! mean exactly what they meant under serial serving; the pipeline win
//! surfaces in `BatchMetrics::wall_time` / `overlap_time` / per-lane
//! `lane_llm` / `lane_gnn`, and the sharing win in
//! `BatchMetrics::shared_hits` / `dedup_bytes_saved`.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::cache::{CacheStats, KvCacheManager, LockStats, Lookup, RepKey,
                   SharedKvCache, TieredOut};
use crate::data::{Dataset, Query};
use crate::embed::sq_dist;
use crate::graph::{Subgraph, TextualGraph};
use crate::metrics::{LaneTimes, QueryLatency, ReliabilityStats, Timer};
use crate::retrieval::{GraphFeatures, Retriever};
use crate::runtime::{pack_subgraph, BackendError, CallTiming, KvHandle, Lane,
                     PackedSubgraph, PendingEncode, PendingExtend,
                     PendingGenerate, PendingPrefill};

use super::session::PreparedQuestion;
use super::{argmax, ArrivalPlan, Coordinator, QueryOutcome, ServeReport,
            ShedReason};

/// One open cluster of the stream. Deliberately small — a centroid, a
/// member count, and the frozen representative subgraph (node/edge id
/// sets) — because cluster metadata outlives the KV budget: the
/// [`crate::cache::CachePolicy`] bounds resident KV bytes, not this state.
/// An evicted representative is re-verbalized from `rep` on its next miss
/// rather than keeping a padded max_seq token vector per cluster alive
/// forever. Cold clusters are reclaimed by the TTL sweep (module docs)
/// when `ServeConfig::cluster_ttl` is set.
struct OnlineCluster {
    /// running mean of member embeddings.
    centroid: Vec<f32>,
    members: usize,
    /// representative subgraph, frozen when the cluster opened.
    rep: Subgraph,
    /// real prefix length of `rep`'s verbalization (stable: the
    /// verbalizer and tokenizer are deterministic over a frozen `rep`).
    plen: usize,
    /// arrival index of the query that most recently opened/joined this
    /// cluster (drives the TTL sweep).
    last_used: u64,
    /// TTL-expired: the centroid no longer participates in matching and
    /// the KV entry has been released. The slot stays so ids are stable.
    expired: bool,
}

/// Content identity of a frozen representative: what makes it "the same"
/// representative in another stream. The verbalizer and tokenizer are
/// deterministic over (graph, subgraph), so equal keys imply a bit-identical
/// prefilled prefix on the same backbone.
fn rep_key(backbone: &str, graph: &TextualGraph, rep: &Subgraph) -> RepKey {
    RepKey::of_parts(
        [backbone, graph.name.as_str()],
        rep.nodes
            .iter()
            .map(|&n| n as u64)
            .chain(std::iter::once(u64::MAX)) // node/edge boundary
            .chain(rep.edges.iter().map(|&e| e as u64)),
    )
}

/// The encode stage of a prepped query: already in flight on the GNN lane
/// (depth ≥ 2), or still packed host-side (depth 1 submits at the turn).
enum EncStage {
    Pending(PendingEncode),
    Packed(PackedSubgraph),
}

/// Engine-free host prep for one arriving query, runnable in the shadow of
/// an in-flight engine call: retrieval, GNN input packing, question
/// tokenization — plus, at depth ≥ 2, the eagerly submitted encode.
/// Nothing here depends on cluster state, which is exactly why it can run
/// ahead of the query's turn.
struct PreppedQuery<'q> {
    q: &'q Query,
    sg: Subgraph,
    enc: EncStage,
    question: PreparedQuestion,
    retrieval_secs: f64,
    pack_secs: f64,
}

/// The decoupled decode stage: everything needed to finalize query *i*
/// while query *i+1* runs. Holds the query's cache pin (released at
/// finalize) and its private prefix+question KV handle — plus enough
/// context (the tokenized question, the frozen prefix length, the query's
/// wall timer) to rebuild that KV from the representative entry if the
/// lane dies under the in-flight generate.
struct InflightDecode<'q> {
    q: &'q Query,
    cid: usize,
    sg: Subgraph,
    hit: bool,
    kv_q: KvHandle,
    first: i32,
    pending: PendingGenerate,
    /// tokenized question, kept for decode-stage recovery (re-extend).
    question: PreparedQuestion,
    /// frozen representative prefix length (mirrors the cluster's).
    plen: usize,
    /// wall timer from the query's turn (bounds decode-stage recovery
    /// against `ServeConfig::deadline`).
    t_query: Timer,
    /// this query needed at least one recovery action before its decode.
    degraded: bool,
    /// composed component times up to the first token
    prompt_ready: f64,
    pftt: f64,
    /// generate-length cap from the brownout ladder (level 3);
    /// `usize::MAX` when uncapped. Applied at finalize, before decode.
    gen_cap: usize,
}

/// Bounded recovery budget for one backend stage of one query. `admit`
/// spends one attempt on a failure while the error is retryable, attempts
/// remain ([`super::ServeConfig::max_retries`]) and the query is still
/// inside its deadline ([`super::ServeConfig::deadline`]); the first
/// inadmissible failure propagates and fails the stream.
struct RetryBudget {
    attempts: u32,
    max: u32,
    deadline: Option<std::time::Duration>,
}

impl RetryBudget {
    fn new(cfg: &super::ServeConfig) -> RetryBudget {
        RetryBudget { attempts: 0, max: cfg.max_retries, deadline: cfg.deadline }
    }

    /// `Ok(())` means "retry now"; `Err` means the failure is terminal for
    /// this stream (non-retryable error, budget exhausted, or the query
    /// ran past its deadline). Borrows the error so the caller can still
    /// branch on its kind after admission; the clone is terminal-path only.
    ///
    /// An [`Overloaded`](BackendError::Overloaded) failure is retryable
    /// *only with backoff* (the runtime taxonomy's contract): admission
    /// sleeps a capped exponential delay before returning, so a retry
    /// storm cannot hammer a full bounded queue or an open breaker.
    fn admit(&mut self, e: &BackendError, t_query: &Timer) -> anyhow::Result<()> {
        let past_deadline =
            self.deadline.is_some_and(|d| t_query.secs() > d.as_secs_f64());
        if !e.is_retryable() || self.attempts >= self.max || past_deadline {
            return Err(e.clone().into());
        }
        self.attempts += 1;
        if e.is_overloaded() {
            const BACKOFF_BASE: std::time::Duration =
                std::time::Duration::from_micros(500);
            std::thread::sleep(BACKOFF_BASE * (1u32 << self.attempts.min(6)));
        }
        Ok(())
    }
}

/// How one stream of a [`Coordinator::serve_online_multi`] fleet ended.
#[derive(Debug, Clone)]
pub enum StreamOutcome {
    /// The stream completed; its report sits at this index of
    /// [`MultiStreamReport::streams`].
    Completed(usize),
    /// The stream failed with this (display-formatted) error chain. The
    /// other streams' reports are unaffected — partial fleet results
    /// survive in [`MultiStreamReport::streams`].
    Failed(String),
}

/// Result of serving N concurrent query streams against one shared
/// representative pool and one backend ([`Coordinator::serve_online_multi`]).
#[derive(Debug, Default)]
pub struct MultiStreamReport {
    /// Per-stream reports for the streams that completed. Each carries its
    /// own hit/miss TTFT split and its own per-stream [`CacheStats`] view
    /// (`cache`). On success this is one report per input stream, in
    /// stream order; under partial failure
    /// ([`Coordinator::serve_online_multi_partial`]) use
    /// [`outcomes`](Self::outcomes) to map input streams to reports.
    pub streams: Vec<ServeReport>,
    /// Per-stream end states, in input-stream order: completed streams
    /// point into [`streams`](Self::streams), failed ones carry their
    /// error — one stream's failure does not discard the rest of the
    /// fleet's results.
    pub outcomes: Vec<StreamOutcome>,
    /// Fleet-level fault-tolerance counters: the completed streams'
    /// retry/quarantine/deadline counters summed, plus the lane
    /// supervisor's restart delta across the whole run (counted once —
    /// a restart is a backend-global event, not a per-stream one).
    pub reliability: ReliabilityStats,
    /// Pool-level cache totals across every stream: `prefills` here is the
    /// number of representative prefills the whole fleet paid (equal to
    /// distinct representative keys when the budget is ample).
    pub shared: CacheStats,
    /// Shared-pool lock contention counters (shard the map when `contended`
    /// becomes a meaningful fraction of `acquisitions`).
    pub lock: LockStats,
    /// Wall-clock seconds from first worker spawn to last join + pool drain.
    pub wall_time: f64,
}

impl MultiStreamReport {
    pub fn total_queries(&self) -> usize {
        self.streams.iter().map(|r| r.metrics.per_query.len()).sum()
    }

    /// Fleet throughput: queries served per wall-clock second.
    pub fn qps(&self) -> f64 {
        if self.wall_time > 0.0 {
            self.total_queries() as f64 / self.wall_time
        } else {
            0.0
        }
    }

    /// Cross-stream warm hits (an entry one stream installed, another hit).
    pub fn shared_hits(&self) -> u64 {
        self.shared.shared_hits
    }

    /// Prefill bytes one stream avoided because another had already paid.
    pub fn dedup_bytes_saved(&self) -> u64 {
        self.shared.dedup_bytes_saved
    }

    /// Streams that failed (see [`MultiStreamReport::outcomes`]).
    pub fn failed_streams(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| matches!(o, StreamOutcome::Failed(_)))
            .count()
    }
}

impl<'e> Coordinator<'e> {
    /// Serve a stream of queries online over a private cache pool — the
    /// single-stream path. `query_stream` is consumed in arrival order;
    /// each query is matched against the clusters opened by the queries
    /// before it — nothing about the batch is known up front.
    ///
    /// The report's `per_query` entries carry `cache_hit` so
    /// [`crate::metrics::BatchMetrics::ttft_hit_ms`] /
    /// [`crate::metrics::BatchMetrics::ttft_miss_ms`] split cleanly — the
    /// split stays exact under pipelining because every latency is composed
    /// from the query's own component times (module docs).
    pub fn serve_online<'q, I>(&self, ds: &Dataset, query_stream: I,
                               retriever: &dyn Retriever) -> anyhow::Result<ServeReport>
    where
        I: IntoIterator<Item = &'q Query>,
    {
        let mut cache: KvCacheManager<KvHandle> = KvCacheManager::new(self.cfg.cache);
        self.serve_online_with_cache(ds, query_stream, retriever, &mut cache)
    }

    /// Serve N query streams concurrently — one worker thread per stream,
    /// all sharing this coordinator's backend and ONE [`SharedKvCache`]
    /// pool, so identical representatives across streams are prefilled once
    /// and reused everywhere (module docs: cross-stream sharing).
    ///
    /// Fails if any stream fails (each stream surfaces its own error — a
    /// dead backend lane errors every stream instead of hanging any); the
    /// pool is drained back to the backend either way. To keep the
    /// completed streams' results when one stream fails, use
    /// [`serve_online_multi_partial`]; for per-stream error inspection
    /// drive [`serve_online_with_cache`] over
    /// [`KvCacheManager::shared_view`]s directly.
    ///
    /// [`serve_online_multi_partial`]: Coordinator::serve_online_multi_partial
    /// [`serve_online_with_cache`]: Coordinator::serve_online_with_cache
    pub fn serve_online_multi<'q>(&self, ds: &Dataset, streams: &[Vec<&'q Query>],
                                  retriever: &dyn Retriever)
                                  -> anyhow::Result<MultiStreamReport> {
        let report = self.serve_online_multi_partial(ds, streams, retriever)?;
        let n = report.outcomes.len();
        let mut failures = report.outcomes.iter().filter_map(|o| match o {
            StreamOutcome::Failed(msg) => Some(msg.as_str()),
            StreamOutcome::Completed(_) => None,
        });
        if let Some(first) = failures.next() {
            let failed = 1 + failures.count();
            return Err(anyhow::anyhow!("{first}")
                .context(format!("{failed}/{n} online streams failed")));
        }
        Ok(report)
    }

    /// Like [`serve_online_multi`], but one stream's failure never
    /// discards the fleet: completed streams keep their reports and
    /// metrics, failed streams surface in
    /// [`MultiStreamReport::outcomes`], and the call itself only errors on
    /// setup failures (empty input, warmup) that would fail every stream
    /// identically.
    ///
    /// [`serve_online_multi`]: Coordinator::serve_online_multi
    pub fn serve_online_multi_partial<'q>(&self, ds: &Dataset,
                                          streams: &[Vec<&'q Query>],
                                          retriever: &dyn Retriever)
                                          -> anyhow::Result<MultiStreamReport> {
        anyhow::ensure!(!streams.is_empty(), "serve_online_multi needs >= 1 stream");
        // compile/load once on the caller's thread so the workers race on
        // serving, not on warmup.
        self.engine.warmup(&self.cfg.backbone)?;
        self.engine.warmup(&self.gnn_module(retriever))?;
        let pool: Arc<SharedKvCache<KvHandle>> =
            Arc::new(SharedKvCache::new(self.cfg.cache));
        // one O(graph) feature build shared by every worker, outside the
        // measured fleet wall time — S-1 redundant rebuilds would otherwise
        // deflate the qps/wall rows the serving bench tracks.
        let feats = GraphFeatures::build(&ds.graph);
        let stats0 = self.engine.stats();
        let restarts0 = stats0.as_ref().map(|s| s.lane_restarts).unwrap_or(0);
        let trips0 = stats0.map(|s| s.breaker_trips).unwrap_or(0);
        let t_wall = Timer::start();
        let joined: Vec<anyhow::Result<ServeReport>> = std::thread::scope(|scope| {
            let handles: Vec<_> = streams
                .iter()
                .enumerate()
                .map(|(si, qs)| {
                    let pool = Arc::clone(&pool);
                    let feats = &feats;
                    // decorrelate each stream's arrival schedule (a no-op
                    // for the default closed plan).
                    let plan = self.cfg.overload.arrivals.stream_plan(si);
                    scope.spawn(move || {
                        let mut view = KvCacheManager::shared_view(&pool);
                        self.serve_online_inner(ds, qs.iter().copied(),
                                                retriever, &mut view, feats,
                                                plan)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(anyhow::anyhow!("online stream worker panicked"))
                    })
                })
                .collect()
        });
        // all workers have joined: the pool is quiescent — drain every
        // resident entry (and deferred handles) back to the backend before
        // reporting, whether the streams succeeded or not.
        self.engine.release_many(pool.drain_all());
        let wall_time = t_wall.secs();
        // the supervisor's restart counter (and the breaker's trip counter)
        // is backend-global: delta each once around the whole fleet rather
        // than per overlapping stream.
        let stats1 = self.engine.stats();
        let restarts = stats1.as_ref()
            .map(|s| s.lane_restarts)
            .unwrap_or(restarts0)
            .saturating_sub(restarts0);
        let trips = stats1
            .map(|s| s.breaker_trips)
            .unwrap_or(trips0)
            .saturating_sub(trips0);

        let mut report = MultiStreamReport {
            shared: pool.stats(),
            lock: pool.lock_stats(),
            wall_time,
            ..MultiStreamReport::default()
        };
        for out in joined {
            match out {
                Ok(r) => {
                    report.reliability.merge(&r.metrics.reliability);
                    report.outcomes.push(StreamOutcome::Completed(report.streams.len()));
                    report.streams.push(r);
                }
                Err(e) => report.outcomes.push(StreamOutcome::Failed(format!("{e:#}"))),
            }
        }
        report.reliability.restarts = restarts;
        report.reliability.breaker_trips = trips;
        Ok(report)
    }

    /// The depth-k online scheduler over a caller-supplied cache view: the
    /// building block behind [`serve_online`] (private view) and
    /// [`serve_online_multi`] (one shared view per worker thread). On error
    /// the view keeps this stream's pins/reservations until it is dropped —
    /// drop it rather than reusing it after a failure.
    ///
    /// [`serve_online`]: Coordinator::serve_online
    /// [`serve_online_multi`]: Coordinator::serve_online_multi
    pub fn serve_online_with_cache<'q, I>(&self, ds: &Dataset, query_stream: I,
                                          retriever: &dyn Retriever,
                                          cache: &mut KvCacheManager<KvHandle>)
                                          -> anyhow::Result<ServeReport>
    where
        I: IntoIterator<Item = &'q Query>,
    {
        let feats = GraphFeatures::build(&ds.graph);
        // restart accounting by supervisor-counter delta: nothing on the
        // serving hot path, and measured outside the run's wall timer. The
        // counter is backend-global, so when several streams share one
        // backend each sees the fleet's restarts (documented on
        // `ReliabilityStats::restarts`).
        let stats0 = self.engine.stats();
        let restarts0 = stats0.as_ref().map(|s| s.lane_restarts).unwrap_or(0);
        let trips0 = stats0.map(|s| s.breaker_trips).unwrap_or(0);
        let mut report =
            self.serve_online_inner(ds, query_stream, retriever, cache, &feats,
                                    self.cfg.overload.arrivals)?;
        let stats1 = self.engine.stats();
        let restarts1 =
            stats1.as_ref().map(|s| s.lane_restarts).unwrap_or(restarts0);
        let trips1 = stats1.map(|s| s.breaker_trips).unwrap_or(trips0);
        report.metrics.reliability.restarts = restarts1.saturating_sub(restarts0);
        report.metrics.reliability.breaker_trips = trips1.saturating_sub(trips0);
        Ok(report)
    }

    /// Invalidate every cache entry whose device handle belongs to a dead
    /// lane incarnation ([`crate::runtime::Backend::kv_current`]) and hand
    /// the stale handles back to the backend (pure bookkeeping — the
    /// device state died with the worker). Returns how many entries the
    /// sweep quarantined.
    fn quarantine_dead(&self, cache: &mut KvCacheManager<KvHandle>) -> u64 {
        let before = cache.stats().quarantined;
        let dead = cache.quarantine_stale(|h| !self.engine.kv_current(h));
        self.engine.release_many(dead);
        cache.stats().quarantined.saturating_sub(before)
    }

    /// Carry out a tier-aware install's outputs: release the dead handles
    /// on the backend, and demote each budget victim to the host tier
    /// ([`crate::runtime::Backend::demote_kv`] +
    /// [`KvCacheManager::admit_host`]), releasing any LRU host-tier deaths
    /// the admission forces and carrying any disk-tier spills to the
    /// archive ([`crate::runtime::Backend::archive_kv`] +
    /// [`KvCacheManager::admit_disk`]). A backend without a host tier (or
    /// a failed copy) has already released the device handle inside
    /// `demote_kv` — the victim simply dies, which is exactly the pre-tier
    /// behaviour; likewise a failed serialization inside `archive_kv`
    /// consumes the host handle and the spill is simply dropped.
    fn finish_install(&self, cache: &mut KvCacheManager<KvHandle>,
                      out: TieredOut<KvHandle>) {
        self.engine.release_many(out.release);
        for d in out.demote {
            if let Ok(host) = self.engine.demote_kv(d.handle) {
                let adm = cache.admit_host(d.slot, host);
                self.engine.release_many(adm.release);
                for a in adm.archive {
                    if let Ok(bytes) = self.engine.archive_kv(a.handle) {
                        cache.admit_disk(a.slot, &bytes);
                    }
                }
            }
        }
    }

    /// Blocking promotion of a checked-out host copy on a recovery path
    /// (the fast path overlaps the copy in its ticket shadow instead — see
    /// step 4 of the scheduler). `Some(t)` means the entry is
    /// device-resident again with this stream's pin held, and `t` is the
    /// promotion's lane timing for the caller's accounting. `None` means
    /// no checkout existed or the copy could not be promoted — the host
    /// handle has been released, and the caller (still holding the key's
    /// install reservation) repays the prefill instead.
    fn promote_on_recovery(&self, cache: &mut KvCacheManager<KvHandle>,
                           cid: usize) -> Option<CallTiming> {
        let (host, bytes) = cache.take_promotion(cid)?;
        match self.engine.promote_kv(&host) {
            Ok((kv, t)) => {
                let out = cache.install_promoted(cid, kv, bytes);
                self.finish_install(cache, out);
                Some(t)
            }
            Err(_) => {
                // the promote ticket only borrows the host copy, so after
                // a failure it is still ours to free.
                self.engine.release(host);
                None
            }
        }
    }

    /// Blocking recall of a checked-out archive payload on a recovery path:
    /// rebuild the host copy ([`crate::runtime::Backend::recall_kv`]), then
    /// walk it up exactly like a promotion. `Some(t)` means the entry is
    /// device-resident again with this stream's pin held. `None` means no
    /// checkout existed or the walk failed — the disk record was consumed
    /// at checkout, any minted host copy has been released, and the caller
    /// (still holding the key's install reservation) repays the prefill.
    fn recall_on_recovery(&self, cache: &mut KvCacheManager<KvHandle>,
                          cid: usize) -> Option<CallTiming> {
        let (payload, bytes) = cache.take_recall(cid)?;
        let host = self.engine.recall_kv(&payload).ok()?;
        match self.engine.promote_kv(&host) {
            Ok((kv, t)) => {
                let out = cache.install_recalled(cid, kv, bytes);
                self.finish_install(cache, out);
                Some(t)
            }
            Err(_) => {
                self.engine.release(host);
                None
            }
        }
    }

    /// [`serve_online_with_cache`] over pre-built retrieval features (so
    /// the multi-stream path builds them once for the whole fleet) and an
    /// explicit arrival plan (so each stream of a fleet can carry its own
    /// decorrelated seed — see [`ArrivalPlan::stream_plan`]).
    ///
    /// [`serve_online_with_cache`]: Coordinator::serve_online_with_cache
    fn serve_online_inner<'q, I>(&self, ds: &Dataset, query_stream: I,
                                 retriever: &dyn Retriever,
                                 cache: &mut KvCacheManager<KvHandle>,
                                 feats: &GraphFeatures, plan: ArrivalPlan)
                                 -> anyhow::Result<ServeReport>
    where
        I: IntoIterator<Item = &'q Query>,
    {
        self.engine.warmup(&self.cfg.backbone)?;
        let gnn = self.gnn_module(retriever);
        self.engine.warmup(&gnn)?;
        let c = *self.store.constants();
        let session = self.session();
        let entry_bytes = self.kv_entry_bytes()?;
        let threshold = self.cfg.online_threshold;
        let depth = self.cfg.pipeline_depth.max(1);
        let eager_encode = depth >= 2;

        // Overload plane (module docs: admission control & the brownout
        // ladder). All state is per-stream; the virtual backlog and the
        // ladder's backlog-driven levels are pure functions of the arrival
        // plan and the service estimate, which is what makes the shed set
        // reproducible across same-seed runs.
        let overload = self.cfg.overload;
        let shed_on = overload.shed;
        let headroom = if overload.headroom > 0.0 { overload.headroom } else { 1.0 };
        let mut est = overload.initial_estimate.as_secs_f64();
        let est_fixed = est > 0.0;
        let mut arrivals = plan.clock();
        // virtual single-server backlog: when the server frees up, in
        // seconds of stream time.
        let mut backlog_end = 0.0f64;
        let mut brown_level = 0usize;
        let mut brown_t: Option<Timer> = None;

        // Host-only prep, shared by the pipeline's lookahead and the
        // first/fallback (non-overlapped) cases. Every component is timed
        // here so it gets charged to its own query wherever it runs. At
        // depth >= 2 the encode ships to the GNN lane immediately — the
        // overlap the lane split exists for.
        let prep = |q: &'q Query| -> anyhow::Result<PreppedQuery<'q>> {
            let t = Timer::start();
            let sg = retriever.retrieve(&ds.graph, feats, &q.text);
            let retrieval_secs = t.secs();
            let t = Timer::start();
            let packed = pack_subgraph(&ds.graph, feats, &sg, c.n_max, c.feat_dim);
            let pack_secs = t.secs();
            let question = session.prepare_question(&q.text);
            let enc = if eager_encode {
                match self.engine.submit_encode(
                    &gnn, packed.x, packed.adj, packed.mask) {
                    Ok(p) => EncStage::Pending(p),
                    // a refused eager submit (bounded GNN queue full /
                    // breaker open) is not an error: fall back to
                    // submitting at the query's own turn — exactly the
                    // depth-1 behaviour — where the retry budget applies.
                    // (The packed inputs moved into the attempt; re-pack.)
                    Err(e) if e.is_overloaded() => EncStage::Packed(
                        pack_subgraph(&ds.graph, feats, &sg, c.n_max, c.feat_dim)),
                    Err(e) => return Err(e.into()),
                }
            } else {
                EncStage::Packed(packed)
            };
            Ok(PreppedQuery { q, sg, enc, question, retrieval_secs, pack_secs })
        };

        // Refill the prep queue up to `limit` (the full depth k, or the
        // brownout-clamped effective depth). `in_shadow` marks calls made
        // under an in-flight engine ticket, whose prep time counts toward
        // `overlap_time` (the work itself is always charged to its query).
        let top_up = |queue: &mut VecDeque<PreppedQuery<'q>>,
                      stream: &mut dyn Iterator<Item = &'q Query>,
                      overlap_time: &mut f64,
                      in_shadow: bool,
                      limit: usize|
         -> anyhow::Result<()> {
            while queue.len() < limit.max(1) {
                match stream.next() {
                    Some(q) => {
                        let t = Timer::start();
                        queue.push_back(prep(q)?);
                        if in_shadow {
                            *overlap_time += t.secs();
                        }
                    }
                    None => break,
                }
            }
            Ok(())
        };

        let mut clusters: Vec<OnlineCluster> = Vec::new();
        let mut report = ServeReport::default();
        let mut rel = ReliabilityStats::default();
        let mut llm_time = 0.0;
        let mut prefill_total = 0.0;
        let mut overlap_time = 0.0;
        let mut lane_llm = LaneTimes::default();
        let mut lane_gnn = LaneTimes::default();
        let mut expired_clusters = 0usize;
        let t_wall = Timer::start();

        // Finalize one decoupled decode: wait the generate, detokenize,
        // compose the record, release the private KV, drop the pin. A
        // transient generate failure is resubmitted against the same
        // private KV; a dead lane took that KV with it, so the decode is
        // rebuilt — quarantine stale entries, re-acquire (or repay) the
        // representative, re-extend (bit-identical first token under a
        // deterministic backend), then generate again.
        let finalize = |mut dec: InflightDecode<'q>,
                        clusters: &[OnlineCluster],
                        cache: &mut KvCacheManager<KvHandle>,
                        report: &mut ServeReport,
                        llm_time: &mut f64,
                        prefill_total: &mut f64,
                        lane_llm: &mut LaneTimes,
                        rel: &mut ReliabilityStats|
         -> anyhow::Result<()> {
            let mut budget = RetryBudget::new(&self.cfg);
            let mut t_rec: Option<Timer> = None;
            let cur_len = (dec.plen + dec.question.qlen) as i32;
            let (gen, gen_t) = loop {
                match dec.pending.wait_timed() {
                    Ok(out) => break out,
                    Err(e) => {
                        budget.admit(&e, &dec.t_query)?;
                        rel.retries += 1;
                        dec.degraded = true;
                        t_rec.get_or_insert_with(Timer::start);
                        if e.is_lane_dead() {
                            // the private prefix+question KV died with the
                            // lane incarnation: the answer is recomputed,
                            // not lost.
                            rel.quarantined_entries += self.quarantine_dead(cache);
                            self.engine.release(dec.kv_q);
                            dec.kv_q = 'rebuild: loop {
                                // drop the (possibly orphaned) pin, then
                                // re-pin through a fresh lookup; on a miss
                                // the repay prefill retries in place — its
                                // install reservation must be fulfilled,
                                // never re-queried, or this stream would
                                // single-flight-block on itself.
                                cache.unpin(dec.cid);
                                let look = cache.lookup(dec.cid);
                                let mut resident = look.is_hit();
                                // a host-tier copy survived the lane death:
                                // promote it back up instead of repaying
                                // the prefill (blocking — recovery is off
                                // the fast path already).
                                match look {
                                    Lookup::MustPromote => {
                                        if let Some(t) =
                                            self.promote_on_recovery(cache, dec.cid)
                                        {
                                            lane_llm.add(&t);
                                            *llm_time += t.secs();
                                            resident = true;
                                        }
                                    }
                                    // an archived disk copy survived: recall
                                    // it through the host tier instead of
                                    // repaying the prefill.
                                    Lookup::MustRecall => {
                                        if let Some(t) =
                                            self.recall_on_recovery(cache, dec.cid)
                                        {
                                            lane_llm.add(&t);
                                            *llm_time += t.secs();
                                            resident = true;
                                        }
                                    }
                                    _ => {}
                                }
                                if !resident {
                                    let cl = &clusters[dec.cid];
                                    let (tokens, _plen) =
                                        session.prefix_tokens(&ds.graph, &cl.rep);
                                    let kv = loop {
                                        let pending = self.engine.submit_prefill(
                                            &self.cfg.backbone, &tokens,
                                            cl.plen as i32)?;
                                        match pending.wait_timed() {
                                            Ok((kv, _logits, t)) => {
                                                lane_llm.add(&t);
                                                *llm_time += t.secs();
                                                *prefill_total += t.secs();
                                                break kv;
                                            }
                                            Err(e2) => {
                                                budget.admit(&e2, &dec.t_query)?;
                                                rel.retries += 1;
                                                if e2.is_lane_dead() {
                                                    rel.quarantined_entries +=
                                                        self.quarantine_dead(cache);
                                                }
                                            }
                                        }
                                    };
                                    let out =
                                        cache.install_tiered(dec.cid, kv,
                                                             entry_bytes);
                                    self.finish_install(cache, out);
                                }
                                let pending_ext = cache
                                    .with_handle(dec.cid, |kv| {
                                        self.engine.submit_extend(
                                            &self.cfg.backbone, kv,
                                            dec.plen as i32,
                                            &dec.question.tokens,
                                            dec.question.qlen as i32)
                                    })
                                    .ok_or_else(|| anyhow::anyhow!(
                                        "online cluster cache missing during \
                                         decode recovery"))??;
                                match pending_ext.wait_timed() {
                                    Ok((kv_q, row, ext_t)) => {
                                        lane_llm.add(&ext_t);
                                        *llm_time += ext_t.secs();
                                        debug_assert_eq!(
                                            argmax(&row), dec.first,
                                            "recovered extend must reproduce \
                                             the first token");
                                        break 'rebuild kv_q;
                                    }
                                    Err(e2) => {
                                        budget.admit(&e2, &dec.t_query)?;
                                        rel.retries += 1;
                                        if e2.is_lane_dead() {
                                            rel.quarantined_entries +=
                                                self.quarantine_dead(cache);
                                        }
                                        // stale (or transient) again:
                                        // re-acquire from the top — the pin
                                        // dance stays balanced because the
                                        // loop re-enters at unpin.
                                    }
                                }
                            };
                        }
                        dec.pending = self.engine.submit_generate(
                            &self.cfg.backbone, &dec.kv_q, cur_len, dec.first)?;
                    }
                }
            };
            if let Some(t) = t_rec {
                rel.degraded_secs += t.secs();
            }
            if dec.degraded {
                rel.degraded_spans += 1;
            }
            lane_llm.add(&gen_t);
            // brownout level 3: serve a truncated answer rather than the
            // full decode (the cap is stamped at the query's turn, so a
            // recovery re-generate is capped identically).
            let mut gen = gen;
            gen.truncate(dec.gen_cap.max(1));
            let t_host = Timer::start();
            let predicted = session.decode_answer(dec.first, &gen);
            let result = session.result(dec.q, predicted, dec.cid, dec.sg);
            let ttft = dec.prompt_ready + dec.pftt;
            let rt = ttft + gen_t.secs() + t_host.secs();
            if self.cfg.deadline.is_some_and(|d| rt > d.as_secs_f64()) {
                rel.deadline_hits += 1;
            }
            *llm_time += gen_t.secs();
            report.metrics.per_query.push(QueryLatency {
                rt,
                ttft,
                pftt: dec.pftt,
                correct: result.correct,
                cache_hit: Some(dec.hit),
            });
            report.results.push(result);
            self.engine.release(dec.kv_q);
            cache.unpin(dec.cid);
            Ok(())
        };

        let mut stream = query_stream.into_iter();
        let mut queue: VecDeque<PreppedQuery<'q>> = VecDeque::new();
        // the opening fill has no shadow to ride: prep inline.
        top_up(&mut queue, &mut stream, &mut overlap_time, false, depth)?;
        let mut pending_decode: Option<InflightDecode<'q>> = None;
        let mut arrival: u64 = 0;

        'turns: while let Some(cur) = queue.pop_front() {
            let PreppedQuery { q, sg, enc, question, retrieval_secs, pack_secs } = cur;
            let now = arrival;
            arrival += 1;

            // -1) open-loop arrival + admission control (module docs). The
            //     query "arrives" at its plan offset: an open plan holds
            //     service until that offset (host prep may have run ahead —
            //     the open system gates service, not prep). The virtual
            //     backlog then predicts its completion; with shedding on, a
            //     predicted deadline miss is shed before any engine work.
            let offset = arrivals.next_offset();
            if let Some(a) = offset {
                let lag = a.as_secs_f64() - t_wall.secs();
                if lag > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(lag));
                }
            }
            // a closed plan admits the moment the server frees up: zero
            // virtual wait, pure service-time admission.
            let arrive = offset.map(|a| a.as_secs_f64()).unwrap_or(backlog_end);
            let start_est = backlog_end.max(arrive);
            let wait_est = start_est - arrive;
            let predicted = wait_est + est;

            // brownout level for this turn: backlog-driven steps, bumped to
            // >= 1 by the live queue-depth / rolling-p95 watermarks.
            let mut level = 0usize;
            if let Some(b) = overload.brownout {
                level = b.backlog_steps
                    .iter()
                    .filter(|s| !s.is_zero() && wait_est >= s.as_secs_f64())
                    .count();
                if b.depth_watermark
                    .is_some_and(|w| w > 0
                        && self.engine.queue_depth(Lane::Llm) >= w)
                {
                    level = level.max(1);
                }
                if let Some(w) = b.p95_watermark {
                    let pq = &report.metrics.per_query;
                    let tail = &pq[pq.len().saturating_sub(32)..];
                    if !tail.is_empty() {
                        let mut rts: Vec<f64> =
                            tail.iter().map(|x| x.rt).collect();
                        rts.sort_by(|a, b| a.partial_cmp(b)
                            .unwrap_or(std::cmp::Ordering::Equal));
                        let p95 = rts[((rts.len() - 1) as f64 * 0.95) as usize];
                        if p95 >= w.as_secs_f64() {
                            level = level.max(1);
                        }
                    }
                }
            }
            if level > 0 && brown_level == 0 {
                rel.brownout_spans += 1;
                brown_t = Some(Timer::start());
            } else if level == 0 {
                if let Some(t) = brown_t.take() {
                    rel.brownout_secs += t.secs();
                }
            }
            brown_level = level;
            // level 1+: clamp the lookahead to serial scheduling — under
            // overload, prepping deep only adds queueing.
            let eff_depth = if level >= 1 { 1 } else { depth };

            // per-arrival queue-depth gauges (peak/mean surface on the
            // lane splits).
            lane_llm.sample_depth(self.engine.queue_depth(Lane::Llm));
            lane_gnn.sample_depth(self.engine.queue_depth(Lane::Gnn));

            if shed_on
                && self.cfg.deadline
                    .is_some_and(|d| predicted >= d.as_secs_f64() * headroom)
            {
                rel.shed.shed_deadline += 1;
                report.outcomes.push(QueryOutcome::Shed {
                    id: q.id,
                    reason: ShedReason::Deadline,
                });
                // a shed arrival never occupies the virtual server.
                top_up(&mut queue, &mut stream, &mut overlap_time, false,
                       eff_depth)?;
                continue 'turns;
            }
            backlog_end = start_est + est;

            // wall clock for this query's turn: bounds recovery against the
            // configured deadline. `degraded` flips on the first recovery
            // action and rides into the decode stage, where the span is
            // counted once per query.
            let t_query = Timer::start();
            let mut degraded = false;

            // 0) TTL sweep: expire clusters whose centroid went cold, and
            //    release their KV entries. A pinned entry belongs to an
            //    in-flight query (extend or decoupled decode) — of THIS
            //    stream or any other sharing the pool — skip it, however
            //    stale; it is reconsidered once unpinned. (Even if a pin
            //    landed between the check and the release, the release
            //    itself defers past pins — see the cache module docs.)
            if let Some(ttl) = self.cfg.cluster_ttl {
                let mut reclaimed: Vec<KvHandle> = Vec::new();
                for (cid, cl) in clusters.iter_mut().enumerate() {
                    if cl.expired || now.saturating_sub(cl.last_used) <= ttl {
                        continue;
                    }
                    if cache.pin_count(cid) > 0 {
                        continue; // in-flight representative survives expiry
                    }
                    cl.expired = true;
                    expired_clusters += 1;
                    // private stream: release the entry now. Shared pool:
                    // only drop this stream's binding — the same content
                    // may be another stream's warm hit, and its pool-LRU
                    // recency (not one stream's cluster staleness) governs
                    // reclamation under the byte budget.
                    reclaimed.extend(cache.expire(cid));
                }
                self.engine.release_many(reclaimed);
            }

            // 1) retrieval/pack/tokenize already ran at prep time (charged
            //    below, wherever they executed).
            // 2) GNN embedding + centroid assignment. The query is charged
            //    the *stall* it spends blocked on its embedding here: under
            //    eager submission the encode ran in the shadow of earlier
            //    LLM work and the stall is ~0; at depth 1 (submit + wait
            //    inline) the stall is the full queue + device time, exactly
            //    the serial accounting.
            let mut budget = RetryBudget::new(&self.cfg);
            let mut t_rec: Option<Timer> = None;
            // submits draw on the same budget as wait failures: a refused
            // submission (bounded queue full / breaker open) retries with
            // backoff instead of instantly erroring the stream.
            let t_stall = Timer::start();
            let mut pending_enc = match enc {
                EncStage::Pending(p) => p,
                EncStage::Packed(mut packed) => loop {
                    match self.engine.submit_encode(
                        &gnn, packed.x, packed.adj, packed.mask) {
                        Ok(p) => break p,
                        Err(e) => {
                            budget.admit(&e, &t_query)?;
                            rel.retries += 1;
                            degraded = true;
                            t_rec.get_or_insert_with(Timer::start);
                            packed = pack_subgraph(&ds.graph, feats, &sg,
                                                   c.n_max, c.feat_dim);
                        }
                    }
                },
            };
            let (emb, enc_t) = loop {
                match pending_enc.wait_timed() {
                    Ok(out) => break out,
                    // a lost encode has no KV to invalidate: re-pack from
                    // the retrieved subgraph and resubmit (the eager
                    // submission's inputs went down with the ticket).
                    Err(e) => {
                        budget.admit(&e, &t_query)?;
                        rel.retries += 1;
                        degraded = true;
                        t_rec.get_or_insert_with(Timer::start);
                        pending_enc = loop {
                            let packed = pack_subgraph(&ds.graph, feats, &sg,
                                                       c.n_max, c.feat_dim);
                            match self.engine.submit_encode(
                                &gnn, packed.x, packed.adj, packed.mask) {
                                Ok(p) => break p,
                                Err(e2) => {
                                    budget.admit(&e2, &t_query)?;
                                    rel.retries += 1;
                                }
                            }
                        };
                    }
                }
            };
            if let Some(t) = t_rec {
                rel.degraded_secs += t.secs();
            }
            let enc_stall = t_stall.secs();
            lane_gnn.add(&enc_t);
            let t_scan = Timer::start();
            let nearest = clusters
                .iter()
                .enumerate()
                .filter(|(_, cl)| !cl.expired)
                .map(|(i, cl)| (i, sq_dist(&cl.centroid, &emb)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
            let joined = nearest.filter(|&(_, d)| d <= threshold).map(|(i, _)| i);
            // brownout level 2: suspend new-cluster opens. A query that
            // would open one instead joins the nearest live representative
            // regardless of the threshold — its answer comes from a prefix
            // it did not choose, so it is flagged degraded — rather than
            // paying a fresh prefill under overload. With no live cluster
            // to degrade to, the ladder's deepest resort is to shed.
            let joined = match joined {
                Some(cid) => Some(cid),
                None if level >= 2 => match nearest.map(|(i, _)| i) {
                    Some(cid) => {
                        degraded = true;
                        Some(cid)
                    }
                    None => {
                        rel.shed.shed_brownout += 1;
                        report.outcomes.push(QueryOutcome::Shed {
                            id: q.id,
                            reason: ShedReason::Brownout,
                        });
                        top_up(&mut queue, &mut stream, &mut overlap_time,
                               false, eff_depth)?;
                        continue 'turns;
                    }
                },
                None => None,
            };
            let assign_secs = pack_secs + enc_stall + t_scan.secs();

            // 3) open a new cluster if nothing was close enough. The prefix
            //    prompt is built here (prompt-construction time), frozen for
            //    the cluster's lifetime; the padded token vector itself is
            //    NOT retained — see `OnlineCluster`. A fresh cluster is
            //    bound to its representative's content key so another
            //    stream's identical representative shares the pool entry
            //    (a no-op on the private single-stream pool).
            let t_open = Timer::start();
            let mut fresh_tokens: Option<Vec<i32>> = None;
            let cid = match joined {
                Some(cid) => {
                    let cl = &mut clusters[cid];
                    cl.members += 1;
                    cl.last_used = now;
                    let n = cl.members as f32;
                    for (ci, ei) in cl.centroid.iter_mut().zip(&emb) {
                        *ci += (ei - *ci) / n;
                    }
                    cid
                }
                None => {
                    let (tokens, plen) = session.prefix_tokens(&ds.graph, &sg);
                    fresh_tokens = Some(tokens);
                    clusters.push(OnlineCluster {
                        centroid: emb,
                        members: 1,
                        rep: sg.clone(),
                        plen,
                        last_used: now,
                        expired: false,
                    });
                    let cid = clusters.len() - 1;
                    cache.bind(cid, rep_key(&self.cfg.backbone, &ds.graph, &sg));
                    cid
                }
            };
            let open_secs = t_open.secs();

            // 4) warm-cache check. `lookup` records exactly one hit or miss
            //    (refreshing LRU / bytes_saved on a hit) and returns with a
            //    pin held — kept until this query's finalize (module docs,
            //    pin safety). A miss holds the key's install reservation:
            //    other streams racing on the same representative block in
            //    their lookup until our install below (single-flight). The
            //    stall a lookup spends blocked on ANOTHER stream's install
            //    is charged to this query's PFTT — it really waited, even
            //    though the prefill was paid elsewhere.
            let t_lookup = Timer::start();
            let look = cache.lookup(cid);
            let lookup_stall = t_lookup.secs();
            let hit = look.is_hit();
            let mut rebuild_secs = 0.0;
            let mut promote_secs = 0.0;
            // 4b) host-tier hit: the representative was demoted under the
            //    device budget, not destroyed. Copy it back up — the
            //    promotion is submitted first and the prep queue refills
            //    in its ticket shadow, so the stream is charged the copy
            //    latency minus the overlapped prep: strictly less than a
            //    repaid prefill under any sane copy bandwidth. A failed
            //    promotion releases the surviving host copy and falls
            //    through to the plain miss path below — the key's install
            //    reservation from the lookup is still held either way, so
            //    racing streams stay single-flight-blocked until the
            //    install (promoted or prefilled) fulfills it.
            let mut need_prefill = matches!(look, Lookup::MustInstall);
            if matches!(look, Lookup::MustPromote) {
                match cache.take_promotion(cid) {
                    Some((host, bytes)) => {
                        let submitted = self.engine.submit_promote(&host);
                        if submitted.is_ok() {
                            top_up(&mut queue, &mut stream, &mut overlap_time,
                                   true, eff_depth)?;
                        }
                        match submitted.and_then(|p| p.wait_timed()) {
                            Ok((kv, t)) => {
                                lane_llm.add(&t);
                                promote_secs = t.secs();
                                let out =
                                    cache.install_promoted(cid, kv, bytes);
                                self.finish_install(cache, out);
                            }
                            Err(e) => {
                                // the promote ticket only borrows the host
                                // copy: free it, then repay the prefill.
                                self.engine.release(host);
                                let mut budget = RetryBudget::new(&self.cfg);
                                budget.admit(&e, &t_query)?;
                                rel.retries += 1;
                                degraded = true;
                                if e.is_lane_dead() {
                                    rel.quarantined_entries +=
                                        self.quarantine_dead(cache);
                                }
                                need_prefill = true;
                            }
                        }
                    }
                    None => need_prefill = true,
                }
            }
            // 4c) disk-tier hit: the representative fell off the host tier
            //    into the archive. The record was consumed at checkout, so
            //    this is the one shot at it: rebuild the host copy from the
            //    payload, then ride the exact promote machinery above —
            //    same ticket-shadow prep overlap, same failure ladder. Any
            //    failure (recall, submit, or the copy itself) releases
            //    whatever tier-resident copy exists and falls through to
            //    the repaid prefill under the still-held reservation.
            if matches!(look, Lookup::MustRecall) {
                match cache.take_recall(cid) {
                    Some((payload, bytes)) => match self.engine.recall_kv(&payload) {
                        Ok(host) => {
                            let submitted = self.engine.submit_promote(&host);
                            if submitted.is_ok() {
                                top_up(&mut queue, &mut stream, &mut overlap_time,
                                       true, eff_depth)?;
                            }
                            match submitted.and_then(|p| p.wait_timed()) {
                                Ok((kv, t)) => {
                                    lane_llm.add(&t);
                                    promote_secs = t.secs();
                                    let out =
                                        cache.install_recalled(cid, kv, bytes);
                                    self.finish_install(cache, out);
                                }
                                Err(e) => {
                                    self.engine.release(host);
                                    let mut budget = RetryBudget::new(&self.cfg);
                                    budget.admit(&e, &t_query)?;
                                    rel.retries += 1;
                                    degraded = true;
                                    if e.is_lane_dead() {
                                        rel.quarantined_entries +=
                                            self.quarantine_dead(cache);
                                    }
                                    need_prefill = true;
                                }
                            }
                        }
                        Err(_) => need_prefill = true,
                    },
                    None => need_prefill = true,
                }
            }
            let mut prefill_secs = if !need_prefill {
                0.0
            } else {
                // an evicted-miss re-verbalizes the frozen representative.
                // That rebuild is prompt-construction (charged like a fresh
                // cluster's token build in step 3), NOT prefill — PFTT and
                // llm_time must mean the same engine work for both miss
                // flavors.
                let tokens = match fresh_tokens.take() {
                    Some(t) => t,
                    None => {
                        let t_rebuild = Timer::start();
                        let (t, plen) =
                            session.prefix_tokens(&ds.graph, &clusters[cid].rep);
                        debug_assert_eq!(plen, clusters[cid].plen,
                                         "frozen rep must re-verbalize identically");
                        rebuild_secs = t_rebuild.secs();
                        t
                    }
                };
                let mut budget = RetryBudget::new(&self.cfg);
                let mut t_rec: Option<Timer> = None;
                let mut pending: Option<PendingPrefill> = None;
                let mut first_submit = true;
                let got = loop {
                    let p = match pending.take() {
                        Some(p) => p,
                        None => match self.engine.submit_prefill(
                            &self.cfg.backbone, &tokens,
                            clusters[cid].plen as i32) {
                            Ok(p) => {
                                if first_submit {
                                    first_submit = false;
                                    // the prep queue refills in the
                                    // representative prefill's shadow — the
                                    // longest call a miss makes before
                                    // decode.
                                    top_up(&mut queue, &mut stream,
                                           &mut overlap_time, true,
                                           eff_depth)?;
                                }
                                p
                            }
                            // a refused submit (bounded queue full /
                            // breaker open) retries through the budget with
                            // backoff; terminal overload sheds below
                            // instead of erroring the stream.
                            Err(e) => match budget.admit(&e, &t_query) {
                                Ok(()) => {
                                    rel.retries += 1;
                                    degraded = true;
                                    t_rec.get_or_insert_with(Timer::start);
                                    continue;
                                }
                                Err(err) => {
                                    if shed_on && e.is_overloaded() {
                                        break None;
                                    }
                                    return Err(err);
                                }
                            },
                        },
                    };
                    match p.wait_timed() {
                        Ok((kv, _logits, t)) => break Some((kv, t)),
                        // retry in place: our install reservation from the
                        // missed lookup stays held across attempts, so
                        // waiting streams keep blocking until the install
                        // below fulfills it. Re-querying the cache here
                        // would single-flight-block on our own reservation.
                        Err(e) => match budget.admit(&e, &t_query) {
                            Ok(()) => {
                                rel.retries += 1;
                                degraded = true;
                                t_rec.get_or_insert_with(Timer::start);
                                if e.is_lane_dead() {
                                    rel.quarantined_entries +=
                                        self.quarantine_dead(cache);
                                }
                            }
                            Err(err) => {
                                if shed_on && e.is_overloaded() {
                                    break None;
                                }
                                return Err(err);
                            }
                        },
                    }
                };
                if let Some(t) = t_rec {
                    rel.degraded_secs += t.secs();
                }
                let Some((kv, prefill_t)) = got else {
                    // terminal overload: shed this query, keep the stream.
                    // Abort the install reservation the missed lookup took,
                    // so single-flight waiters on other streams wake and
                    // elect a new installer instead of blocking forever. A
                    // miss holds no pin (the pin comes with the install),
                    // so the reservation is the only state to unwind.
                    cache.abort_install(cid);
                    rel.shed.shed_overloaded += 1;
                    report.outcomes.push(QueryOutcome::Shed {
                        id: q.id,
                        reason: ShedReason::Overloaded,
                    });
                    top_up(&mut queue, &mut stream, &mut overlap_time, false,
                           eff_depth)?;
                    continue 'turns;
                };
                lane_llm.add(&prefill_t);
                let secs = prefill_t.secs();
                // admitted pinned, fulfilling the lookup's reservation
                // (waiting streams wake and hit); colder representatives
                // may fall out — never a pinned one, on any stream — and
                // fall to the host tier instead of dying when one is
                // configured.
                let out = cache.install_tiered(cid, kv, entry_bytes);
                self.finish_install(cache, out);
                secs
            };
            // (prefill_total is charged after the extend ladder below, so a
            // repaid prefill during extend recovery lands in the same pot.)

            // 5) extend against the resident representative cache, the
            //    handle borrowed under the pool lock (our pin keeps the
            //    entry alive; the lock makes handle access and submission
            //    atomic against other streams). In the extend's shadow:
            //    finalize the previous query's decoupled decode (its
            //    generate runs on the LLM lane just ahead of this extend)
            //    and refill the prep queue.
            let plen = clusters[cid].plen;
            debug_assert!(cache.pin_count(cid) >= 1,
                          "in-flight cluster must hold a pin across its tickets");
            // the missing-cache anyhow error stays terminal (outer `?`);
            // the backend error comes back typed so terminal overload can
            // shed instead of erroring the stream.
            let submit_ext = |cache: &mut KvCacheManager<KvHandle>|
             -> anyhow::Result<Result<PendingExtend, BackendError>> {
                cache
                    .with_handle(cid, |kv| {
                        self.engine.submit_extend(&self.cfg.backbone, kv, plen as i32,
                                                  &question.tokens,
                                                  question.qlen as i32)
                    })
                    .ok_or_else(|| anyhow::anyhow!("online cluster cache missing"))
            };
            let mut budget = RetryBudget::new(&self.cfg);
            let mut t_rec: Option<Timer> = None;
            let mut pending_ext: Option<PendingExtend> = None;
            let mut first_submit = true;
            let got = loop {
                let p = match pending_ext.take() {
                    Some(p) => p,
                    None => match submit_ext(cache)? {
                        Ok(p) => {
                            if first_submit {
                                first_submit = false;
                                // the previous query's decoupled decode
                                // finalizes (and the prep queue refills) in
                                // this extend's shadow.
                                if let Some(dec) = pending_decode.take() {
                                    finalize(dec, &clusters, &mut *cache,
                                             &mut report, &mut llm_time,
                                             &mut prefill_total, &mut lane_llm,
                                             &mut rel)?;
                                }
                                top_up(&mut queue, &mut stream,
                                       &mut overlap_time, true, eff_depth)?;
                            }
                            p
                        }
                        // a refused submit retries through the budget with
                        // backoff; terminal overload sheds below.
                        Err(e) => match budget.admit(&e, &t_query) {
                            Ok(()) => {
                                rel.retries += 1;
                                degraded = true;
                                t_rec.get_or_insert_with(Timer::start);
                                continue;
                            }
                            Err(err) => {
                                if shed_on && e.is_overloaded() {
                                    break None;
                                }
                                return Err(err);
                            }
                        },
                    },
                };
                match p.wait_timed() {
                    Ok(out) => break Some(out),
                    Err(e) => {
                        match budget.admit(&e, &t_query) {
                            Ok(()) => {}
                            Err(err) => {
                                if shed_on && e.is_overloaded() {
                                    break None;
                                }
                                return Err(err);
                            }
                        }
                        rel.retries += 1;
                        degraded = true;
                        t_rec.get_or_insert_with(Timer::start);
                        if e.is_lane_dead() {
                            rel.quarantined_entries += self.quarantine_dead(cache);
                            // the pinned representative may be gone with the
                            // lane incarnation: drop the (possibly orphaned)
                            // pin and re-pin through a fresh lookup, repaying
                            // the prefill on a miss. The repay retries in
                            // place — re-querying the cache while holding our
                            // own install reservation would single-flight-
                            // block this stream on itself.
                            cache.unpin(cid);
                            let look = cache.lookup(cid);
                            let mut resident = look.is_hit();
                            // a host- or disk-tier copy survived the lane
                            // death: walk it back up instead of repaying
                            // the prefill.
                            match look {
                                Lookup::MustPromote => {
                                    if let Some(t) =
                                        self.promote_on_recovery(cache, cid)
                                    {
                                        lane_llm.add(&t);
                                        promote_secs += t.secs();
                                        resident = true;
                                    }
                                }
                                Lookup::MustRecall => {
                                    if let Some(t) =
                                        self.recall_on_recovery(cache, cid)
                                    {
                                        lane_llm.add(&t);
                                        promote_secs += t.secs();
                                        resident = true;
                                    }
                                }
                                _ => {}
                            }
                            if !resident {
                                let t_rebuild = Timer::start();
                                let (tokens, _plen) = session
                                    .prefix_tokens(&ds.graph, &clusters[cid].rep);
                                rebuild_secs += t_rebuild.secs();
                                let kv = loop {
                                    let pending = self.engine.submit_prefill(
                                        &self.cfg.backbone, &tokens,
                                        clusters[cid].plen as i32)?;
                                    match pending.wait_timed() {
                                        Ok((kv, _logits, t)) => {
                                            lane_llm.add(&t);
                                            prefill_secs += t.secs();
                                            break kv;
                                        }
                                        Err(e2) => {
                                            budget.admit(&e2, &t_query)?;
                                            rel.retries += 1;
                                            if e2.is_lane_dead() {
                                                rel.quarantined_entries +=
                                                    self.quarantine_dead(cache);
                                            }
                                        }
                                    }
                                };
                                let out =
                                    cache.install_tiered(cid, kv, entry_bytes);
                                self.finish_install(cache, out);
                            }
                        }
                    }
                }
            };
            if let Some(t) = t_rec {
                rel.degraded_secs += t.secs();
            }
            let Some((kv_q, row, ext_t)) = got else {
                // terminal overload at extend: the representative entry
                // stays resident for later queries — drop only this query's
                // pin and shed. Engine work already spent on this query
                // (repaid prefill / promotion copy) stays charged.
                cache.unpin(cid);
                rel.shed.shed_overloaded += 1;
                report.outcomes.push(QueryOutcome::Shed {
                    id: q.id,
                    reason: ShedReason::Overloaded,
                });
                prefill_total += prefill_secs;
                llm_time += prefill_secs + promote_secs;
                top_up(&mut queue, &mut stream, &mut overlap_time, false,
                       eff_depth)?;
                continue 'turns;
            };
            prefill_total += prefill_secs;
            lane_llm.add(&ext_t);
            let t_host = Timer::start();
            let first = argmax(&row);
            let first_host_secs = t_host.secs();
            llm_time += prefill_secs + promote_secs + ext_t.secs();

            // 6) latency accounting (no amortization — see the module docs
            //    in `coordinator`): a miss pays its prefill in PFTT, a hit
            //    does not. That asymmetry IS the online speedup. Every term
            //    is this query's own component time (`lookup_stall` is ~0
            //    except when this query waited out another stream's install
            //    of its representative).
            let prompt_ready =
                retrieval_secs + assign_secs + open_secs + rebuild_secs + question.tok_secs;
            // a promoted (host-tier-hit) query's PFTT carries the copy it
            // actually waited out, never a prefill; prefill_total stays a
            // pure count of engine prefill seconds, so the tier's win is
            // exactly prefill_total's shrinkage at equal correctness.
            let pftt = lookup_stall + prefill_secs + promote_secs + ext_t.secs()
                + first_host_secs;

            // 7) decode. k >= 2 leaves the generate in flight (finalized in
            //    the next query's extend shadow, or drained after the loop);
            //    k = 1 — or a brownout-clamped turn — waits inline,
            //    reproducing the serial pipeline.
            let mut budget = RetryBudget::new(&self.cfg);
            let pending_gen = loop {
                match self.engine.submit_generate(
                    &self.cfg.backbone, &kv_q,
                    (plen + question.qlen) as i32, first) {
                    Ok(p) => break Some(p),
                    Err(e) => match budget.admit(&e, &t_query) {
                        Ok(()) => {
                            rel.retries += 1;
                            degraded = true;
                        }
                        Err(err) => {
                            if shed_on && e.is_overloaded() {
                                break None;
                            }
                            return Err(err);
                        }
                    },
                }
            };
            let Some(pending_gen) = pending_gen else {
                // terminal overload at the decode submit: give the private
                // prefix+question KV back, keep the representative resident
                // (unpin only), shed. The extend's engine time is already
                // charged above.
                self.engine.release(kv_q);
                cache.unpin(cid);
                rel.shed.shed_overloaded += 1;
                report.outcomes.push(QueryOutcome::Shed {
                    id: q.id,
                    reason: ShedReason::Overloaded,
                });
                top_up(&mut queue, &mut stream, &mut overlap_time, false,
                       eff_depth)?;
                continue 'turns;
            };
            let dec = InflightDecode {
                q, cid, sg, hit, kv_q, first, pending: pending_gen, question, plen,
                t_query, degraded, prompt_ready, pftt,
                gen_cap: if level >= 3 {
                    overload.brownout.map_or(usize::MAX, |b| b.gen_cap.max(1))
                } else {
                    usize::MAX
                },
            };
            // the query is now past every shed point: it WILL be served.
            rel.shed.admitted += 1;
            report.outcomes.push(QueryOutcome::Served { id: q.id });
            if !est_fixed {
                // no calibrated estimate was configured: track the engine-
                // bound service component with an EWMA of observed PFTT.
                est = if est > 0.0 { 0.8 * est + 0.2 * pftt } else { pftt };
            }
            if eff_depth >= 2 {
                pending_decode = Some(dec);
            } else {
                finalize(dec, &clusters, &mut *cache, &mut report, &mut llm_time,
                         &mut prefill_total, &mut lane_llm, &mut rel)?;
            }
        }
        // drain the last in-flight decode
        if let Some(dec) = pending_decode.take() {
            finalize(dec, &clusters, &mut *cache, &mut report, &mut llm_time,
                     &mut prefill_total, &mut lane_llm, &mut rel)?;
        }
        // close a still-open brownout span at end of stream.
        if let Some(t) = brown_t.take() {
            rel.brownout_secs += t.secs();
        }

        report.cluster_sizes = clusters.iter().map(|cl| cl.members).collect();
        report.representative_sizes = clusters.iter().map(|cl| cl.rep.len()).collect();
        report.expired_clusters = expired_clusters;
        report.metrics.llm_time = llm_time;
        report.metrics.shared_prefill_time = prefill_total;
        report.metrics.overlap_time = overlap_time;
        report.metrics.pipeline_depth = depth;
        report.metrics.lane_llm = lane_llm;
        report.metrics.lane_gnn = lane_gnn;
        // restarts stay 0 here: the supervisor counter is fleet-wide, so the
        // delta is taken once by the caller (serve_online_with_cache or
        // serve_online_multi_partial), never double-counted per stream.
        report.metrics.reliability = rel;
        // end of stream: a private view drains the whole pool; a shared
        // view only drops this stream's pins and returns deferred handles
        // (the pool owner drains the rest once every stream is done).
        self.engine.release_many(cache.release_all());
        report.cache = cache.stats();
        report.metrics.shared_hits = report.cache.shared_hits;
        report.metrics.dedup_bytes_saved = report.cache.dedup_bytes_saved;
        report.metrics.wall_time = t_wall.secs();
        Ok(report)
    }
}
