//! Online (streaming) SubGCache: the deployment setting the paper's §3
//! sketches but the in-batch pipeline never implements.
//!
//! Queries arrive one at a time. Each arriving query's retrieved subgraph is
//! GNN-encoded and assigned to the nearest existing cluster centroid within
//! `ServeConfig::online_threshold` (squared Euclidean over GNN embeddings);
//! farther queries open a new cluster whose representative subgraph — and
//! therefore prefix prompt — is frozen at open time, so a later warm hit
//! extends exactly the prefix that was prefilled. Centroids keep a running
//! mean of member embeddings so clusters track their query population.
//!
//! A query whose cluster's representative KV cache is still resident is a
//! **hit**: it pays only the question `extend`. A query that opens a new
//! cluster, or whose representative was evicted under the cache budget, is a
//! **miss**: it additionally pays the representative prefill in full — no
//! amortization exists online because membership is unknown at serve time.
//!
//! # Two-stage pipeline
//!
//! The stream is served as a software pipeline with one query of lookahead:
//! while the engine executes query *i*'s prefill (miss) or extend (hit),
//! the coordinator runs query *i+1*'s engine-free host prep — retrieval,
//! GNN input packing, and question tokenization — in the shadow of the
//! in-flight ticket. Each prep component is timed where it executes and
//! charged to its own query, and engine stages are charged from the
//! engine-thread [`crate::runtime::CallTiming`], so the per-query
//! PFTT/TTFT (and their hit/miss split) mean exactly what they meant under
//! serial serving; the overlap win surfaces in `BatchMetrics::wall_time` /
//! `overlap_time`. Cluster assignment, prefix verbalization and cache state
//! stay strictly in arrival order — only order-independent host work moves
//! into the shadow.

use crate::cache::KvCacheManager;
use crate::data::{Dataset, Query};
use crate::embed::sq_dist;
use crate::graph::Subgraph;
use crate::metrics::{QueryLatency, Timer};
use crate::retrieval::{GraphFeatures, Retriever};
use crate::runtime::{pack_subgraph, KvHandle, PackedSubgraph};

use super::session::PreparedQuestion;
use super::{Coordinator, ServeReport};

/// One open cluster of the stream. Deliberately small — a centroid, a
/// member count, and the frozen representative subgraph (node/edge id
/// sets) — because cluster metadata outlives the KV budget: the
/// [`crate::cache::CachePolicy`] bounds resident KV bytes, not this state,
/// which grows with the number of clusters the stream opens. An evicted
/// representative is re-verbalized from `rep` on its next miss rather than
/// keeping a padded max_seq token vector per cluster alive forever.
/// Expiring cold clusters outright is future work (ROADMAP).
struct OnlineCluster {
    /// running mean of member embeddings.
    centroid: Vec<f32>,
    members: usize,
    /// representative subgraph, frozen when the cluster opened.
    rep: Subgraph,
    /// real prefix length of `rep`'s verbalization (stable: the
    /// verbalizer and tokenizer are deterministic over a frozen `rep`).
    plen: usize,
}

/// Engine-free host prep for one arriving query, runnable in the shadow of
/// the previous query's in-flight engine call: retrieval, GNN input
/// packing, question tokenization. Nothing here depends on cluster state,
/// which is exactly why it can run ahead of the query's turn.
struct PreppedQuery<'q> {
    q: &'q Query,
    sg: Subgraph,
    packed: PackedSubgraph,
    question: PreparedQuestion,
    retrieval_secs: f64,
    pack_secs: f64,
}

impl<'e> Coordinator<'e> {
    /// Serve a stream of queries online. `query_stream` is consumed in
    /// arrival order; each query is matched against the clusters opened by
    /// the queries before it — nothing about the batch is known up front.
    ///
    /// The report's `per_query` entries carry `cache_hit` so
    /// [`crate::metrics::BatchMetrics::ttft_hit_ms`] /
    /// [`crate::metrics::BatchMetrics::ttft_miss_ms`] split cleanly — the
    /// split stays exact under pipelining because every latency is composed
    /// from the query's own component times (module docs).
    pub fn serve_online<'q, I>(&self, ds: &Dataset, query_stream: I,
                               retriever: &dyn Retriever) -> anyhow::Result<ServeReport>
    where
        I: IntoIterator<Item = &'q Query>,
    {
        self.engine.warmup(&self.cfg.backbone)?;
        let gnn = self.gnn_module(retriever);
        self.engine.warmup(&gnn)?;
        let c = *self.store.constants();
        let session = self.session();
        let feats = GraphFeatures::build(&ds.graph);
        let entry_bytes = self.kv_entry_bytes()?;
        let threshold = self.cfg.online_threshold;

        // Host-only prep, shared by the pipeline's lookahead and the
        // first/fallback (non-overlapped) cases. Every component is timed
        // here so it gets charged to its own query wherever it runs.
        let prep = |q: &'q Query| -> PreppedQuery<'q> {
            let t = Timer::start();
            let sg = retriever.retrieve(&ds.graph, &feats, &q.text);
            let retrieval_secs = t.secs();
            let t = Timer::start();
            let packed = pack_subgraph(&ds.graph, &feats, &sg, c.n_max, c.feat_dim);
            let pack_secs = t.secs();
            let question = session.prepare_question(&q.text);
            PreppedQuery { q, sg, packed, question, retrieval_secs, pack_secs }
        };

        let mut clusters: Vec<OnlineCluster> = Vec::new();
        let mut cache: KvCacheManager<KvHandle> = KvCacheManager::new(self.cfg.cache);
        let mut report = ServeReport::default();
        let mut llm_time = 0.0;
        let mut prefill_total = 0.0;
        let mut overlap_time = 0.0;
        let t_wall = Timer::start();

        let mut stream = query_stream.into_iter();
        // the opening query has no predecessor to shadow: prep it inline.
        let mut current: Option<PreppedQuery<'q>> = stream.next().map(&prep);

        while let Some(cur) = current.take() {
            let PreppedQuery { q, sg, packed, question, retrieval_secs, pack_secs } = cur;
            let next_q = stream.next();
            let mut next_prepped: Option<PreppedQuery<'q>> = None;
            // One-query lookahead: the first in-flight engine call of this
            // query hosts the next query's prep in its shadow. Idempotent,
            // so the miss path (prefill shadow) and the common path (extend
            // shadow) can both offer the slot.
            let mut do_overlap = || {
                if next_prepped.is_some() {
                    return; // the slot already ran in an earlier shadow
                }
                if let Some(nq) = next_q {
                    let t = Timer::start();
                    next_prepped = Some(prep(nq));
                    overlap_time += t.secs();
                }
            };

            // 1) retrieval already ran at prep time (charged below).
            // 2) GNN encode + centroid assignment. Charged in full to this
            //    query: online there is no batch to amortize over. The
            //    packing cost was measured at prep time and lands here too.
            //    The overlap slot is deliberately NOT offered here: it runs
            //    once, and the prefill/extend below cast a longer device
            //    shadow than the encode — offering it first would hide the
            //    next prep under the smallest call instead of the largest.
            let pending_enc = self.engine.submit_encode(
                &gnn, packed.x, packed.adj, packed.mask)?;
            let (emb, enc_t) = pending_enc.wait_timed()?;
            let t_scan = Timer::start();
            let nearest = clusters
                .iter()
                .enumerate()
                .map(|(i, cl)| (i, sq_dist(&cl.centroid, &emb)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
            let joined = nearest.filter(|&(_, d)| d <= threshold).map(|(i, _)| i);
            let assign_secs = pack_secs + enc_t.secs() + t_scan.secs();

            // 3) open a new cluster if nothing was close enough. The prefix
            //    prompt is built here (prompt-construction time), frozen for
            //    the cluster's lifetime; the padded token vector itself is
            //    NOT retained — see `OnlineCluster`.
            let t_open = Timer::start();
            let mut fresh_tokens: Option<Vec<i32>> = None;
            let cid = match joined {
                Some(cid) => {
                    let cl = &mut clusters[cid];
                    cl.members += 1;
                    let n = cl.members as f32;
                    for (ci, ei) in cl.centroid.iter_mut().zip(&emb) {
                        *ci += (ei - *ci) / n;
                    }
                    cid
                }
                None => {
                    let (tokens, plen) = session.prefix_tokens(&ds.graph, &sg);
                    fresh_tokens = Some(tokens);
                    clusters.push(OnlineCluster {
                        centroid: emb,
                        members: 1,
                        rep: sg.clone(),
                        plen,
                    });
                    clusters.len() - 1
                }
            };
            let open_secs = t_open.secs();

            // 4) warm-cache check. `lookup` records exactly one hit or miss
            //    (and refreshes LRU / bytes_saved on a hit).
            let hit = cache.lookup(cid).is_some();
            let mut rebuild_secs = 0.0;
            let prefill_secs = if hit {
                cache.pin(cid);
                0.0
            } else {
                // an evicted-miss re-verbalizes the frozen representative.
                // That rebuild is prompt-construction (charged like a fresh
                // cluster's token build in step 3), NOT prefill — PFTT and
                // llm_time must mean the same engine work for both miss
                // flavors.
                let tokens = match fresh_tokens.take() {
                    Some(t) => t,
                    None => {
                        let t_rebuild = Timer::start();
                        let (t, plen) =
                            session.prefix_tokens(&ds.graph, &clusters[cid].rep);
                        debug_assert_eq!(plen, clusters[cid].plen,
                                         "frozen rep must re-verbalize identically");
                        rebuild_secs = t_rebuild.secs();
                        t
                    }
                };
                let pending = self.engine.submit_prefill(&self.cfg.backbone, &tokens,
                                                         clusters[cid].plen as i32)?;
                // the next query's host prep rides the representative
                // prefill — the longest call a miss makes before decode.
                do_overlap();
                let (kv, _logits, prefill_t) = pending.wait_timed()?;
                let secs = prefill_t.secs();
                // admitted pinned; colder representatives may fall out.
                let evicted = cache.install(cid, kv, entry_bytes);
                self.engine.release_many(evicted);
                secs
            };
            prefill_total += prefill_secs;

            // 5) extend + decode against the resident representative cache.
            //    The entry stays pinned across the in-flight ticket (install
            //    admits pinned; a hit pinned explicitly above), so the
            //    overlap work can never race it out of residency.
            let plen = clusters[cid].plen;
            debug_assert!(cache.pin_count(cid) >= 1,
                          "in-flight cluster must hold a pin across its tickets");
            let out = {
                let kv = cache
                    .peek(cid)
                    .ok_or_else(|| anyhow::anyhow!("online cluster cache missing"))?;
                session.extend_decode_prepared(kv, plen, &question, &mut do_overlap)?
            };
            cache.unpin(cid);
            llm_time += prefill_secs + (out.t_done - out.t_prompt);

            // 6) latency accounting (no amortization — see the module docs
            //    in `coordinator`): a miss pays its prefill in PFTT, a hit
            //    does not. That asymmetry IS the online speedup. Every term
            //    is this query's own component time.
            let prompt_ready =
                retrieval_secs + assign_secs + open_secs + rebuild_secs + out.t_prompt;
            let pftt = prefill_secs + (out.t_first - out.t_prompt);
            let ttft = prompt_ready + pftt;
            let rt = ttft + (out.t_done - out.t_first);

            let result = session.result(q, out.predicted, cid, sg);
            report.metrics.per_query.push(QueryLatency {
                rt,
                ttft,
                pftt,
                correct: result.correct,
                cache_hit: Some(hit),
            });
            report.results.push(result);

            // advance the pipeline: the shadow prep (if any) becomes the
            // next stage-2 input; otherwise prep inline (first iteration
            // after an all-engine-error-free query always has it already).
            current = next_prepped.or_else(|| next_q.map(&prep));
        }

        report.cluster_sizes = clusters.iter().map(|cl| cl.members).collect();
        report.representative_sizes = clusters.iter().map(|cl| cl.rep.len()).collect();
        report.metrics.llm_time = llm_time;
        report.metrics.shared_prefill_time = prefill_total;
        report.metrics.overlap_time = overlap_time;
        self.engine.release_many(cache.release_all());
        report.cache = cache.stats();
        report.metrics.wall_time = t_wall.secs();
        Ok(report)
    }
}
