//! Online (streaming) SubGCache: the deployment setting the paper's §3
//! sketches but the in-batch pipeline never implements.
//!
//! Queries arrive one at a time. Each arriving query's retrieved subgraph is
//! GNN-encoded and assigned to the nearest existing cluster centroid within
//! `ServeConfig::online_threshold` (squared Euclidean over GNN embeddings);
//! farther queries open a new cluster whose representative subgraph — and
//! therefore prefix prompt — is frozen at open time, so a later warm hit
//! extends exactly the prefix that was prefilled. Centroids keep a running
//! mean of member embeddings so clusters track their query population.
//!
//! A query whose cluster's representative KV cache is still resident is a
//! **hit**: it pays only the question `extend`. A query that opens a new
//! cluster, or whose representative was evicted under the cache budget, is a
//! **miss**: it additionally pays the representative prefill in full — no
//! amortization exists online because membership is unknown at serve time.
//!
//! # The depth-k scheduler
//!
//! The stream is served as a software pipeline over the backend's two lanes
//! (`ServeConfig::pipeline_depth` = k):
//!
//! * **Prep queue** — up to k queries of engine-free host prep (retrieval,
//!   GNN input packing, question tokenization) run ahead of the query
//!   currently being served, refilled in the shadow of in-flight tickets.
//! * **Eager encode** (k ≥ 2) — a prepped query's GNN encode is submitted
//!   to the GNN lane at prep time, so query *i+1*'s encode executes while
//!   the LLM lane runs query *i*'s prefill/extend/generate. At its own turn
//!   the query only pays the *stall* it actually spends waiting for the
//!   embedding (often ~0) — not lane time that overlapped earlier work.
//! * **Decoupled decode** (k ≥ 2) — the greedy `generate` of query *i* is
//!   left in flight while query *i+1* is assigned and its `extend`
//!   submitted; the two touch different KV entries (the private
//!   prefix+question cache vs the next query's representative), so the LLM
//!   lane streams generate(i) → extend(i+1) back to back with no
//!   coordinator round-trip between them. Query *i* is finalized — decode
//!   waited, answer detokenized, latency recorded — in the shadow of query
//!   *i+1*'s extend. With k = 1 the decode is waited inline, reproducing
//!   the serial one-query-lookahead pipeline.
//!
//! Arrival order is never violated: cluster assignment, prefix
//! verbalization, cache state, and result records advance strictly in
//! stream order — only order-independent work moves into shadows.
//!
//! # Pin safety
//!
//! A cluster's representative entry is pinned from its lookup/install until
//! the query's *finalize* (not merely until the extend returns), so neither
//! a shadow-prep admission, budget eviction, nor a TTL sweep can release an
//! entry any in-flight ticket might still reference. Pins nest across
//! back-to-back queries of one cluster.
//!
//! # Cluster TTL
//!
//! With `ServeConfig::cluster_ttl = Some(ttl)`, a sweep at the top of every
//! turn expires clusters whose centroid has not been opened/joined for more
//! than `ttl` arrivals: the centroid stops participating in matching and
//! its resident KV entry (if any) is released back to the backend. A pinned
//! (in-flight) representative always survives a sweep regardless of
//! staleness — it is reconsidered once unpinned. Expired clusters keep
//! their slot (ids are stable) and are counted in
//! [`super::ServeReport::expired_clusters`].
//!
//! # Latency accounting
//!
//! Each prep component is timed where it executes and charged to its own
//! query; LLM-lane stages are charged from the lane-side
//! [`crate::runtime::CallTiming`] (queue seconds — the query really did
//! wait behind earlier lane work — plus execution span); the eagerly
//! submitted encode is charged its measured *stall* at the query's turn
//! (queue/device time that overlapped other queries' engine work did not
//! delay this query's first token, and claiming otherwise would punish
//! pipelining in per-query numbers). The per-query PFTT/TTFT (and their
//! hit/miss split) therefore mean exactly what they meant under serial
//! serving; the pipeline win surfaces in `BatchMetrics::wall_time` /
//! `overlap_time` / per-lane `lane_llm` / `lane_gnn`.

use std::collections::VecDeque;

use crate::cache::KvCacheManager;
use crate::data::{Dataset, Query};
use crate::embed::sq_dist;
use crate::graph::Subgraph;
use crate::metrics::{LaneTimes, QueryLatency, Timer};
use crate::retrieval::{GraphFeatures, Retriever};
use crate::runtime::{pack_subgraph, KvHandle, PackedSubgraph, PendingEncode,
                     PendingGenerate};

use super::session::PreparedQuestion;
use super::{argmax, Coordinator, ServeReport};

/// One open cluster of the stream. Deliberately small — a centroid, a
/// member count, and the frozen representative subgraph (node/edge id
/// sets) — because cluster metadata outlives the KV budget: the
/// [`crate::cache::CachePolicy`] bounds resident KV bytes, not this state.
/// An evicted representative is re-verbalized from `rep` on its next miss
/// rather than keeping a padded max_seq token vector per cluster alive
/// forever. Cold clusters are reclaimed by the TTL sweep (module docs)
/// when `ServeConfig::cluster_ttl` is set.
struct OnlineCluster {
    /// running mean of member embeddings.
    centroid: Vec<f32>,
    members: usize,
    /// representative subgraph, frozen when the cluster opened.
    rep: Subgraph,
    /// real prefix length of `rep`'s verbalization (stable: the
    /// verbalizer and tokenizer are deterministic over a frozen `rep`).
    plen: usize,
    /// arrival index of the query that most recently opened/joined this
    /// cluster (drives the TTL sweep).
    last_used: u64,
    /// TTL-expired: the centroid no longer participates in matching and
    /// the KV entry has been released. The slot stays so ids are stable.
    expired: bool,
}

/// The encode stage of a prepped query: already in flight on the GNN lane
/// (depth ≥ 2), or still packed host-side (depth 1 submits at the turn).
enum EncStage {
    Pending(PendingEncode),
    Packed(PackedSubgraph),
}

/// Engine-free host prep for one arriving query, runnable in the shadow of
/// an in-flight engine call: retrieval, GNN input packing, question
/// tokenization — plus, at depth ≥ 2, the eagerly submitted encode.
/// Nothing here depends on cluster state, which is exactly why it can run
/// ahead of the query's turn.
struct PreppedQuery<'q> {
    q: &'q Query,
    sg: Subgraph,
    enc: EncStage,
    question: PreparedQuestion,
    retrieval_secs: f64,
    pack_secs: f64,
}

/// The decoupled decode stage: everything needed to finalize query *i*
/// while query *i+1* runs. Holds the query's cache pin (released at
/// finalize) and its private prefix+question KV handle.
struct InflightDecode<'q> {
    q: &'q Query,
    cid: usize,
    sg: Subgraph,
    hit: bool,
    kv_q: KvHandle,
    first: i32,
    pending: PendingGenerate,
    /// composed component times up to the first token
    prompt_ready: f64,
    pftt: f64,
}

impl<'e> Coordinator<'e> {
    /// Serve a stream of queries online. `query_stream` is consumed in
    /// arrival order; each query is matched against the clusters opened by
    /// the queries before it — nothing about the batch is known up front.
    ///
    /// The report's `per_query` entries carry `cache_hit` so
    /// [`crate::metrics::BatchMetrics::ttft_hit_ms`] /
    /// [`crate::metrics::BatchMetrics::ttft_miss_ms`] split cleanly — the
    /// split stays exact under pipelining because every latency is composed
    /// from the query's own component times (module docs).
    pub fn serve_online<'q, I>(&self, ds: &Dataset, query_stream: I,
                               retriever: &dyn Retriever) -> anyhow::Result<ServeReport>
    where
        I: IntoIterator<Item = &'q Query>,
    {
        self.engine.warmup(&self.cfg.backbone)?;
        let gnn = self.gnn_module(retriever);
        self.engine.warmup(&gnn)?;
        let c = *self.store.constants();
        let session = self.session();
        let feats = GraphFeatures::build(&ds.graph);
        let entry_bytes = self.kv_entry_bytes()?;
        let threshold = self.cfg.online_threshold;
        let depth = self.cfg.pipeline_depth.max(1);
        let eager_encode = depth >= 2;

        // Host-only prep, shared by the pipeline's lookahead and the
        // first/fallback (non-overlapped) cases. Every component is timed
        // here so it gets charged to its own query wherever it runs. At
        // depth >= 2 the encode ships to the GNN lane immediately — the
        // overlap the lane split exists for.
        let prep = |q: &'q Query| -> anyhow::Result<PreppedQuery<'q>> {
            let t = Timer::start();
            let sg = retriever.retrieve(&ds.graph, &feats, &q.text);
            let retrieval_secs = t.secs();
            let t = Timer::start();
            let packed = pack_subgraph(&ds.graph, &feats, &sg, c.n_max, c.feat_dim);
            let pack_secs = t.secs();
            let question = session.prepare_question(&q.text);
            let enc = if eager_encode {
                EncStage::Pending(self.engine.submit_encode(
                    &gnn, packed.x, packed.adj, packed.mask)?)
            } else {
                EncStage::Packed(packed)
            };
            Ok(PreppedQuery { q, sg, enc, question, retrieval_secs, pack_secs })
        };

        // Refill the prep queue up to depth k. `in_shadow` marks calls made
        // under an in-flight engine ticket, whose prep time counts toward
        // `overlap_time` (the work itself is always charged to its query).
        let top_up = |queue: &mut VecDeque<PreppedQuery<'q>>,
                      stream: &mut dyn Iterator<Item = &'q Query>,
                      overlap_time: &mut f64,
                      in_shadow: bool|
         -> anyhow::Result<()> {
            while queue.len() < depth {
                match stream.next() {
                    Some(q) => {
                        let t = Timer::start();
                        queue.push_back(prep(q)?);
                        if in_shadow {
                            *overlap_time += t.secs();
                        }
                    }
                    None => break,
                }
            }
            Ok(())
        };

        let mut clusters: Vec<OnlineCluster> = Vec::new();
        let mut cache: KvCacheManager<KvHandle> = KvCacheManager::new(self.cfg.cache);
        let mut report = ServeReport::default();
        let mut llm_time = 0.0;
        let mut prefill_total = 0.0;
        let mut overlap_time = 0.0;
        let mut lane_llm = LaneTimes::default();
        let mut lane_gnn = LaneTimes::default();
        let mut expired_clusters = 0usize;
        let t_wall = Timer::start();

        // Finalize one decoupled decode: wait the generate, detokenize,
        // compose the record, release the private KV, drop the pin.
        let finalize = |dec: InflightDecode<'q>,
                        cache: &mut KvCacheManager<KvHandle>,
                        report: &mut ServeReport,
                        llm_time: &mut f64,
                        lane_llm: &mut LaneTimes|
         -> anyhow::Result<()> {
            let (gen, gen_t) = dec.pending.wait_timed()?;
            lane_llm.add(&gen_t);
            let t_host = Timer::start();
            let predicted = session.decode_answer(dec.first, &gen);
            let result = session.result(dec.q, predicted, dec.cid, dec.sg);
            let ttft = dec.prompt_ready + dec.pftt;
            let rt = ttft + gen_t.secs() + t_host.secs();
            *llm_time += gen_t.secs();
            report.metrics.per_query.push(QueryLatency {
                rt,
                ttft,
                pftt: dec.pftt,
                correct: result.correct,
                cache_hit: Some(dec.hit),
            });
            report.results.push(result);
            self.engine.release(dec.kv_q);
            cache.unpin(dec.cid);
            Ok(())
        };

        let mut stream = query_stream.into_iter();
        let mut queue: VecDeque<PreppedQuery<'q>> = VecDeque::new();
        // the opening fill has no shadow to ride: prep inline.
        top_up(&mut queue, &mut stream, &mut overlap_time, false)?;
        let mut pending_decode: Option<InflightDecode<'q>> = None;
        let mut arrival: u64 = 0;

        while let Some(cur) = queue.pop_front() {
            let PreppedQuery { q, sg, enc, question, retrieval_secs, pack_secs } = cur;
            let now = arrival;
            arrival += 1;

            // 0) TTL sweep: expire clusters whose centroid went cold, and
            //    release their KV entries. A pinned entry belongs to an
            //    in-flight query (extend or decoupled decode) — skip it,
            //    however stale; it is reconsidered once unpinned.
            if let Some(ttl) = self.cfg.cluster_ttl {
                let mut reclaimed: Vec<KvHandle> = Vec::new();
                for (cid, cl) in clusters.iter_mut().enumerate() {
                    if cl.expired || now.saturating_sub(cl.last_used) <= ttl {
                        continue;
                    }
                    if cache.pin_count(cid) > 0 {
                        continue; // in-flight representative survives expiry
                    }
                    cl.expired = true;
                    expired_clusters += 1;
                    if let Some(h) = cache.release(cid) {
                        reclaimed.push(h);
                    }
                }
                self.engine.release_many(reclaimed);
            }

            // 1) retrieval/pack/tokenize already ran at prep time (charged
            //    below, wherever they executed).
            // 2) GNN embedding + centroid assignment. The query is charged
            //    the *stall* it spends blocked on its embedding here: under
            //    eager submission the encode ran in the shadow of earlier
            //    LLM work and the stall is ~0; at depth 1 (submit + wait
            //    inline) the stall is the full queue + device time, exactly
            //    the serial accounting.
            let pending_enc = match enc {
                EncStage::Pending(p) => p,
                EncStage::Packed(packed) => self.engine.submit_encode(
                    &gnn, packed.x, packed.adj, packed.mask)?,
            };
            let t_stall = Timer::start();
            let (emb, enc_t) = pending_enc.wait_timed()?;
            let enc_stall = t_stall.secs();
            lane_gnn.add(&enc_t);
            let t_scan = Timer::start();
            let nearest = clusters
                .iter()
                .enumerate()
                .filter(|(_, cl)| !cl.expired)
                .map(|(i, cl)| (i, sq_dist(&cl.centroid, &emb)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
            let joined = nearest.filter(|&(_, d)| d <= threshold).map(|(i, _)| i);
            let assign_secs = pack_secs + enc_stall + t_scan.secs();

            // 3) open a new cluster if nothing was close enough. The prefix
            //    prompt is built here (prompt-construction time), frozen for
            //    the cluster's lifetime; the padded token vector itself is
            //    NOT retained — see `OnlineCluster`.
            let t_open = Timer::start();
            let mut fresh_tokens: Option<Vec<i32>> = None;
            let cid = match joined {
                Some(cid) => {
                    let cl = &mut clusters[cid];
                    cl.members += 1;
                    cl.last_used = now;
                    let n = cl.members as f32;
                    for (ci, ei) in cl.centroid.iter_mut().zip(&emb) {
                        *ci += (ei - *ci) / n;
                    }
                    cid
                }
                None => {
                    let (tokens, plen) = session.prefix_tokens(&ds.graph, &sg);
                    fresh_tokens = Some(tokens);
                    clusters.push(OnlineCluster {
                        centroid: emb,
                        members: 1,
                        rep: sg.clone(),
                        plen,
                        last_used: now,
                        expired: false,
                    });
                    clusters.len() - 1
                }
            };
            let open_secs = t_open.secs();

            // 4) warm-cache check. `lookup` records exactly one hit or miss
            //    (and refreshes LRU / bytes_saved on a hit). The pin taken
            //    here (or by install below) is held until this query's
            //    finalize — see the pin-safety section of the module docs.
            let hit = cache.lookup(cid).is_some();
            let mut rebuild_secs = 0.0;
            let prefill_secs = if hit {
                cache.pin(cid);
                0.0
            } else {
                // an evicted-miss re-verbalizes the frozen representative.
                // That rebuild is prompt-construction (charged like a fresh
                // cluster's token build in step 3), NOT prefill — PFTT and
                // llm_time must mean the same engine work for both miss
                // flavors.
                let tokens = match fresh_tokens.take() {
                    Some(t) => t,
                    None => {
                        let t_rebuild = Timer::start();
                        let (t, plen) =
                            session.prefix_tokens(&ds.graph, &clusters[cid].rep);
                        debug_assert_eq!(plen, clusters[cid].plen,
                                         "frozen rep must re-verbalize identically");
                        rebuild_secs = t_rebuild.secs();
                        t
                    }
                };
                let pending = self.engine.submit_prefill(&self.cfg.backbone, &tokens,
                                                         clusters[cid].plen as i32)?;
                // the prep queue refills in the representative prefill's
                // shadow — the longest call a miss makes before decode.
                top_up(&mut queue, &mut stream, &mut overlap_time, true)?;
                let (kv, _logits, prefill_t) = pending.wait_timed()?;
                lane_llm.add(&prefill_t);
                let secs = prefill_t.secs();
                // admitted pinned; colder representatives may fall out.
                let evicted = cache.install(cid, kv, entry_bytes);
                self.engine.release_many(evicted);
                secs
            };
            prefill_total += prefill_secs;

            // 5) extend against the resident representative cache. In the
            //    extend's shadow: finalize the previous query's decoupled
            //    decode (its generate runs on the LLM lane just ahead of
            //    this extend) and refill the prep queue.
            let plen = clusters[cid].plen;
            debug_assert!(cache.pin_count(cid) >= 1,
                          "in-flight cluster must hold a pin across its tickets");
            let pending_ext = {
                let kv = cache
                    .peek(cid)
                    .ok_or_else(|| anyhow::anyhow!("online cluster cache missing"))?;
                self.engine.submit_extend(&self.cfg.backbone, kv, plen as i32,
                                          &question.tokens, question.qlen as i32)?
            };
            if let Some(dec) = pending_decode.take() {
                finalize(dec, &mut cache, &mut report, &mut llm_time, &mut lane_llm)?;
            }
            top_up(&mut queue, &mut stream, &mut overlap_time, true)?;
            let (kv_q, row, ext_t) = pending_ext.wait_timed()?;
            lane_llm.add(&ext_t);
            let t_host = Timer::start();
            let first = argmax(&row);
            let first_host_secs = t_host.secs();
            llm_time += prefill_secs + ext_t.secs();

            // 6) latency accounting (no amortization — see the module docs
            //    in `coordinator`): a miss pays its prefill in PFTT, a hit
            //    does not. That asymmetry IS the online speedup. Every term
            //    is this query's own component time.
            let prompt_ready =
                retrieval_secs + assign_secs + open_secs + rebuild_secs + question.tok_secs;
            let pftt = prefill_secs + ext_t.secs() + first_host_secs;

            // 7) decode. k >= 2 leaves the generate in flight (finalized in
            //    the next query's extend shadow, or drained after the loop);
            //    k = 1 waits inline, reproducing the serial pipeline.
            let pending_gen = self.engine.submit_generate(
                &self.cfg.backbone, &kv_q, (plen + question.qlen) as i32, first)?;
            let dec = InflightDecode {
                q, cid, sg, hit, kv_q, first, pending: pending_gen, prompt_ready, pftt,
            };
            if depth >= 2 {
                pending_decode = Some(dec);
            } else {
                finalize(dec, &mut cache, &mut report, &mut llm_time, &mut lane_llm)?;
            }
        }
        // drain the last in-flight decode
        if let Some(dec) = pending_decode.take() {
            finalize(dec, &mut cache, &mut report, &mut llm_time, &mut lane_llm)?;
        }

        report.cluster_sizes = clusters.iter().map(|cl| cl.members).collect();
        report.representative_sizes = clusters.iter().map(|cl| cl.rep.len()).collect();
        report.expired_clusters = expired_clusters;
        report.metrics.llm_time = llm_time;
        report.metrics.shared_prefill_time = prefill_total;
        report.metrics.overlap_time = overlap_time;
        report.metrics.pipeline_depth = depth;
        report.metrics.lane_llm = lane_llm;
        report.metrics.lane_gnn = lane_gnn;
        self.engine.release_many(cache.release_all());
        report.cache = cache.stats();
        report.metrics.wall_time = t_wall.secs();
        Ok(report)
    }
}
