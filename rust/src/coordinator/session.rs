//! Per-query serving session: the machinery every serving path shares.
//!
//! The seed duplicated tokenization, prompt construction, decode and latency
//! plumbing between the baseline and SubGCache paths; [`ServeSession`] owns
//! all of it once. The pipelines differ only in *which* engine calls they
//! make (full prefill vs. cached-prefix extend) and in how raw timing splits
//! are composed into [`QueryLatency`] (amortized in-batch, wall-clock
//! online) — see the module docs in [`super`].

use crate::data::{answer_correct, Query};
use crate::graph::{full_prompt, prefix_text, question_text, Subgraph, TextualGraph};
use crate::metrics::{QueryLatency, Timer};
use crate::runtime::{ArtifactStore, Engine, KvHandle};
use crate::tokenizer::Tokenizer;

use super::{argmax, QueryResult};

/// Raw timing splits of one question served against a cached prefix.
/// All fields are seconds since the query's own timer started.
pub(crate) struct ExtendOutcome {
    pub predicted: String,
    /// question tokenization done (prompt ready)
    pub t_prompt: f64,
    /// extend + first-token argmax done
    pub t_first: f64,
    /// greedy decode done
    pub t_done: f64,
}

/// One query served with a full prompt (the baseline path).
pub(crate) struct FullOutcome {
    pub latency: QueryLatency,
    pub result: QueryResult,
    /// LLM-only seconds (prefill + decode), for `BatchMetrics::llm_time`.
    pub llm_secs: f64,
}

/// Borrowed view over everything the per-query flow needs.
pub(crate) struct ServeSession<'a> {
    store: &'a ArtifactStore,
    engine: &'a Engine,
    backbone: &'a str,
}

impl<'a> ServeSession<'a> {
    pub fn new(store: &'a ArtifactStore, engine: &'a Engine, backbone: &'a str) -> Self {
        ServeSession { store, engine, backbone }
    }

    fn tok(&self) -> &Tokenizer {
        self.store.tokenizer()
    }

    // -- prompt construction -------------------------------------------------

    /// Prefix tokens: [BOS] + verbalized subgraph, padded to S.
    pub fn prefix_tokens(&self, g: &TextualGraph, sg: &Subgraph) -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let text = prefix_text(g, sg, Some(c.max_prefix));
        let mut ids = Vec::with_capacity(c.max_seq);
        ids.push(c.bos_id);
        self.tok().encode_into(&text, &mut ids);
        ids.truncate(c.max_seq - c.max_q - c.max_gen);
        let plen = ids.len();
        ids.resize(c.max_seq, c.pad_id);
        (ids, plen)
    }

    /// Full baseline prompt tokens: [BOS] + prefix + question, padded to S.
    pub fn full_tokens(&self, g: &TextualGraph, sg: &Subgraph, qtext: &str)
                       -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let text = full_prompt(g, sg, qtext, Some(c.max_prefix));
        let mut ids = Vec::with_capacity(c.max_seq);
        ids.push(c.bos_id);
        self.tok().encode_into(&text, &mut ids);
        ids.truncate(c.max_seq - c.max_gen);
        let plen = ids.len();
        ids.resize(c.max_seq, c.pad_id);
        (ids, plen)
    }

    /// Question tokens padded to Q.
    pub fn question_tokens(&self, qtext: &str) -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let mut ids = Vec::with_capacity(c.max_q);
        self.tok().encode_into(&question_text(qtext), &mut ids);
        ids.truncate(c.max_q);
        let qlen = ids.len();
        ids.resize(c.max_q, c.pad_id);
        (ids, qlen)
    }

    fn decode_answer(&self, first: i32, gen: &[i32]) -> String {
        debug_assert!(gen.first().copied() == Some(first));
        self.tok().decode(gen)
    }

    /// Assemble the per-query outcome record.
    pub fn result(&self, q: &Query, predicted: String, cluster: usize,
                  retrieved: Subgraph) -> QueryResult {
        let correct = answer_correct(&predicted, &q.answer);
        QueryResult {
            id: q.id,
            query: q.text.clone(),
            predicted,
            gold: q.answer.clone(),
            correct,
            cluster,
            retrieved,
        }
    }

    // -- serving flows -------------------------------------------------------

    /// Baseline flow for one query: verbalize → full prefill → decode, with
    /// the seed's exact latency accounting (retrieval already charged by the
    /// caller is NOT included here — pass the retrieved subgraph in).
    pub fn serve_full(&self, g: &TextualGraph, sg: Subgraph, q: &Query)
                      -> anyhow::Result<FullOutcome> {
        let t_all = Timer::start();
        let (tokens, plen) = self.full_tokens(g, &sg, &q.text);
        let t_prompt_ready = t_all.secs();

        let (kv, logits) = self.engine.prefill(self.backbone, &tokens, plen as i32)?;
        let first = argmax(&logits);
        let ttft = t_all.secs();
        let pftt = ttft - t_prompt_ready;

        let gen = self.engine.generate(self.backbone, &kv, plen as i32, first)?;
        self.engine.release(kv);
        let rt = t_all.secs();

        let predicted = self.decode_answer(first, &gen);
        let result = self.result(q, predicted, usize::MAX, sg);
        Ok(FullOutcome {
            latency: QueryLatency { rt, ttft, pftt, correct: result.correct,
                                    cache_hit: None },
            result,
            llm_secs: rt - t_prompt_ready,
        })
    }

    /// Cached-prefix flow for one question: tokenize → `extend` against the
    /// resident representative KV → decode. Returns raw timing splits; the
    /// caller composes them into `QueryLatency` under its own accounting
    /// rules (amortized shares in-batch, wall-clock online).
    pub fn extend_decode(&self, kv_prefix: &KvHandle, plen: usize, q: &Query)
                         -> anyhow::Result<ExtendOutcome> {
        let c = self.store.constants();
        let t_q = Timer::start();
        let (q_tokens, qlen) = self.question_tokens(&q.text);
        let t_prompt = t_q.secs();

        let (kv_q, logits) =
            self.engine.extend(self.backbone, kv_prefix, plen as i32, &q_tokens)?;
        let row = &logits[(qlen - 1) * c.vocab..qlen * c.vocab];
        let first = argmax(row);
        let t_first = t_q.secs();

        let gen = self.engine.generate(self.backbone, &kv_q,
                                       (plen + qlen) as i32, first)?;
        self.engine.release(kv_q);
        let t_done = t_q.secs();

        Ok(ExtendOutcome {
            predicted: self.decode_answer(first, &gen),
            t_prompt,
            t_first,
            t_done,
        })
    }
}
