//! Per-query serving session: the machinery every serving path shares.
//!
//! The seed duplicated tokenization, prompt construction, decode and latency
//! plumbing between the baseline and SubGCache paths; [`ServeSession`] owns
//! all of it once. The pipelines differ only in *which* engine calls they
//! make (full prefill vs. cached-prefix extend) and in how raw timing splits
//! are composed into [`QueryLatency`] (amortized in-batch, wall-clock
//! online) — see the module docs in [`super`].
//!
//! # Timing under pipelined submission
//!
//! Engine calls go through the submit/wait ticket API, so the coordinator
//! may run *another* query's host prep between submit and wait. A plain
//! wall timer around that window would charge the neighbor's shadow work to
//! this query, so every outcome here is composed from per-component
//! measurements instead: host stages are timed where they execute (whoever
//! ran them in whichever shadow), engine stages use the engine-thread
//! [`crate::runtime::CallTiming`] (queue seconds charged to the query,
//! execution span measured on the engine thread). The pipelining win
//! therefore shows up in `BatchMetrics::wall_time`/`qps`, never as
//! mysteriously shrunken per-query latencies.

use crate::data::{answer_correct, Query};
use crate::graph::{full_prompt, prefix_text, question_text, Subgraph, TextualGraph};
use crate::metrics::{QueryLatency, Timer};
use crate::runtime::{ArtifactStore, Backend, CallTiming, PendingExtend};
use crate::tokenizer::Tokenizer;

use super::{argmax, QueryResult};

/// Raw timing splits of one question served against a cached prefix.
/// Composed component times (see the module docs), all in seconds since the
/// query's own prompt stage began:
/// `t_prompt` = question tokenization; `t_first` adds the extend
/// (queue + engine span) and the first-token argmax; `t_done` adds the
/// scan-decode generate.
pub(crate) struct ExtendOutcome {
    pub predicted: String,
    /// question tokenization done (prompt ready)
    pub t_prompt: f64,
    /// extend + first-token argmax done
    pub t_first: f64,
    /// greedy decode done
    pub t_done: f64,
    /// lane-side timings of the two LLM calls, for
    /// `BatchMetrics::lane_llm` accounting by the caller.
    pub ext_timing: CallTiming,
    pub gen_timing: CallTiming,
}

/// One query served with a full prompt (the baseline path).
pub(crate) struct FullOutcome {
    pub latency: QueryLatency,
    pub result: QueryResult,
    /// LLM-only seconds (prefill + decode), for `BatchMetrics::llm_time`.
    pub llm_secs: f64,
    /// lane-side timings of the two LLM calls (prefill, generate).
    pub prefill_timing: CallTiming,
    pub gen_timing: CallTiming,
}

/// A tokenized question, ready to extend a cached prefix. Producing one is
/// pure host work, so pipelined callers build it in the shadow of an
/// in-flight engine call; `tok_secs` is charged to the owning query's
/// prompt time regardless of whose shadow it ran in.
pub(crate) struct PreparedQuestion {
    pub tokens: Vec<i32>,
    pub qlen: usize,
    pub tok_secs: f64,
}

/// Borrowed view over everything the per-query flow needs.
pub(crate) struct ServeSession<'a> {
    store: &'a ArtifactStore,
    engine: &'a dyn Backend,
    backbone: &'a str,
}

impl<'a> ServeSession<'a> {
    pub fn new(store: &'a ArtifactStore, engine: &'a dyn Backend, backbone: &'a str) -> Self {
        ServeSession { store, engine, backbone }
    }

    fn tok(&self) -> &Tokenizer {
        self.store.tokenizer()
    }

    // -- prompt construction -------------------------------------------------

    /// Prefix tokens: [BOS] + verbalized subgraph, padded to S.
    pub fn prefix_tokens(&self, g: &TextualGraph, sg: &Subgraph) -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let text = prefix_text(g, sg, Some(c.max_prefix));
        let mut ids = Vec::with_capacity(c.max_seq);
        ids.push(c.bos_id);
        self.tok().encode_into(&text, &mut ids);
        ids.truncate(c.max_seq - c.max_q - c.max_gen);
        let plen = ids.len();
        ids.resize(c.max_seq, c.pad_id);
        (ids, plen)
    }

    /// Full baseline prompt tokens: [BOS] + prefix + question, padded to S.
    pub fn full_tokens(&self, g: &TextualGraph, sg: &Subgraph, qtext: &str)
                       -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let text = full_prompt(g, sg, qtext, Some(c.max_prefix));
        let mut ids = Vec::with_capacity(c.max_seq);
        ids.push(c.bos_id);
        self.tok().encode_into(&text, &mut ids);
        ids.truncate(c.max_seq - c.max_gen);
        let plen = ids.len();
        ids.resize(c.max_seq, c.pad_id);
        (ids, plen)
    }

    /// Question tokens padded to Q. `qlen` may be 0 for empty question
    /// text — the engine clamps its logits-row selection, so a degenerate
    /// query costs one answer, not the process.
    pub fn question_tokens(&self, qtext: &str) -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let mut ids = Vec::with_capacity(c.max_q);
        self.tok().encode_into(&question_text(qtext), &mut ids);
        ids.truncate(c.max_q);
        let qlen = ids.len();
        ids.resize(c.max_q, c.pad_id);
        (ids, qlen)
    }

    /// Tokenize one question, timing the work (host-only — safe to run in
    /// the shadow of an in-flight engine call).
    pub fn prepare_question(&self, qtext: &str) -> PreparedQuestion {
        let t = Timer::start();
        let (tokens, qlen) = self.question_tokens(qtext);
        PreparedQuestion { tokens, qlen, tok_secs: t.secs() }
    }

    /// Detokenize a generated sequence (used inline by the online path's
    /// decoupled decode stage as well as the session flows below).
    pub fn decode_answer(&self, first: i32, gen: &[i32]) -> String {
        debug_assert!(gen.first().copied() == Some(first));
        self.tok().decode(gen)
    }

    /// Assemble the per-query outcome record.
    pub fn result(&self, q: &Query, predicted: String, cluster: usize,
                  retrieved: Subgraph) -> QueryResult {
        let correct = answer_correct(&predicted, &q.answer);
        QueryResult {
            id: q.id,
            query: q.text.clone(),
            predicted,
            gold: q.answer.clone(),
            correct,
            cluster,
            retrieved,
        }
    }

    // -- serving flows -------------------------------------------------------

    /// Baseline flow for one query: verbalize → full prefill → decode, with
    /// the seed's latency accounting composed from components (retrieval
    /// already charged by the caller is NOT included here — pass the
    /// retrieved subgraph in).
    pub fn serve_full(&self, g: &TextualGraph, sg: Subgraph, q: &Query)
                      -> anyhow::Result<FullOutcome> {
        let t_build = Timer::start();
        let (tokens, plen) = self.full_tokens(g, &sg, &q.text);
        let t_prompt_ready = t_build.secs();

        let (kv, logits, prefill_t) = self.engine
            .submit_prefill(self.backbone, &tokens, plen as i32)?
            .wait_timed()?;
        let t_host = Timer::start();
        let first = argmax(&logits);
        let pftt = prefill_t.secs() + t_host.secs();
        let ttft = t_prompt_ready + pftt;

        let (gen, gen_t) = self.engine
            .submit_generate(self.backbone, &kv, plen as i32, first)?
            .wait_timed()?;
        self.engine.release(kv);
        let rt = ttft + gen_t.secs();

        let predicted = self.decode_answer(first, &gen);
        let result = self.result(q, predicted, usize::MAX, sg);
        Ok(FullOutcome {
            latency: QueryLatency { rt, ttft, pftt, correct: result.correct,
                                    cache_hit: None },
            result,
            llm_secs: prefill_t.secs() + gen_t.secs(),
            prefill_timing: prefill_t,
            gen_timing: gen_t,
        })
    }

    /// Cached-prefix flow for one pre-tokenized question whose `extend` the
    /// caller has already submitted (the representative handle is borrowed
    /// under the cache's lock via `KvCacheManager::with_handle`, so the
    /// submission happens there): wait the extend → decode. `overlap` runs
    /// exactly once, in the shadow of the in-flight extend — pipelined
    /// callers use it for the next query's host prep, serial callers pass
    /// `|| {}`. Returns raw timing splits; the caller composes them into
    /// `QueryLatency` under its own accounting rules (amortized shares
    /// in-batch, wall-clock online).
    pub fn extend_decode_submitted(&self, pending: PendingExtend, plen: usize,
                                   prep: &PreparedQuestion, mut overlap: impl FnMut())
                                   -> anyhow::Result<ExtendOutcome> {
        overlap();
        let (kv_q, row, ext_t) = pending.wait_timed()?;
        let t_host = Timer::start();
        let first = argmax(&row);
        let t_first = prep.tok_secs + ext_t.secs() + t_host.secs();

        let (gen, gen_t) = self.engine
            .submit_generate(self.backbone, &kv_q, (plen + prep.qlen) as i32, first)?
            .wait_timed()?;
        self.engine.release(kv_q);
        let t_done = t_first + gen_t.secs();

        Ok(ExtendOutcome {
            predicted: self.decode_answer(first, &gen),
            t_prompt: prep.tok_secs,
            t_first,
            t_done,
            ext_timing: ext_t,
            gen_timing: gen_t,
        })
    }
}
