//! Seeded open-loop arrival processes for overload experiments.
//!
//! The serving paths were a *closed* loop until the overload plane landed:
//! the next query was prepped the moment the previous one finished, so the
//! system could never be oversubscribed and deadlines only measured service
//! time. An [`ArrivalPlan`] turns `serve_online` / `serve_online_multi` into
//! an *open* system: each query has a plan-assigned arrival offset, the
//! scheduler waits for that offset before admitting it, and a backlog forms
//! whenever arrivals outpace service — which is exactly the regime where
//! admission control and the brownout ladder earn their keep.
//!
//! Everything here is a pure function of `(seed, arrival index)` via
//! splitmix64, so two runs with the same plan produce bit-identical
//! schedules (and therefore, on `SimBackend`, bit-identical shed decisions).
//! The clock object only caches the running offset; it never consults wall
//! time or ambient randomness.

use std::time::Duration;

/// splitmix64 — the same tiny mixer the sim's fault plan uses, kept local so
/// arrival schedules never share a stream with fault rolls.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Shape of the arrival process. All inter-arrival randomness is exponential
/// (Poisson process) so mean rates compose the way queueing theory expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Closed loop (the pre-overload default): the next query arrives the
    /// instant the scheduler is ready for it. No pacing, no backlog.
    Closed,
    /// Open Poisson arrivals with the given mean inter-arrival gap.
    Poisson { mean: Duration },
    /// Arrivals land in back-to-back groups of `burst` (zero intra-burst
    /// spacing); bursts are separated by `lull` plus an exponential gap.
    Bursty { mean: Duration, burst: usize, lull: Duration },
    /// Poisson background traffic, except arrivals `at .. at + size` all
    /// land at the same instant (and, via [`ArrivalPlan::target`], all aim
    /// at the hot cluster 0): a flash crowd on one representative.
    FlashCrowd { mean: Duration, at: usize, size: usize },
}

/// A seeded arrival schedule plus a Zipf cluster-skew generator for
/// synthesising overload workloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPlan {
    pub seed: u64,
    pub process: ArrivalProcess,
    /// Zipf exponent for [`target`](Self::target); `<= 0` means uniform.
    pub zipf_skew: f64,
}

impl ArrivalPlan {
    /// The inert plan: closed loop, no skew. This is the config default, so
    /// every pre-overload serving path behaves exactly as before.
    pub fn closed() -> Self {
        ArrivalPlan { seed: 0, process: ArrivalProcess::Closed, zipf_skew: 0.0 }
    }

    pub fn is_open(&self) -> bool {
        self.process != ArrivalProcess::Closed
    }

    /// Fresh clock over this plan's schedule, starting at arrival 0.
    pub fn clock(&self) -> ArrivalClock {
        ArrivalClock { plan: *self, i: 0, t: 0.0 }
    }

    /// Derive the plan for stream `s` of a multi-stream fleet: same process,
    /// decorrelated seed, so streams don't burst in lock-step unless the
    /// caller wants them to (pass the same plan to every stream manually).
    pub fn stream_plan(&self, s: usize) -> ArrivalPlan {
        ArrivalPlan { seed: splitmix64(self.seed ^ 0x5357_4d00 ^ s as u64), ..*self }
    }

    /// Uniform in (0, 1], pure in `(seed, salt, i)`.
    fn unit(&self, salt: u64, i: u64) -> f64 {
        let r = splitmix64(self.seed ^ salt ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        ((r >> 11) as f64 + 1.0) / 9_007_199_254_740_992.0
    }

    /// Exponential inter-arrival gap for arrival `i`.
    fn gap(&self, mean: Duration, i: u64) -> f64 {
        -mean.as_secs_f64() * self.unit(0x4152_5256, i).ln()
    }

    /// Which of `n` clusters/groups arrival `i` aims at: Zipf(`zipf_skew`)
    /// over ranks, except a flash crowd always hammers the hot cluster 0.
    /// Workload builders use this to synthesise skewed query streams.
    pub fn target(&self, i: usize, n: usize) -> usize {
        if n <= 1 {
            return 0;
        }
        if let ArrivalProcess::FlashCrowd { at, size, .. } = self.process {
            if i >= at && i < at.saturating_add(size) {
                return 0;
            }
        }
        let u = self.unit(0x5a49_5046, i as u64);
        if self.zipf_skew <= 0.0 {
            return ((u * n as f64) as usize).min(n - 1);
        }
        let total: f64 = (1..=n).map(|k| (k as f64).powf(-self.zipf_skew)).sum();
        let mut acc = 0.0;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-self.zipf_skew);
            if u * total <= acc {
                return k;
            }
        }
        n - 1
    }
}

impl Default for ArrivalPlan {
    fn default() -> Self {
        ArrivalPlan::closed()
    }
}

/// Walks a plan's schedule one arrival at a time. `next_offset` returns the
/// absolute offset (from stream start) at which the next query arrives, or
/// `None` for a closed loop (no pacing).
#[derive(Debug, Clone)]
pub struct ArrivalClock {
    plan: ArrivalPlan,
    i: u64,
    t: f64,
}

impl ArrivalClock {
    pub fn next_offset(&mut self) -> Option<Duration> {
        let i = self.i;
        self.i += 1;
        match self.plan.process {
            ArrivalProcess::Closed => return None,
            ArrivalProcess::Poisson { mean } => {
                if i > 0 {
                    self.t += self.plan.gap(mean, i);
                }
            }
            ArrivalProcess::Bursty { mean, burst, lull } => {
                let burst = burst.max(1) as u64;
                if i > 0 && i % burst == 0 {
                    self.t += lull.as_secs_f64() + self.plan.gap(mean, i);
                }
            }
            ArrivalProcess::FlashCrowd { mean, at, size } => {
                let in_crowd =
                    i as usize > at && (i as usize) < at.saturating_add(size.max(1));
                if i > 0 && !in_crowd {
                    self.t += self.plan.gap(mean, i);
                }
            }
        }
        Some(Duration::from_secs_f64(self.t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn offsets(plan: &ArrivalPlan, n: usize) -> Vec<Duration> {
        let mut c = plan.clock();
        (0..n).map(|_| c.next_offset().unwrap()).collect()
    }

    #[test]
    fn closed_clock_yields_none_and_default_is_closed() {
        let plan = ArrivalPlan::default();
        assert!(!plan.is_open());
        assert_eq!(plan.clock().next_offset(), None);
    }

    #[test]
    fn poisson_offsets_are_monotone_deterministic_and_seeded() {
        let plan = ArrivalPlan {
            seed: 7,
            process: ArrivalProcess::Poisson { mean: Duration::from_millis(3) },
            zipf_skew: 0.0,
        };
        let a = offsets(&plan, 32);
        assert_eq!(a[0], Duration::ZERO);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "strictly increasing: {a:?}");
        assert_eq!(a, offsets(&plan, 32), "same seed, same schedule");
        let other = ArrivalPlan { seed: 8, ..plan };
        assert_ne!(a, offsets(&other, 32), "different seed, different schedule");
    }

    #[test]
    fn bursty_packs_arrivals_into_bursts() {
        let plan = ArrivalPlan {
            seed: 11,
            process: ArrivalProcess::Bursty {
                mean: Duration::from_millis(5),
                burst: 4,
                lull: Duration::from_millis(2),
            },
            zipf_skew: 0.0,
        };
        let a = offsets(&plan, 8);
        assert!(a[0] == a[1] && a[1] == a[2] && a[2] == a[3], "{a:?}");
        assert!(a[4] == a[5] && a[5] == a[6] && a[6] == a[7], "{a:?}");
        // inter-burst gap >= lull
        assert!(a[4] - a[3] >= Duration::from_millis(2), "{a:?}");
    }

    #[test]
    fn flash_crowd_lands_at_one_instant_on_the_hot_cluster() {
        let plan = ArrivalPlan {
            seed: 3,
            process: ArrivalProcess::FlashCrowd {
                mean: Duration::from_millis(4),
                at: 3,
                size: 5,
            },
            zipf_skew: 0.0,
        };
        let a = offsets(&plan, 10);
        for i in 3..8 {
            assert_eq!(a[i], a[3], "crowd arrival {i} shares the instant: {a:?}");
            assert_eq!(plan.target(i, 6), 0, "crowd arrival {i} hits cluster 0");
        }
        assert!(a[8] > a[7], "traffic resumes after the crowd: {a:?}");
        assert!(a[3] > a[2], "the crowd itself arrives after background traffic");
    }

    #[test]
    fn zipf_targets_prefer_the_head_and_stay_in_bounds() {
        let plan = ArrivalPlan {
            seed: 19,
            process: ArrivalProcess::Poisson { mean: Duration::from_millis(1) },
            zipf_skew: 1.5,
        };
        let n = 8;
        let mut counts = vec![0usize; n];
        for i in 0..512 {
            let t = plan.target(i, n);
            assert!(t < n);
            counts[t] += 1;
        }
        assert!(counts[0] > counts[n - 1], "head beats tail: {counts:?}");
        assert!(counts[0] > 512 / n, "rank 0 beats the uniform share: {counts:?}");
        assert_eq!(plan.target(5, 0), 0, "degenerate n is clamped");
        assert_eq!(plan.target(5, 1), 0);
    }

    #[test]
    fn stream_plans_decorrelate_but_keep_the_process() {
        let plan = ArrivalPlan {
            seed: 42,
            process: ArrivalProcess::Poisson { mean: Duration::from_millis(2) },
            zipf_skew: 1.0,
        };
        let s1 = plan.stream_plan(1);
        assert_ne!(s1.seed, plan.seed);
        assert_eq!(s1.process, plan.process);
        assert_ne!(offsets(&plan, 16), offsets(&s1, 16));
        assert_ne!(plan.stream_plan(1).seed, plan.stream_plan(2).seed);
    }
}
