//! L3 coordinator — the paper's system contribution (§3), organized as a
//! session-based serving core across three files:
//!
//! * [`session`] — a [`session::ServeSession`] owns the per-query machinery
//!   shared by every serving path: tokenization, prompt construction,
//!   decode, and raw latency splits.
//! * [`pipeline`] — the two in-batch paths. [`Coordinator::serve_baseline`]
//!   is standard graph-based RAG (every query pays a full prefill over its
//!   own retrieved-subgraph prompt); [`Coordinator::serve_subgcache`] is the
//!   SubGCache pipeline (GNN subgraph embeddings → hierarchical clustering →
//!   representative subgraph per cluster → prefill once → per-query `extend`
//!   + decode), now running over the byte-budgeted multi-resident
//!   [`crate::cache::KvCacheManager`] so several representatives stay warm
//!   when the budget allows.
//! * [`online`] — [`Coordinator::serve_online`], the streaming deployment
//!   the paper's §3 sketches: queries arrive one at a time, are matched to
//!   the nearest existing cluster centroid (or open a new cluster), and
//!   reuse a still-resident representative KV cache when one is warm.
//!   [`Coordinator::serve_online_multi`] runs N such streams on worker
//!   threads against ONE [`crate::cache::SharedKvCache`] pool, so identical
//!   representatives across streams are prefilled once and shared
//!   (cross-stream hits surface as [`crate::metrics::BatchMetrics::shared_hits`]
//!   / `dedup_bytes_saved`, and pool totals in [`MultiStreamReport`]).
//!
//! # Latency accounting
//!
//! **In-batch** (App. A.3, documented in EXPERIMENTS.md): one-time
//! cluster-stage work (GNN encoding, clustering, representative merge) is
//! amortized equally across the batch into TTFT; the one-time representative
//! prefill is amortized across its cluster's members into both TTFT and
//! PFTT. With c = m (singleton clusters) the pipeline degenerates to the
//! baseline, which `tests/coordinator_e2e.rs` checks end-to-end.
//!
//! **Online**: nothing is amortized — each query pays, in arrival order,
//! its own retrieval, GNN encoding + centroid assignment, and prompt build.
//! A **hit** (warm representative resident) pays only the question `extend`
//! in PFTT; a **miss** (new cluster, or representative evicted under the
//! byte budget) additionally pays the full representative prefill in PFTT.
//! The hit/miss split is recorded per query
//! ([`crate::metrics::QueryLatency::cache_hit`]) and surfaces as
//! `ttft_hit_ms` / `ttft_miss_ms` on [`crate::metrics::BatchMetrics`].
//!
//! # Pipelined submission over per-lane queues
//!
//! Backend calls go through the runtime's submit/wait ticket API
//! ([`crate::runtime::PendingPrefill`] et al.) against per-lane worker
//! threads ([`crate::runtime::Lane`]): KV-touching LLM calls on one lane,
//! GNN encodes on another. Both SubGCache paths overlap host work with
//! in-flight execution — `serve_subgcache` pipelines its encode stage and
//! tokenizes a cluster's member questions in the shadow of the
//! representative prefill; `serve_online` runs a depth-k scheduler
//! (`ServeConfig::pipeline_depth`): a prep queue of up to k queries
//! (retrieval, GNN packing, question tokenization, refilled in engine
//! shadows), eager encode submission on the GNN lane so query *i+1*'s
//! encode executes under query *i*'s prefill/extend, and a decoupled decode
//! stage whose generate of query *i* overlaps query *i+1*'s extend (they
//! touch different KV entries). To keep PFTT/TTFT semantics honest under
//! that overlap, per-query latencies are composed from component times —
//! host stages timed where they execute and charged to their own query,
//! engine stages charged from the lane-side [`crate::runtime::CallTiming`]
//! (queue seconds + execution span) — never from a wall timer spanning a
//! neighbor's shadow work. The overlap win is reported separately as
//! [`crate::metrics::BatchMetrics::wall_time`] /
//! [`crate::metrics::BatchMetrics::qps`], with
//! [`crate::metrics::BatchMetrics::overlap_time`] sizing how much host prep
//! rode in engine shadows and [`crate::metrics::BatchMetrics::lane_llm`] /
//! [`crate::metrics::BatchMetrics::lane_gnn`] splitting queue/device time
//! per lane.

mod arrival;
mod online;
mod pipeline;
mod session;

pub use arrival::{ArrivalClock, ArrivalPlan, ArrivalProcess};
pub use online::{MultiStreamReport, StreamOutcome};

use crate::cache::{CachePolicy, CacheStats};
use crate::cluster::Linkage;
use crate::graph::Subgraph;
use crate::metrics::BatchMetrics;
use crate::retrieval::Retriever;
use crate::runtime::{ArtifactStore, Backend};

/// Serving configuration (one table cell = one config).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub backbone: String,
    /// target cluster count c (paper Fig. 3 sweeps this).
    pub n_clusters: usize,
    pub linkage: Linkage,
    /// GNN encoder module; `None` derives it from the retriever
    /// (G-Retriever → graph_transformer, GRAG → GAT, per App. A.2).
    pub gnn: Option<String>,
    /// Byte/entry budget for resident representative KV caches.
    pub cache: CachePolicy,
    /// Online path only: squared-Euclidean distance bound for joining an
    /// existing cluster centroid; farther queries open a new cluster.
    /// Negative means "never join" (every query becomes its own cluster).
    pub online_threshold: f32,
    /// Online scheduler lookahead k (≥ 1). k = 1 reproduces the serial
    /// one-query-lookahead pipeline; k ≥ 2 preps up to k queries ahead,
    /// submits their GNN encodes eagerly on the GNN lane, and decouples the
    /// decode stage (query *i*'s generate overlaps query *i+1*'s extend).
    pub pipeline_depth: usize,
    /// Online path only: expire a cluster whose centroid has not matched
    /// (or been opened by) a query for more than this many arrivals,
    /// releasing its KV cache entry with it. `None` keeps every cluster for
    /// the stream's lifetime (the pre-TTL behaviour). A pinned (in-flight)
    /// representative always survives a sweep, however stale.
    pub cluster_ttl: Option<u64>,
    /// Online path only: per-query recovery deadline. A query whose backend
    /// op fails retryably is retried/repaid while its elapsed time stays
    /// under this bound; once exceeded, the next retryable failure becomes
    /// terminal for the stream (and a query that *succeeds* past the bound
    /// is counted in [`crate::metrics::ReliabilityStats::deadline_hits`]).
    /// `None` bounds recovery only by `max_retries`.
    pub deadline: Option<std::time::Duration>,
    /// Online path only: retryable-failure budget per backend stage of one
    /// query (encode / prefill / extend / generate each get their own
    /// budget). 0 disables recovery — the first failure, however
    /// transient, errors the stream (the pre-fault-tolerance behaviour).
    pub max_retries: u32,
    /// Online path only: the overload plane — open-loop arrivals, admission
    /// control / load shedding, and the brownout ladder. Default is fully
    /// inert (closed loop, no shedding), so every pre-overload serving path
    /// behaves exactly as before.
    pub overload: OverloadConfig,
}

/// Overload-plane configuration (`ServeConfig::overload`); see the
/// admission-control section of [`mod@online`]'s docs for the mechanism.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadConfig {
    /// Seeded arrival schedule driving the stream as an open system.
    /// [`ArrivalPlan::closed`] (the default) keeps the closed loop.
    pub arrivals: ArrivalPlan,
    /// Enable admission control: a query predicted (virtual backlog + the
    /// service estimate) to miss `ServeConfig::deadline` is shed at
    /// admission ([`QueryOutcome::Shed`]) instead of burning device time; a
    /// query whose submit is terminally `Overloaded` is shed rather than
    /// erroring the stream. Off by default — overruns are then only counted
    /// after the fact in [`crate::metrics::ReliabilityStats::deadline_hits`].
    pub shed: bool,
    /// Calibrated per-query service-time estimate (e.g. the sim's
    /// `SimLatency` serial sum). Zero falls back to an EWMA of observed
    /// post-admission service times — adaptive, but no longer a pure
    /// function of the arrival plan.
    pub initial_estimate: std::time::Duration,
    /// Deadline safety factor for admission: shed when
    /// `predicted >= deadline * headroom`. `1.0` (default) sheds exactly at
    /// the deadline; `< 1.0` sheds earlier, keeping slack for decode/host
    /// time the estimate does not cover. Non-positive values are treated
    /// as `1.0`.
    pub headroom: f64,
    /// Brownout ladder watermarks; `None` (default) disables degradation.
    pub brownout: Option<BrownoutConfig>,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            arrivals: ArrivalPlan::closed(),
            shed: false,
            initial_estimate: std::time::Duration::ZERO,
            headroom: 1.0,
            brownout: None,
        }
    }
}

/// Brownout ladder thresholds. The ladder level for a query is the number
/// of `backlog_steps` at or below its predicted queueing delay (a zero step
/// is disabled), bumped to at least 1 when a live watermark trips. Levels
/// are cumulative — level 2 also applies level 1's degradation:
///
/// 1. clamp the pipeline lookahead to 1 (serial scheduling),
/// 2. suspend new-cluster opens — join the nearest live representative
///    (answer flagged degraded) or shed if none exists,
/// 3. cap generate length at `gen_cap` tokens.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrownoutConfig {
    /// Predicted-wait thresholds for ladder levels 1..=3. A level engages
    /// when the virtual-backlog wait reaches its step; `Duration::ZERO`
    /// disables that step.
    pub backlog_steps: [std::time::Duration; 3],
    /// Live LLM-lane queue depth at which level >= 1 engages regardless of
    /// the virtual backlog. `None` disables.
    pub depth_watermark: Option<usize>,
    /// Rolling p95 response time (last 32 served queries) at which
    /// level >= 1 engages. `None` disables.
    pub p95_watermark: Option<std::time::Duration>,
    /// Generate-length cap applied at level 3 (clamped to >= 1).
    pub gen_cap: usize,
}

/// Why a query was shed ([`QueryOutcome::Shed`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Admission control predicted a `ServeConfig::deadline` miss.
    Deadline,
    /// A backend submit stayed `Overloaded` (full bounded queue or open
    /// circuit breaker) past the retry budget.
    Overloaded,
    /// Brownout level >= 2 suspended new-cluster opens and no live
    /// representative existed to degrade to.
    Brownout,
}

/// Per-query disposition of the online scheduler, in arrival order
/// (`ServeReport::outcomes`). Every arrival gets exactly one outcome;
/// `Served` queries also appear in `ServeReport::results`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryOutcome {
    Served { id: usize },
    Shed { id: usize, reason: ShedReason },
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            backbone: "llama-3.2-3b-sim".into(),
            n_clusters: 2,
            linkage: Linkage::Ward,
            gnn: None,
            cache: CachePolicy::default(),
            online_threshold: 0.5,
            pipeline_depth: 2,
            cluster_ttl: None,
            deadline: None,
            max_retries: 3,
            overload: OverloadConfig::default(),
        }
    }
}

/// Per-query outcome (drives ACC and the Fig. 5 case study).
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub id: usize,
    pub query: String,
    pub predicted: String,
    pub gold: String,
    pub correct: bool,
    /// cluster index (usize::MAX for the baseline path).
    pub cluster: usize,
    pub retrieved: Subgraph,
}

/// Full result of serving one workload (batch or stream).
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub metrics: BatchMetrics,
    pub results: Vec<QueryResult>,
    pub cluster_sizes: Vec<usize>,
    /// representative subgraph (nodes, edges) per cluster.
    pub representative_sizes: Vec<(usize, usize)>,
    /// Online path only: clusters reclaimed by the TTL sweep
    /// (`ServeConfig::cluster_ttl`). Their sizes stay in `cluster_sizes`.
    pub expired_clusters: usize,
    pub cache: CacheStats,
    /// Online path only: per-arrival disposition (served vs shed, with the
    /// shed reason), in arrival order. Empty for the in-batch paths.
    pub outcomes: Vec<QueryOutcome>,
}

impl ServeReport {
    pub fn acc(&self) -> f64 {
        self.metrics.acc()
    }
}

/// Greedy next-token choice over a logits row.
///
/// Total order made explicit: the highest non-NaN value wins and ties break
/// to the lowest index; NaN entries are skipped entirely. An empty or
/// all-NaN slice returns 0 (a safe pad/BOS id) instead of panicking — a
/// degenerate logits row must fail one answer, not the process.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in logits.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map_or(0, |(i, _)| i as i32)
}

/// The serving coordinator. Owns configuration and the serving pipelines;
/// borrows the execution [`Backend`] (the PJRT engine in production, the
/// deterministic sim in scheduling tests) so several coordinators
/// (backbones) can share it.
pub struct Coordinator<'e> {
    pub(crate) store: ArtifactStore,
    pub(crate) engine: &'e dyn Backend,
    pub(crate) cfg: ServeConfig,
}

impl<'e> Coordinator<'e> {
    pub fn new(store: &ArtifactStore, engine: &'e dyn Backend, cfg: ServeConfig)
               -> anyhow::Result<Self> {
        // fail fast on bad config: the backbone must exist AND carry LLM KV
        // geometry — otherwise the byte budget would silently size every
        // cache entry at 0 and measure nothing.
        let module = store.manifest().module(&cfg.backbone)?;
        anyhow::ensure!(
            module.dims.is_some(),
            "backbone '{}' has no LLM KV geometry (kind: {})",
            cfg.backbone, module.kind
        );
        anyhow::ensure!(cfg.n_clusters >= 1, "n_clusters must be >= 1");
        anyhow::ensure!(cfg.cache.max_entries >= 1, "cache must admit >= 1 entry");
        anyhow::ensure!(cfg.pipeline_depth >= 1, "pipeline_depth must be >= 1");
        Ok(Coordinator { store: store.clone(), engine, cfg })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub(crate) fn session(&self) -> session::ServeSession<'_> {
        session::ServeSession::new(&self.store, self.engine, &self.cfg.backbone)
    }

    pub(crate) fn gnn_module(&self, retriever: &dyn Retriever) -> String {
        self.cfg.gnn.clone().unwrap_or_else(|| {
            if retriever.name() == "grag" { "gat".into() } else { "graph_transformer".into() }
        })
    }

    /// Resident bytes of one representative KV cache (k + v), sized from the
    /// engine's manifest. `new()` guarantees the backbone has KV geometry,
    /// so an error here means the manifest changed underneath us — propagate
    /// it rather than silently sizing entries at 0 (which would disable the
    /// byte budget).
    pub(crate) fn kv_entry_bytes(&self) -> anyhow::Result<usize> {
        self.engine.kv_bytes(&self.cfg.backbone)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.backbone, "llama-3.2-3b-sim");
        assert_eq!(c.linkage, Linkage::Ward);
        assert!(c.gnn.is_none());
        assert!(c.cache.max_entries >= 2, "default policy must be multi-resident");
        assert!(c.online_threshold > 0.0);
        assert!(c.pipeline_depth >= 1, "scheduler needs at least serial lookahead");
        assert!(c.cluster_ttl.is_none(), "TTL is opt-in");
        assert!(c.deadline.is_none(), "deadlines are opt-in");
        assert!(c.max_retries >= 1, "transient faults must be survivable by default");
        // the overload plane must default fully inert: closed loop, no
        // shedding, no brownout — or every pre-overload test would change.
        assert!(!c.overload.arrivals.is_open(), "arrivals default closed");
        assert!(!c.overload.shed, "shedding is opt-in");
        assert!(c.overload.brownout.is_none(), "brownout is opt-in");
        assert_eq!(c.overload.headroom, 1.0);
        assert!(c.overload.initial_estimate.is_zero());
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn argmax_breaks_ties_to_lowest_index() {
        assert_eq!(argmax(&[1.0, 1.0]), 0);
        assert_eq!(argmax(&[-2.0, 7.0, 7.0, 7.0]), 1);
    }

    #[test]
    fn argmax_empty_is_zero_not_panic() {
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_ignores_nan() {
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[3.0, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0, "all-NaN falls back to 0");
    }

    #[test]
    fn argmax_handles_infinities() {
        assert_eq!(argmax(&[f32::NEG_INFINITY, 0.0, f32::INFINITY]), 2);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
    }
}
