//! L3 coordinator — the paper's system contribution (§3).
//!
//! Two serving paths over the same engine and retrievers:
//!
//! * [`Coordinator::serve_baseline`] — standard graph-based RAG: every query
//!   pays a full prefill over its own retrieved-subgraph prompt.
//! * [`Coordinator::serve_subgcache`] — the SubGCache pipeline: GNN subgraph
//!   embeddings → hierarchical clustering → representative subgraph per
//!   cluster → prefill once → per-query `extend` + decode against the shared
//!   KV cache, released cluster-by-cluster.
//!
//! Latency accounting (App. A.3, documented in EXPERIMENTS.md): one-time
//! cluster-stage work (GNN encoding, clustering, representative merge) is
//! amortized equally across the batch into TTFT; the one-time representative
//! prefill is amortized across its cluster's members into both TTFT and
//! PFTT. With c = m (singleton clusters) the pipeline degenerates to the
//! baseline, which `tests/consistency.rs` checks end-to-end.

use crate::cache::{CacheStats, KvCacheManager};
use crate::cluster::{cluster, groups, Linkage};
use crate::data::{answer_correct, Dataset, Query};
use crate::graph::{full_prompt, prefix_text, question_text, Subgraph, TextualGraph};
use crate::metrics::{BatchMetrics, QueryLatency, Timer};
use crate::retrieval::{GraphFeatures, Retriever};
use crate::runtime::{pack_subgraph, ArtifactStore, Engine, KvHandle};
use crate::tokenizer::Tokenizer;

/// Serving configuration (one table cell = one config).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub backbone: String,
    /// target cluster count c (paper Fig. 3 sweeps this).
    pub n_clusters: usize,
    pub linkage: Linkage,
    /// GNN encoder module; `None` derives it from the retriever
    /// (G-Retriever → graph_transformer, GRAG → GAT, per App. A.2).
    pub gnn: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            backbone: "llama-3.2-3b-sim".into(),
            n_clusters: 2,
            linkage: Linkage::Ward,
            gnn: None,
        }
    }
}

/// Per-query outcome (drives ACC and the Fig. 5 case study).
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub id: usize,
    pub query: String,
    pub predicted: String,
    pub gold: String,
    pub correct: bool,
    /// cluster index (usize::MAX for the baseline path).
    pub cluster: usize,
    pub retrieved: Subgraph,
}

/// Full result of serving one in-batch workload.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub metrics: BatchMetrics,
    pub results: Vec<QueryResult>,
    pub cluster_sizes: Vec<usize>,
    /// representative subgraph (nodes, edges) per cluster.
    pub representative_sizes: Vec<(usize, usize)>,
    pub cache: CacheStats,
}

impl ServeReport {
    pub fn acc(&self) -> f64 {
        self.metrics.acc()
    }
}

/// Greedy next-token choice over a logits row.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// The serving coordinator. Owns prompt construction and the two pipelines;
/// borrows the engine so several coordinators (backbones) can share it.
pub struct Coordinator<'e> {
    store: ArtifactStore,
    engine: &'e Engine,
    cfg: ServeConfig,
}

impl<'e> Coordinator<'e> {
    pub fn new(store: &ArtifactStore, engine: &'e Engine, cfg: ServeConfig)
               -> anyhow::Result<Self> {
        store.manifest().module(&cfg.backbone)?; // fail fast on bad config
        anyhow::ensure!(cfg.n_clusters >= 1, "n_clusters must be >= 1");
        Ok(Coordinator { store: store.clone(), engine, cfg })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    fn tok(&self) -> &Tokenizer {
        self.store.tokenizer()
    }

    fn gnn_module(&self, retriever: &dyn Retriever) -> String {
        self.cfg.gnn.clone().unwrap_or_else(|| {
            if retriever.name() == "grag" { "gat".into() } else { "graph_transformer".into() }
        })
    }

    // -- prompt construction -------------------------------------------------

    /// Prefix tokens: [BOS] + verbalized subgraph, padded to S.
    fn prefix_tokens(&self, g: &TextualGraph, sg: &Subgraph) -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let text = prefix_text(g, sg, Some(c.max_prefix));
        let mut ids = Vec::with_capacity(c.max_seq);
        ids.push(c.bos_id);
        self.tok().encode_into(&text, &mut ids);
        ids.truncate(c.max_seq - c.max_q - c.max_gen);
        let plen = ids.len();
        ids.resize(c.max_seq, c.pad_id);
        (ids, plen)
    }

    /// Full baseline prompt tokens: [BOS] + prefix + question, padded to S.
    fn full_tokens(&self, g: &TextualGraph, sg: &Subgraph, qtext: &str)
                   -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let text = full_prompt(g, sg, qtext, Some(c.max_prefix));
        let mut ids = Vec::with_capacity(c.max_seq);
        ids.push(c.bos_id);
        self.tok().encode_into(&text, &mut ids);
        ids.truncate(c.max_seq - c.max_gen);
        let plen = ids.len();
        ids.resize(c.max_seq, c.pad_id);
        (ids, plen)
    }

    /// Question tokens padded to Q.
    fn question_tokens(&self, qtext: &str) -> (Vec<i32>, usize) {
        let c = self.store.constants();
        let mut ids = Vec::with_capacity(c.max_q);
        self.tok().encode_into(&question_text(qtext), &mut ids);
        ids.truncate(c.max_q);
        let qlen = ids.len();
        ids.resize(c.max_q, c.pad_id);
        (ids, qlen)
    }

    fn decode_answer(&self, first: i32, gen: &[i32]) -> String {
        debug_assert!(gen.first().copied() == Some(first));
        self.tok().decode(gen)
    }

    // -- baseline pipeline ---------------------------------------------------

    /// Standard graph-based RAG: retrieve → verbalize → full prefill → decode,
    /// independently per query (Fig. 1a).
    pub fn serve_baseline(&self, ds: &Dataset, queries: &[&Query],
                          retriever: &dyn Retriever) -> anyhow::Result<ServeReport> {
        self.engine.warmup(&self.cfg.backbone)?;
        let feats = GraphFeatures::build(&ds.graph);
        let mut report = ServeReport::default();
        let mut llm_time = 0.0;

        for q in queries {
            let t_all = Timer::start();
            let sg = retriever.retrieve(&ds.graph, &feats, &q.text);
            let (tokens, plen) = self.full_tokens(&ds.graph, &sg, &q.text);
            let t_prompt_ready = t_all.secs();

            let (kv, logits) = self.engine.prefill(&self.cfg.backbone, &tokens, plen as i32)?;
            let first = argmax(&logits);
            let ttft = t_all.secs();
            let pftt = ttft - t_prompt_ready;

            let gen = self.engine.generate(&self.cfg.backbone, &kv, plen as i32, first)?;
            self.engine.release(kv);
            let rt = t_all.secs();
            llm_time += rt - t_prompt_ready;

            let predicted = self.decode_answer(first, &gen);
            let correct = answer_correct(&predicted, &q.answer);
            report.metrics.per_query.push(QueryLatency { rt, ttft, pftt, correct });
            report.results.push(QueryResult {
                id: q.id,
                query: q.text.clone(),
                predicted,
                gold: q.answer.clone(),
                correct,
                cluster: usize::MAX,
                retrieved: sg,
            });
        }
        report.metrics.llm_time = llm_time;
        Ok(report)
    }

    // -- SubGCache pipeline ---------------------------------------------------

    /// The in-batch SubGCache pipeline (Fig. 1b / §3).
    pub fn serve_subgcache(&self, ds: &Dataset, queries: &[&Query],
                           retriever: &dyn Retriever) -> anyhow::Result<ServeReport> {
        let m = queries.len();
        if m == 0 {
            return Ok(ServeReport::default());
        }
        self.engine.warmup(&self.cfg.backbone)?;
        let gnn = self.gnn_module(retriever);
        self.engine.warmup(&gnn)?;
        let c = *self.store.constants();
        let feats = GraphFeatures::build(&ds.graph);

        // 1) per-query retrieval (charged individually, as in the baseline).
        let mut retrieval_secs = Vec::with_capacity(m);
        let mut subgraphs = Vec::with_capacity(m);
        for q in queries {
            let t = Timer::start();
            subgraphs.push(retriever.retrieve(&ds.graph, &feats, &q.text));
            retrieval_secs.push(t.secs());
        }

        // 2) cluster stage (Fig. 4's red series): GNN encoding + hierarchical
        //    clustering + representative construction. One-time, amortized.
        let t_cluster = Timer::start();
        let mut embs = Vec::with_capacity(m);
        for sg in &subgraphs {
            let p = pack_subgraph(&ds.graph, &feats, sg, c.n_max, c.feat_dim);
            embs.push(self.engine.encode(&gnn, p.x, p.adj, p.mask)?);
        }
        let assignment = cluster(&embs, self.cfg.n_clusters, self.cfg.linkage);
        let clusters = groups(&assignment);
        let representatives: Vec<Subgraph> = clusters
            .iter()
            .map(|members| {
                let parts: Vec<&Subgraph> = members.iter().map(|&i| &subgraphs[i]).collect();
                Subgraph::representative(&parts)
            })
            .collect();
        let cluster_secs = t_cluster.secs();
        let cluster_share = cluster_secs / m as f64;

        // 3) cluster-wise serving with subgraph-level KV cache reuse.
        let mut cache: KvCacheManager<KvHandle> = KvCacheManager::new();
        let mut report = ServeReport::default();
        report.cluster_sizes = clusters.iter().map(|c| c.len()).collect();
        report.representative_sizes = representatives.iter().map(|r| r.len()).collect();
        report.metrics.cluster_time = cluster_secs;
        report.results = Vec::with_capacity(m);
        let mut llm_time = 0.0;
        let mut shared_prefill_total = 0.0;
        let mut slots: Vec<Option<(QueryLatency, QueryResult)>> = (0..m).map(|_| None).collect();

        for (cid, members) in clusters.iter().enumerate() {
            // prefill the representative-subgraph prompt once per cluster.
            let t_prefill = Timer::start();
            let (tokens, plen) = self.prefix_tokens(&ds.graph, &representatives[cid]);
            let (kv, _logits) = self.engine.prefill(&self.cfg.backbone, &tokens, plen as i32)?;
            let prefill_secs = t_prefill.secs();
            shared_prefill_total += prefill_secs;
            let prefill_share = prefill_secs / members.len() as f64;
            if let Some(evicted) = cache.install(cid, kv, 2 * self.kv_bytes()) {
                self.engine.release(evicted);
            }

            for &qi in members {
                let q = queries[qi];
                let t_q = Timer::start();
                let (q_tokens, qlen) = self.question_tokens(&q.text);
                let t_prompt = t_q.secs();

                let kv_cluster = cache
                    .lookup(cid)
                    .ok_or_else(|| anyhow::anyhow!("cluster cache missing"))?;
                let (kv_q, logits) =
                    self.engine.extend(&self.cfg.backbone, kv_cluster, plen as i32, &q_tokens)?;
                let row = &logits[(qlen - 1) * c.vocab..qlen * c.vocab];
                let first = argmax(row);
                let t_first = t_q.secs();

                let gen = self.engine.generate(&self.cfg.backbone, &kv_q,
                                               (plen + qlen) as i32, first)?;
                self.engine.release(kv_q);
                let t_done = t_q.secs();
                llm_time += t_done - t_prompt;

                let pftt = (t_first - t_prompt) + prefill_share;
                let ttft = retrieval_secs[qi] + cluster_share + t_prompt + pftt;
                let rt = ttft + (t_done - t_first);

                let predicted = self.decode_answer(first, &gen);
                let correct = answer_correct(&predicted, &q.answer);
                slots[qi] = Some((
                    QueryLatency { rt, ttft, pftt, correct },
                    QueryResult {
                        id: q.id,
                        query: q.text.clone(),
                        predicted,
                        gold: q.answer.clone(),
                        correct,
                        cluster: cid,
                        retrieved: subgraphs[qi].clone(),
                    },
                ));
            }
            // release before moving to the next cluster (§3.4).
            if let Some(h) = cache.release() {
                self.engine.release(h);
            }
        }

        for s in slots.into_iter() {
            let (lat, res) = s.expect("every query served");
            report.metrics.per_query.push(lat);
            report.results.push(res);
        }
        report.metrics.llm_time = llm_time + shared_prefill_total;
        report.metrics.shared_prefill_time = shared_prefill_total;
        report.cache = cache.stats();
        Ok(report)
    }

    fn kv_bytes(&self) -> usize {
        self.store
            .manifest()
            .module(&self.cfg.backbone)
            .ok()
            .and_then(|m| m.dims)
            .map(|d| d.kv_bytes_each())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.backbone, "llama-3.2-3b-sim");
        assert_eq!(c.linkage, Linkage::Ward);
        assert!(c.gnn.is_none());
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // deterministic tie-break
    }
}
