//! Minimal JSON parser/serializer.
//!
//! serde is not installable in this offline image (DESIGN.md §4), so the
//! dataset/vocab/manifest files are handled by this in-tree implementation.
//! Supports the full JSON grammar we emit from Python (no exotic numbers).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array index access; `Json::Null` when out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

/// Parse error with byte offset for debugging malformed artifacts.
#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { msg: msg.to_string(), offset: self.pos })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(&format!("unexpected byte 0x{c:02x}")),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(&format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("lone high surrogate");
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // re-assemble multi-byte utf8 spans without per-byte pushes
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    if c >= 0x80 {
                        while matches!(self.peek(), Some(n) if n >= 0x80) {
                            self.pos += 1;
                            end += 1;
                        }
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("eof in \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a') as u32 + 10,
                b'A'..=b'F' => (c - b'A') as u32 + 10,
                _ => return self.err("bad hex digit"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

/// Read + parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_into(self, &mut s);
        f.write_str(&s)
    }
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ é 😀"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo wörld ⊕\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld ⊕"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,{"b":"c\nd"}],"e":true,"f":null,"g":-7}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[ ]").unwrap(), Json::Arr(vec![]));
    }
}
