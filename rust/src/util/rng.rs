//! Deterministic RNG (splitmix64 core) — the `rand` crate is not available
//! offline, and every experiment in this repo must be bit-reproducible from
//! a seed anyway (EXPERIMENTS.md records the seeds per table/figure).

/// The splitmix64 finalization mix: the one bit-mixer shared by [`Rng`],
/// the sim backend's hash logits, and the KV cache's view-salted private
/// keys — defined once so a future tweak cannot silently diverge between
/// copies. (Callers add their own golden-ratio increment/salt first.)
pub fn splitmix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Splitmix64-based PRNG. Small state, passes the usual empirical batteries,
/// and trivially seedable from a u64.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        splitmix64(self.state)
    }

    /// Uniform in [0, n) without modulo bias (rejection sampling).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut dedup = s.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 10);
    }
}
