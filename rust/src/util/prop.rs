//! Property-testing harness (proptest is not installable offline).
//!
//! Deterministic seeded case generation with failure reporting that prints
//! the reproducing seed. No shrinking — cases are kept small by construction.
//!
//! ```ignore
//! prop_check(200, |rng| {
//!     let xs = rng.sample_indices(50, rng.below(50));
//!     // ... assert invariant ...
//! });
//! ```

use super::rng::Rng;

/// Run `cases` generated checks. The closure receives a per-case RNG; panics
/// are caught and re-raised with the case seed for reproduction.
pub fn prop_check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check(50, |rng| {
            let n = rng.range(1, 100);
            assert!(rng.below(n) < n);
        });
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn reports_failing_case_with_seed() {
        prop_check(50, |rng| {
            assert!(rng.below(10) < 9, "hit the 1-in-10 failure");
        });
    }
}
