//! Environment substrates: JSON, RNG, CLI parsing, property testing and a
//! statistical bench harness. The offline image only ships the xla crate's
//! vendor set, so these stand in for serde/rand/clap/proptest/criterion
//! (DESIGN.md §4).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
