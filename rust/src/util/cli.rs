//! Tiny CLI argument parser (clap is not installable offline).
//!
//! Supports `--flag`, `--key value`, and `--key=value`; everything else is a
//! positional. Each binary declares its options by querying this by name.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (binaries).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().expect("bad integer option")).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.parse().expect("bad float option")).unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &str) -> Vec<String> {
        self.get_or(name, default).split(',').map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        // note the grammar: a bare `--name` followed by a non-`--` token
        // consumes that token as its value, so flags go last or use `=`.
        let a = args("--n 5 --mode=fast pos1 pos2 --verbose");
        assert_eq!(a.get("n"), Some("5"));
        assert_eq!(a.get("mode"), Some("fast"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn flag_before_positional_binds_as_value() {
        let a = args("--verbose pos");
        assert!(!a.flag("verbose"));
        assert_eq!(a.get("verbose"), Some("pos"));
    }

    #[test]
    fn typed_accessors() {
        let a = args("--count 12 --ratio 0.5 --names a,b,c");
        assert_eq!(a.usize_or("count", 0), 12);
        assert_eq!(a.f64_or("ratio", 1.0), 0.5);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.list_or("names", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn trailing_flag() {
        let a = args("--x 1 --dry-run");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("x"), Some("1"));
    }
}
