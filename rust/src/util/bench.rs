//! Statistical micro-benchmark harness (criterion is not installable
//! offline). Used by `rust/benches/*` with `harness = false`.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; reports mean, median,
//! p95 and standard deviation. Deliberately simple but honest — the paper
//! comparisons in EXPERIMENTS.md cite median values.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  (n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bench {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            budget: Duration::from_secs(3),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, min_iters: 3, max_iters: 50, budget: Duration::from_millis(800),
                results: Vec::new() }
    }

    /// Time `f`, which should perform one complete operation per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters
            || (start.elapsed() < self.budget && samples_ns.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = summarize(name, &mut samples_ns);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

fn summarize(name: &str, samples_ns: &mut [f64]) -> Stats {
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let median = if n % 2 == 1 {
        samples_ns[n / 2]
    } else {
        (samples_ns[n / 2 - 1] + samples_ns[n / 2]) / 2.0
    };
    let p95 = samples_ns[((n as f64 * 0.95) as usize).min(n - 1)];
    let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        stddev_ns: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench { warmup: 1, min_iters: 5, max_iters: 10,
                            budget: Duration::from_millis(50), results: vec![] };
        let s = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.median_ns > 0.0);
        assert!(s.p95_ns >= s.median_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn summarize_median_even_odd() {
        let mut xs = vec![3.0, 1.0, 2.0];
        let s = summarize("t", &mut xs);
        assert_eq!(s.median_ns, 2.0);
        let mut ys = vec![4.0, 1.0, 2.0, 3.0];
        let s = summarize("t", &mut ys);
        assert_eq!(s.median_ns, 2.5);
    }
}
