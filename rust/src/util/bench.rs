//! Statistical micro-benchmark harness (criterion is not installable
//! offline). Used by `rust/benches/*` with `harness = false`.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; reports mean, median,
//! p95 and standard deviation. Deliberately simple but honest — the paper
//! comparisons in EXPERIMENTS.md cite median values.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  (n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bench {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            budget: Duration::from_secs(3),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, min_iters: 3, max_iters: 50, budget: Duration::from_millis(800),
                results: Vec::new() }
    }

    /// Time `f`, which should perform one complete operation per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters
            || (start.elapsed() < self.budget && samples_ns.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = summarize(name, &mut samples_ns);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

// ---------------------------------------------------------------------------
// Bench JSON emission (BENCH_engine.json / BENCH_serving.json share this)
// ---------------------------------------------------------------------------

/// One result row of a bench JSON file: a name plus pre-rendered JSON
/// scalar fields (numbers stay unquoted; the caller formats them).
#[derive(Debug, Clone)]
pub struct JsonRow {
    pub name: String,
    pub fields: Vec<(String, String)>,
}

impl JsonRow {
    pub fn new(name: &str) -> JsonRow {
        JsonRow { name: name.to_string(), fields: Vec::new() }
    }

    /// Add a numeric field (rendered as a bare JSON number).
    pub fn num(mut self, key: &str, value: f64) -> JsonRow {
        let v = if value.is_finite() { format!("{value:.3}") } else { "null".into() };
        self.fields.push((key.to_string(), v));
        self
    }

    /// Add an integer field.
    pub fn int(mut self, key: &str, value: u64) -> JsonRow {
        self.fields.push((key.to_string(), value.to_string()));
        self
    }
}

pub fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl From<&Stats> for JsonRow {
    fn from(r: &Stats) -> JsonRow {
        JsonRow::new(&r.name)
            .int("iters", r.iters as u64)
            .num("median_ns", r.median_ns)
            .num("mean_ns", r.mean_ns)
            .num("p95_ns", r.p95_ns)
            .num("stddev_ns", r.stddev_ns)
    }
}

/// Write a `BENCH_*.json` file in the shared shape:
/// `{"bench": ..., "mode": ..., <extra...>, "results": [{"name": ..., ...}]}`.
/// `extra` values are pre-rendered JSON scalars (numbers unquoted).
pub fn emit_bench_json(path: &str, bench: &str, mode: &str,
                       extra: &[(String, String)], rows: &[JsonRow])
                       -> anyhow::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"{}\",\n  \"mode\": \"{}\",\n",
        json_escape(bench), json_escape(mode)
    ));
    for (k, v) in extra {
        s.push_str(&format!("  \"{}\": {v},\n", json_escape(k)));
    }
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!("    {{\"name\": \"{}\"", json_escape(&r.name)));
        for (k, v) in &r.fields {
            s.push_str(&format!(", \"{}\": {v}", json_escape(k)));
        }
        s.push_str(&format!("}}{}\n", if i + 1 == rows.len() { "" } else { "," }));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)?;
    Ok(())
}

fn summarize(name: &str, samples_ns: &mut [f64]) -> Stats {
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let median = if n % 2 == 1 {
        samples_ns[n / 2]
    } else {
        (samples_ns[n / 2 - 1] + samples_ns[n / 2]) / 2.0
    };
    let p95 = samples_ns[((n as f64 * 0.95) as usize).min(n - 1)];
    let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        stddev_ns: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench { warmup: 1, min_iters: 5, max_iters: 10,
                            budget: Duration::from_millis(50), results: vec![] };
        let s = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.median_ns > 0.0);
        assert!(s.p95_ns >= s.median_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn emit_bench_json_renders_shared_shape() {
        let rows = vec![
            JsonRow::new("a \"quoted\" case").num("wall_s", 1.25).int("queries", 8),
            JsonRow::new("b").num("qps", f64::NAN),
        ];
        let path = std::env::temp_dir().join("subgcache_bench_emit_test.json");
        let path_s = path.to_str().unwrap();
        emit_bench_json(path_s, "serving", "sim-quick",
                        &[("depth".into(), "2".into())], &rows).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(s.contains("\"bench\": \"serving\""));
        assert!(s.contains("\"mode\": \"sim-quick\""));
        assert!(s.contains("\"depth\": 2"));
        assert!(s.contains("\"a \\\"quoted\\\" case\""));
        assert!(s.contains("\"wall_s\": 1.250"));
        assert!(s.contains("\"queries\": 8"));
        assert!(s.contains("\"qps\": null"), "non-finite numbers must not break JSON");
        // it must parse back with the in-tree JSON substrate
        let parsed = crate::util::json::parse(&s).unwrap();
        assert_eq!(parsed.get("results").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn summarize_median_even_odd() {
        let mut xs = vec![3.0, 1.0, 2.0];
        let s = summarize("t", &mut xs);
        assert_eq!(s.median_ns, 2.0);
        let mut ys = vec![4.0, 1.0, 2.0, 3.0];
        let s = summarize("t", &mut ys);
        assert_eq!(s.median_ns, 2.5);
    }
}
