//! GRAG (Hu et al., 2024): retrieve top-k *subgraphs* directly by embedding
//! k-hop ego networks, then prune irrelevant components.
//!
//! Per the paper's configuration (App. A.2): top-k = 3 subgraphs, keeping the
//! top-10 entities within two hops. The ego-network embedding here is the
//! mean of member node text embeddings — a textual proxy for the GNN soft
//! prompt, which is sufficient for ranking (DESIGN.md §4).

use std::collections::BTreeSet;

use super::{top_k_desc, GraphFeatures, Retriever, MAX_RETRIEVED_NODES};
use crate::embed::{cosine, embed_text, FEAT_DIM};
use crate::graph::{Subgraph, TextualGraph};

pub struct GragRetriever {
    /// number of ego subgraphs retrieved (paper: 3).
    pub top_k_subgraphs: usize,
    /// entities kept per retrieval (paper: top-10 within 2 hops).
    pub top_entities: usize,
    /// ego-network radius (paper: 2).
    pub hops: usize,
}

impl Default for GragRetriever {
    fn default() -> Self {
        GragRetriever { top_k_subgraphs: 3, top_entities: 10, hops: 2 }
    }
}

impl GragRetriever {
    fn ego_embedding(&self, feats: &GraphFeatures, members: &BTreeSet<usize>) -> Vec<f32> {
        let mut v = vec![0f32; FEAT_DIM];
        for &n in members {
            for (i, x) in feats.node_emb[n].iter().enumerate() {
                v[i] += x;
            }
        }
        let k = members.len().max(1) as f32;
        v.iter_mut().for_each(|x| *x /= k);
        v
    }
}

impl Retriever for GragRetriever {
    fn name(&self) -> &'static str {
        "grag"
    }

    fn retrieve(&self, g: &TextualGraph, feats: &GraphFeatures, query: &str) -> Subgraph {
        let q_emb = embed_text(query);
        let node_scores: Vec<f32> =
            feats.node_emb.iter().map(|e| cosine(&q_emb, e)).collect();

        // Candidate ego networks around the most similar seeds.
        let seeds = top_k_desc(&node_scores, (2 * self.top_k_subgraphs).min(g.n_nodes()));
        let mut egos: Vec<(f32, BTreeSet<usize>)> = seeds
            .iter()
            .map(|&s| {
                let members = g.k_hop(s, self.hops);
                let emb = self.ego_embedding(feats, &members);
                (cosine(&q_emb, &emb), members)
            })
            .collect();
        egos.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        egos.truncate(self.top_k_subgraphs);

        // Union of retrieved egos, pruned to the top entities by similarity.
        let mut union: BTreeSet<usize> = BTreeSet::new();
        for (_, members) in &egos {
            union.extend(members.iter().copied());
        }
        let mut ranked: Vec<usize> = union.into_iter().collect();
        ranked.sort_by(|&a, &b| {
            node_scores[b].partial_cmp(&node_scores[a]).unwrap().then(a.cmp(&b))
        });
        ranked.truncate(self.top_entities.min(MAX_RETRIEVED_NODES));

        let mut sg = Subgraph::default();
        sg.nodes.extend(ranked.iter().copied());
        // keep every graph edge internal to the kept node set
        for &n in &sg.nodes.clone() {
            for &(ei, v, _) in g.incident(n) {
                if sg.nodes.contains(&v) {
                    sg.edges.insert(ei);
                }
            }
        }
        sg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Edge, Node};
    use crate::retrieval::check_subgraph_valid;
    use crate::util::prop::prop_check;

    fn star_graph() -> TextualGraph {
        // hub 0 with spokes 1..6; a disconnected pair 7-8
        let mut nodes: Vec<Node> = (0..9)
            .map(|i| Node { id: i, name: format!("e{i}"), text: format!("e{i} topic t{}", i % 3) })
            .collect();
        nodes[7].text = "paper about graph caching".into();
        nodes[8].text = "paper about kv reuse".into();
        let mut edges: Vec<Edge> = (1..7)
            .map(|i| Edge { src: 0, dst: i, text: "links".into() })
            .collect();
        edges.push(Edge { src: 7, dst: 8, text: "cites".into() });
        TextualGraph::new("star", nodes, edges).unwrap()
    }

    #[test]
    fn retrieves_relevant_component() {
        let g = star_graph();
        let feats = GraphFeatures::build(&g);
        let sg = GragRetriever::default().retrieve(&g, &feats, "graph caching kv reuse ?");
        assert!(sg.nodes.contains(&7) && sg.nodes.contains(&8), "{:?}", sg.nodes);
        assert!(check_subgraph_valid(&g, &sg));
        // the 7-8 edge must be kept (both endpoints retained)
        assert!(sg.edges.iter().any(|&e| g.edges[e].src == 7));
    }

    #[test]
    fn respects_entity_budget() {
        let g = star_graph();
        let feats = GraphFeatures::build(&g);
        let r = GragRetriever { top_k_subgraphs: 3, top_entities: 4, hops: 2 };
        let sg = r.retrieve(&g, &feats, "e0 t0 ?");
        assert!(sg.nodes.len() <= 4);
        assert!(check_subgraph_valid(&g, &sg));
    }

    #[test]
    fn valid_on_random_graphs_property() {
        prop_check(40, |rng| {
            let n = rng.range(2, 40);
            let m = rng.range(1, 80);
            let g = crate::graph::tests::random_graph(rng, n, m);
            let feats = GraphFeatures::build(&g);
            let r = GragRetriever {
                top_k_subgraphs: rng.range(1, 5),
                top_entities: rng.range(1, 15),
                hops: rng.range(1, 4),
            };
            let sg = r.retrieve(&g, &feats, &format!("n{} ?", rng.below(n)));
            assert!(check_subgraph_valid(&g, &sg));
            assert!(!sg.nodes.is_empty());
        });
    }

    #[test]
    fn deterministic() {
        let g = star_graph();
        let feats = GraphFeatures::build(&g);
        let r = GragRetriever::default();
        assert_eq!(r.retrieve(&g, &feats, "e3 ?"), r.retrieve(&g, &feats, "e3 ?"));
    }
}
