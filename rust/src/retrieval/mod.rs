//! Graph retrieval: the two baseline front-ends the paper plugs SubGCache
//! into — **G-Retriever** (PCST over similarity prizes) and **GRAG**
//! (k-hop ego-network ranking). Both consume hash embeddings of node/edge
//! attribute text (the SentenceBERT substitute, DESIGN.md §4).

mod grag;
mod gretriever;

pub use grag::GragRetriever;
pub use gretriever::GRetriever;

use crate::embed::{embed_text, FEAT_DIM};
use crate::graph::{Subgraph, TextualGraph};

/// Hard cap on retrieved-subgraph node count (the GNN encoder's N_MAX).
pub const MAX_RETRIEVED_NODES: usize = 64;

/// Precomputed text embeddings for a graph (built once per dataset, reused
/// across the whole batch — not on the per-query hot path).
pub struct GraphFeatures {
    pub node_emb: Vec<Vec<f32>>,
    pub edge_emb: Vec<Vec<f32>>,
}

impl GraphFeatures {
    pub fn build(g: &TextualGraph) -> GraphFeatures {
        GraphFeatures {
            node_emb: g.nodes.iter().map(|n| embed_text(&n.text)).collect(),
            edge_emb: g.edges.iter().map(|e| embed_text(&e.text)).collect(),
        }
    }

    pub fn dim(&self) -> usize {
        FEAT_DIM
    }
}

/// A pluggable retriever (the paper's "graph-based RAG framework" axis).
pub trait Retriever: Send + Sync {
    fn name(&self) -> &'static str;

    /// Retrieve the query-relevant subgraph. Must return at most
    /// [`MAX_RETRIEVED_NODES`] nodes and only edges whose endpoints are in
    /// the node set.
    fn retrieve(&self, g: &TextualGraph, feats: &GraphFeatures, query: &str) -> Subgraph;
}

/// Rank indices by descending score (deterministic tie-break by index).
pub(crate) fn top_k_desc(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// Shared invariant check used by tests and debug assertions.
pub fn check_subgraph_valid(g: &TextualGraph, sg: &Subgraph) -> bool {
    sg.nodes.len() <= MAX_RETRIEVED_NODES
        && sg.nodes.iter().all(|&n| n < g.n_nodes())
        && sg.edges.iter().all(|&e| {
            e < g.n_edges()
                && sg.nodes.contains(&g.edges[e].src)
                && sg.nodes.contains(&g.edges[e].dst)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_desc_orders_and_breaks_ties() {
        let s = [0.1f32, 0.9, 0.9, 0.3];
        assert_eq!(top_k_desc(&s, 3), vec![1, 2, 3]);
        assert_eq!(top_k_desc(&s, 10).len(), 4);
    }
}
