//! G-Retriever (He et al., NeurIPS'24): retrieve top-k nodes and edges by
//! query similarity, then connect them with a Prize-Collecting Steiner Tree
//! so the prompt keeps relational context.
//!
//! The original uses the GW-based `pcst_fast`; we implement the standard
//! greedy path-merging approximation: seed the tree at the highest-prize
//! node, then repeatedly attach the next prized node via its BFS shortest
//! path iff collected prize exceeds path cost (edge cost 0.5, the paper's
//! configuration). This preserves what matters downstream — a small
//! *connected* subgraph around the prized elements.

use std::collections::{BTreeSet, HashMap, VecDeque};

use super::{top_k_desc, GraphFeatures, Retriever, MAX_RETRIEVED_NODES};
use crate::embed::{cosine, embed_text};
use crate::graph::{Subgraph, TextualGraph};

pub struct GRetriever {
    /// top-k nodes and edges receiving prizes (paper: k = 3).
    pub top_k: usize,
    /// uniform edge traversal cost (paper: 0.5).
    pub edge_cost: f32,
}

impl Default for GRetriever {
    fn default() -> Self {
        GRetriever { top_k: 3, edge_cost: 0.5 }
    }
}

impl GRetriever {
    /// BFS shortest path from `from` to any node in `targets`; returns the
    /// (node path, edge path) or None. Uniform edge costs make BFS exact.
    fn shortest_path_to_set(
        g: &TextualGraph,
        from: &BTreeSet<usize>,
        target: usize,
    ) -> Option<(Vec<usize>, Vec<usize>)> {
        if from.contains(&target) {
            return Some((vec![], vec![]));
        }
        let mut prev: HashMap<usize, (usize, usize)> = HashMap::new(); // node -> (parent, edge)
        let mut q: VecDeque<usize> = from.iter().copied().collect();
        let mut seen: BTreeSet<usize> = from.clone();
        while let Some(u) = q.pop_front() {
            for &(ei, v, _) in g.incident(u) {
                if seen.insert(v) {
                    prev.insert(v, (u, ei));
                    if v == target {
                        // reconstruct
                        let mut nodes = vec![v];
                        let mut edges = vec![];
                        let mut cur = v;
                        while let Some(&(p, e)) = prev.get(&cur) {
                            edges.push(e);
                            if from.contains(&p) {
                                break;
                            }
                            nodes.push(p);
                            cur = p;
                        }
                        return Some((nodes, edges));
                    }
                    q.push_back(v);
                }
            }
        }
        None
    }
}

impl Retriever for GRetriever {
    fn name(&self) -> &'static str {
        "g-retriever"
    }

    fn retrieve(&self, g: &TextualGraph, feats: &GraphFeatures, query: &str) -> Subgraph {
        let q_emb = embed_text(query);
        let node_scores: Vec<f32> =
            feats.node_emb.iter().map(|e| cosine(&q_emb, e)).collect();
        // Edge relevance mixes the relation text with its endpoint mentions
        // (the query names entities; bare relation text rarely matches).
        let edge_scores: Vec<f32> = g
            .edges
            .iter()
            .enumerate()
            .map(|(ei, e)| {
                let rel = cosine(&q_emb, &feats.edge_emb[ei]);
                let ends = 0.5 * (node_scores[e.src] + node_scores[e.dst]);
                0.5 * rel + 0.5 * ends
            })
            .collect();

        let prized_nodes = top_k_desc(&node_scores, self.top_k.min(g.n_nodes()));
        let prized_edges = top_k_desc(&edge_scores, self.top_k.min(g.n_edges()));

        // PCST approximation: grow a tree from the best node.
        let mut sg = Subgraph::default();
        if let Some(&seed) = prized_nodes.first() {
            sg.nodes.insert(seed);
        }
        // prize of a node = similarity rank weight (k - rank), like the
        // original's rank-based prize assignment.
        for (rank, &n) in prized_nodes.iter().enumerate().skip(1) {
            let prize = (self.top_k - rank) as f32;
            if let Some((path_nodes, path_edges)) =
                Self::shortest_path_to_set(g, &sg.nodes, n)
            {
                let cost = self.edge_cost * path_edges.len() as f32;
                if prize >= cost && sg.nodes.len() + path_nodes.len() <= MAX_RETRIEVED_NODES {
                    sg.nodes.extend(path_nodes);
                    sg.edges.extend(path_edges);
                }
            }
        }
        // prized edges join with their endpoints (if the cap allows).
        for &ei in &prized_edges {
            let e = &g.edges[ei];
            let new_nodes = [e.src, e.dst]
                .iter()
                .filter(|n| !sg.nodes.contains(n))
                .count();
            if sg.nodes.len() + new_nodes <= MAX_RETRIEVED_NODES {
                sg.nodes.insert(e.src);
                sg.nodes.insert(e.dst);
                sg.edges.insert(ei);
            }
        }
        // include edges fully inside the node set that carry prize signal:
        // connect the prized nodes' direct links (bounded, deterministic).
        for &n in &prized_nodes {
            if !sg.nodes.contains(&n) {
                continue;
            }
            for &(ei, v, _) in g.incident(n) {
                if sg.nodes.contains(&v) {
                    sg.edges.insert(ei);
                }
            }
        }
        sg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Edge, Node};
    use crate::retrieval::check_subgraph_valid;
    use crate::util::prop::prop_check;

    fn line_graph(n: usize) -> TextualGraph {
        let nodes = (0..n)
            .map(|i| Node { id: i, name: format!("node{i}"), text: format!("node{i} attr") })
            .collect();
        let edges = (0..n - 1)
            .map(|i| Edge { src: i, dst: i + 1, text: "next to".into() })
            .collect();
        TextualGraph::new("line", nodes, edges).unwrap()
    }

    #[test]
    fn retrieves_query_relevant_nodes() {
        let g = line_graph(8);
        let feats = GraphFeatures::build(&g);
        let sg = GRetriever::default().retrieve(&g, &feats, "what is node3 attr ?");
        assert!(sg.nodes.contains(&3), "expected node3 in {:?}", sg.nodes);
        assert!(check_subgraph_valid(&g, &sg));
    }

    #[test]
    fn output_is_connected_when_paths_exist() {
        let g = line_graph(10);
        let feats = GraphFeatures::build(&g);
        let sg = GRetriever::default().retrieve(&g, &feats, "node2 node5 ?");
        // connectivity check via BFS over the subgraph's own edges
        let nodes: Vec<usize> = sg.nodes.iter().copied().collect();
        if nodes.len() > 1 && !sg.edges.is_empty() {
            let mut seen = BTreeSet::new();
            let mut q = vec![nodes[0]];
            seen.insert(nodes[0]);
            while let Some(u) = q.pop() {
                for &ei in &sg.edges {
                    let e = &g.edges[ei];
                    for (a, b) in [(e.src, e.dst), (e.dst, e.src)] {
                        if a == u && sg.nodes.contains(&b) && seen.insert(b) {
                            q.push(b);
                        }
                    }
                }
            }
            // paths are attached prize-permitting; distant low-prize nodes may
            // stay disconnected (PCST semantics) — require ≥ half reached.
            assert!(seen.len() * 2 >= nodes.len(), "{seen:?} vs {nodes:?}");
        }
    }

    #[test]
    fn respects_node_cap_property() {
        prop_check(40, |rng| {
            let n = rng.range(2, 30);
            let m = rng.range(1, 60);
            let g = crate::graph::tests::random_graph(rng, n, m);
            let feats = GraphFeatures::build(&g);
            let r = GRetriever { top_k: rng.range(1, 6), edge_cost: 0.5 };
            let sg = r.retrieve(&g, &feats, &format!("n{} a{} ?", rng.below(n), rng.below(5)));
            assert!(check_subgraph_valid(&g, &sg));
            assert!(!sg.nodes.is_empty());
        });
    }

    #[test]
    fn deterministic() {
        let g = line_graph(12);
        let feats = GraphFeatures::build(&g);
        let r = GRetriever::default();
        assert_eq!(r.retrieve(&g, &feats, "node4 ?"), r.retrieve(&g, &feats, "node4 ?"));
    }
}
