//! Agglomerative hierarchical clustering over subgraph embeddings
//! (the paper §3.2: Euclidean metric, dendrogram cut at a preset cluster
//! count, five linkage strategies — Table 3).
//!
//! Lance–Williams updates on a dense dissimilarity matrix: O(m³) worst case,
//! which is fine at in-batch scale (m ≤ a few hundred; Fig. 4 measures this
//! stage end-to-end).

use crate::embed::sq_dist;

/// Linkage strategies evaluated in the paper's Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Linkage {
    Ward,
    Single,
    Average,
    Complete,
    Centroid,
}

impl Linkage {
    pub const ALL: [Linkage; 5] =
        [Linkage::Ward, Linkage::Single, Linkage::Average, Linkage::Complete, Linkage::Centroid];

    pub fn name(&self) -> &'static str {
        match self {
            Linkage::Ward => "ward",
            Linkage::Single => "single",
            Linkage::Average => "average",
            Linkage::Complete => "complete",
            Linkage::Centroid => "centroid",
        }
    }

    pub fn parse(s: &str) -> Option<Linkage> {
        Linkage::ALL.iter().copied().find(|l| l.name() == s)
    }

    /// Ward/centroid operate on *squared* Euclidean dissimilarities
    /// (the Lance–Williams recurrences below assume it); the min/max/mean
    /// linkages are metric-agnostic.
    fn squared(&self) -> bool {
        matches!(self, Linkage::Ward | Linkage::Centroid)
    }
}

/// Flat clustering: assign each embedding to one of `c` clusters.
/// Labels are canonicalized by first occurrence (deterministic).
pub fn cluster(embs: &[Vec<f32>], c: usize, linkage: Linkage) -> Vec<usize> {
    let m = embs.len();
    if m == 0 {
        return vec![];
    }
    let c = c.clamp(1, m);

    // dissimilarity matrix
    let mut d = vec![vec![0f32; m]; m];
    for i in 0..m {
        for j in (i + 1)..m {
            let sq = sq_dist(&embs[i], &embs[j]);
            let v = if linkage.squared() { sq } else { sq.sqrt() };
            d[i][j] = v;
            d[j][i] = v;
        }
    }

    let mut active: Vec<bool> = vec![true; m];
    let mut size: Vec<f32> = vec![1.0; m];
    let mut label: Vec<usize> = (0..m).collect(); // representative per point
    let mut n_clusters = m;

    while n_clusters > c {
        // find the closest active pair (deterministic tie-break)
        let (mut bi, mut bj, mut best) = (usize::MAX, usize::MAX, f32::INFINITY);
        for i in 0..m {
            if !active[i] {
                continue;
            }
            for j in (i + 1)..m {
                if !active[j] {
                    continue;
                }
                if d[i][j] < best {
                    best = d[i][j];
                    bi = i;
                    bj = j;
                }
            }
        }
        debug_assert!(bi != usize::MAX);

        // Lance–Williams: merge bj into bi, update distances d[bi][k].
        let (si, sj) = (size[bi], size[bj]);
        for k in 0..m {
            if !active[k] || k == bi || k == bj {
                continue;
            }
            let (dik, djk, dij) = (d[bi][k], d[bj][k], d[bi][bj]);
            let sk = size[k];
            let new = match linkage {
                Linkage::Single => dik.min(djk),
                Linkage::Complete => dik.max(djk),
                Linkage::Average => (si * dik + sj * djk) / (si + sj),
                Linkage::Ward => {
                    let t = si + sj + sk;
                    ((si + sk) * dik + (sj + sk) * djk - sk * dij) / t
                }
                Linkage::Centroid => {
                    let t = si + sj;
                    (si * dik + sj * djk) / t - (si * sj * dij) / (t * t)
                }
            };
            d[bi][k] = new;
            d[k][bi] = new;
        }
        size[bi] += size[bj];
        active[bj] = false;
        for l in label.iter_mut() {
            if *l == bj {
                *l = bi;
            }
        }
        n_clusters -= 1;
    }

    canonicalize(&label)
}

/// Relabel representatives to 0..k-1 by first occurrence.
fn canonicalize(label: &[usize]) -> Vec<usize> {
    let mut map = std::collections::HashMap::new();
    label
        .iter()
        .map(|&l| {
            let next = map.len();
            *map.entry(l).or_insert(next)
        })
        .collect()
}

/// Group query indices per cluster label (cluster id -> member indices).
pub fn groups(assignment: &[usize]) -> Vec<Vec<usize>> {
    let k = assignment.iter().copied().max().map_or(0, |m| m + 1);
    let mut out = vec![Vec::new(); k];
    for (i, &a) in assignment.iter().enumerate() {
        out[a].push(i);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn blobs() -> Vec<Vec<f32>> {
        // two well-separated 2-d blobs of 4 points each
        let mut v = Vec::new();
        for i in 0..4 {
            v.push(vec![0.0 + 0.01 * i as f32, 0.0]);
        }
        for i in 0..4 {
            v.push(vec![10.0 + 0.01 * i as f32, 10.0]);
        }
        v
    }

    #[test]
    fn separates_blobs_all_linkages() {
        for l in Linkage::ALL {
            let a = cluster(&blobs(), 2, l);
            assert_eq!(a[..4], [a[0]; 4][..], "{l:?}");
            assert_eq!(a[4..], [a[4]; 4][..], "{l:?}");
            assert_ne!(a[0], a[4], "{l:?}");
        }
    }

    #[test]
    fn c_equals_m_is_singletons() {
        let e = blobs();
        let a = cluster(&e, e.len(), Linkage::Ward);
        let mut sorted = a.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), e.len());
    }

    #[test]
    fn c_one_is_single_cluster() {
        let a = cluster(&blobs(), 1, Linkage::Average);
        assert!(a.iter().all(|&x| x == 0));
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(cluster(&[], 3, Linkage::Ward), Vec::<usize>::new());
        assert_eq!(cluster(&[vec![1.0]], 3, Linkage::Ward), vec![0]);
    }

    #[test]
    fn partition_property() {
        prop_check(60, |rng| {
            let m = rng.range(1, 25);
            let dim = rng.range(1, 6);
            let embs: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            let c = rng.range(1, m + 1);
            let linkage = *rng.choose(&Linkage::ALL);
            let a = cluster(&embs, c, linkage);
            assert_eq!(a.len(), m);
            let k = a.iter().copied().max().unwrap() + 1;
            assert_eq!(k, c.min(m), "wanted {c} clusters, got {k} ({linkage:?})");
            // labels are contiguous 0..k and canonical by first occurrence
            let mut seen = vec![false; k];
            let mut next = 0usize;
            for &l in &a {
                assert!(l < k);
                if !seen[l] {
                    assert_eq!(l, next, "non-canonical labels {a:?}");
                    seen[l] = true;
                    next += 1;
                }
            }
        });
    }

    #[test]
    fn identical_points_merge_first() {
        let mut e = vec![vec![5.0f32, 5.0]; 3];
        e.push(vec![100.0, 100.0]);
        for l in Linkage::ALL {
            let a = cluster(&e, 2, l);
            assert_eq!(a[0], a[1]);
            assert_eq!(a[1], a[2]);
            assert_ne!(a[0], a[3]);
        }
    }

    #[test]
    fn groups_inverts_assignment() {
        let a = vec![0, 1, 0, 2, 1];
        let g = groups(&a);
        assert_eq!(g, vec![vec![0, 2], vec![1, 4], vec![3]]);
        for (cid, members) in g.iter().enumerate() {
            for &m in members {
                assert_eq!(a[m], cid);
            }
        }
    }

    #[test]
    fn ward_prefers_balanced_merges() {
        // A classic ward behaviour: merging into big clusters is penalized.
        // points: tight pair far from a third point
        let e = vec![vec![0.0f32], vec![0.1], vec![0.2], vec![9.0]];
        let a = cluster(&e, 2, Linkage::Ward);
        assert_eq!(a[0], a[1]);
        assert_eq!(a[1], a[2]);
        assert_ne!(a[3], a[0]);
    }

    #[test]
    fn linkage_parse_roundtrip() {
        for l in Linkage::ALL {
            assert_eq!(Linkage::parse(l.name()), Some(l));
        }
        assert_eq!(Linkage::parse("bogus"), None);
    }
}
