//! Textual graph store, subgraphs, representative-subgraph merging, and the
//! canonical verbalizer (exact mirror of `python/compile/verbalize.py`,
//! pinned by `artifacts/golden/verbalize.json`).

use std::collections::BTreeSet;

use crate::tokenizer::split_text;

/// A node of the textual graph: `name` is the entity mention used in edge
/// clauses, `text` the full attribute string used as its own clause.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub text: String,
}

/// A directed, attributed edge (attribute = relation phrase).
#[derive(Debug, Clone, PartialEq)]
pub struct Edge {
    pub src: usize,
    pub dst: usize,
    pub text: String,
}

/// The external knowledge graph G.
#[derive(Debug, Clone, Default)]
pub struct TextualGraph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    /// adjacency: for each node, (edge index, neighbor, outgoing?) triples.
    adj: Vec<Vec<(usize, usize, bool)>>,
}

impl TextualGraph {
    pub fn new(name: &str, nodes: Vec<Node>, edges: Vec<Edge>) -> anyhow::Result<Self> {
        for (i, n) in nodes.iter().enumerate() {
            anyhow::ensure!(n.id == i, "node ids must be contiguous (got {} at {i})", n.id);
        }
        let mut adj = vec![Vec::new(); nodes.len()];
        for (ei, e) in edges.iter().enumerate() {
            anyhow::ensure!(e.src < nodes.len() && e.dst < nodes.len(),
                            "edge {ei} out of range");
            adj[e.src].push((ei, e.dst, true));
            adj[e.dst].push((ei, e.src, false));
        }
        Ok(TextualGraph { name: name.to_string(), nodes, edges, adj })
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Incident edges of `node`: (edge index, neighbor id, outgoing?).
    pub fn incident(&self, node: usize) -> &[(usize, usize, bool)] {
        &self.adj[node]
    }

    /// Undirected k-hop neighborhood node set of a seed.
    pub fn k_hop(&self, seed: usize, k: usize) -> BTreeSet<usize> {
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        seen.insert(seed);
        let mut frontier = vec![seed];
        for _ in 0..k {
            let mut next = Vec::new();
            for &u in &frontier {
                for &(_, v, _) in &self.adj[u] {
                    if seen.insert(v) {
                        next.push(v);
                    }
                }
            }
            frontier = next;
        }
        seen
    }
}

/// A retrieved subgraph: sorted node/edge id sets over a `TextualGraph`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Subgraph {
    pub nodes: BTreeSet<usize>,
    pub edges: BTreeSet<usize>,
}

impl Subgraph {
    pub fn from_parts(nodes: impl IntoIterator<Item = usize>,
                      edges: impl IntoIterator<Item = usize>) -> Self {
        Subgraph { nodes: nodes.into_iter().collect(), edges: edges.into_iter().collect() }
    }

    /// Close the node set over edge endpoints (every edge's ends included).
    pub fn close_over(&mut self, g: &TextualGraph) {
        for &ei in &self.edges {
            self.nodes.insert(g.edges[ei].src);
            self.nodes.insert(g.edges[ei].dst);
        }
    }

    /// Union-merge (the paper's representative-subgraph construction §3.3).
    pub fn union(&mut self, other: &Subgraph) {
        self.nodes.extend(other.nodes.iter().copied());
        self.edges.extend(other.edges.iter().copied());
    }

    /// Merge many retrieved subgraphs into the representative subgraph.
    pub fn representative(parts: &[&Subgraph]) -> Subgraph {
        let mut out = Subgraph::default();
        for p in parts {
            out.union(p);
        }
        out
    }

    pub fn is_superset_of(&self, other: &Subgraph) -> bool {
        other.nodes.is_subset(&self.nodes) && other.edges.is_subset(&self.edges)
    }

    pub fn len(&self) -> (usize, usize) {
        (self.nodes.len(), self.edges.len())
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty() && self.edges.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Verbalizer (canonical; mirrors python/compile/verbalize.py)
// ---------------------------------------------------------------------------

/// Count tokens of one clause (clause tokens + its trailing ";").
fn clause_cost(clause: &str) -> usize {
    split_text(clause).len() + 1
}

/// Verbalize a subgraph into the canonical prompt prefix. `max_tokens`
/// bounds the word-token count (including the leading "graph :"), dropping
/// whole clauses from the tail like the Python reference.
pub fn prefix_text(g: &TextualGraph, sg: &Subgraph, max_tokens: Option<usize>) -> String {
    let mut out = String::from("graph :");
    let mut used = 2usize;
    let mut push = |clause: &str, used: &mut usize, out: &mut String| -> bool {
        let cost = clause_cost(clause);
        if let Some(m) = max_tokens {
            if *used + cost > m {
                return false;
            }
        }
        out.push(' ');
        out.push_str(clause);
        out.push_str(" ;");
        *used += cost;
        true
    };
    for &ni in &sg.nodes {
        if !push(&g.nodes[ni].text, &mut used, &mut out) {
            return out;
        }
    }
    // edges sorted by (src, dst) — BTreeSet gives edge-id order, so re-sort.
    let mut eids: Vec<usize> = sg.edges.iter().copied().collect();
    eids.sort_by_key(|&ei| (g.edges[ei].src, g.edges[ei].dst));
    for ei in eids {
        let e = &g.edges[ei];
        let clause = format!("{} {} {}", g.nodes[e.src].name, e.text, g.nodes[e.dst].name);
        if !push(&clause, &mut used, &mut out) {
            return out;
        }
    }
    out
}

/// The query suffix appended after the (possibly cached) prefix.
pub fn question_text(query_text: &str) -> String {
    format!(" question : {query_text} answer :")
}

/// Full baseline prompt = prefix ⊕ question.
pub fn full_prompt(g: &TextualGraph, sg: &Subgraph, query_text: &str,
                   max_prefix_tokens: Option<usize>) -> String {
    let mut s = prefix_text(g, sg, max_prefix_tokens);
    s.push_str(&question_text(query_text));
    s
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    pub(crate) fn tiny_graph() -> TextualGraph {
        TextualGraph::new(
            "t",
            vec![
                Node { id: 0, name: "cords".into(), text: "cords color blue".into() },
                Node { id: 1, name: "laptop".into(), text: "laptop".into() },
                Node { id: 2, name: "screen".into(), text: "screen material glass".into() },
            ],
            vec![
                Edge { src: 0, dst: 1, text: "left of".into() },
                Edge { src: 2, dst: 1, text: "above".into() },
            ],
        )
        .unwrap()
    }

    pub(crate) fn random_graph(rng: &mut Rng, n: usize, m: usize) -> TextualGraph {
        let nodes = (0..n)
            .map(|i| Node { id: i, name: format!("n{i}"), text: format!("n{i} attr a{}", i % 5) })
            .collect();
        let edges = (0..m)
            .map(|_| {
                let a = rng.below(n);
                let mut b = rng.below(n);
                if b == a {
                    b = (b + 1) % n;
                }
                Edge { src: a, dst: b, text: format!("rel{}", rng.below(4)) }
            })
            .collect();
        TextualGraph::new("rand", nodes, edges).unwrap()
    }

    #[test]
    fn rejects_bad_edges_and_ids() {
        assert!(TextualGraph::new("x",
            vec![Node { id: 1, name: "a".into(), text: "a".into() }], vec![]).is_err());
        assert!(TextualGraph::new("x",
            vec![Node { id: 0, name: "a".into(), text: "a".into() }],
            vec![Edge { src: 0, dst: 5, text: "r".into() }]).is_err());
    }

    #[test]
    fn k_hop_grows_monotonically() {
        let g = tiny_graph();
        let h0 = g.k_hop(0, 0);
        let h1 = g.k_hop(0, 1);
        let h2 = g.k_hop(0, 2);
        assert_eq!(h0.len(), 1);
        assert!(h0.is_subset(&h1) && h1.is_subset(&h2));
        assert_eq!(h2, [0, 1, 2].into_iter().collect());
    }

    #[test]
    fn prefix_format_matches_reference() {
        let g = tiny_graph();
        let sg = Subgraph::from_parts([0, 1], [0]);
        assert_eq!(prefix_text(&g, &sg, None),
                   "graph : cords color blue ; laptop ; cords left of laptop ;");
    }

    #[test]
    fn question_format() {
        assert_eq!(question_text("x ?"), " question : x ? answer :");
    }

    #[test]
    fn budget_drops_whole_clauses() {
        let g = tiny_graph();
        let sg = Subgraph::from_parts([0, 1, 2], [0, 1]);
        // "graph :"(2) + node0(3+1) + node1(1+1) = 8 tokens; next clause won't fit in 10
        let s = prefix_text(&g, &sg, Some(10));
        assert_eq!(s, "graph : cords color blue ; laptop ;");
        let full = prefix_text(&g, &sg, None);
        assert!(split_text(&full).len() > 10);
    }

    #[test]
    fn representative_is_superset_of_members() {
        prop_check(100, |rng| {
            let g = random_graph(rng, 12, 30);
            let subs: Vec<Subgraph> = (0..rng.range(1, 5))
                .map(|_| {
                    let kn = rng.range(1, 6);
                    let ke = rng.below(8);
                    let mut sg = Subgraph::from_parts(
                        rng.sample_indices(12, kn),
                        rng.sample_indices(30, ke),
                    );
                    sg.close_over(&g);
                    sg
                })
                .collect();
            let refs: Vec<&Subgraph> = subs.iter().collect();
            let rep = Subgraph::representative(&refs);
            for s in &subs {
                assert!(rep.is_superset_of(s));
            }
            // idempotent and commutative under shuffle
            let mut shuffled: Vec<&Subgraph> = subs.iter().collect();
            rng.shuffle(&mut shuffled);
            assert_eq!(rep, Subgraph::representative(&shuffled));
        });
    }

    #[test]
    fn close_over_adds_endpoints() {
        let g = tiny_graph();
        let mut sg = Subgraph::from_parts([], [1]);
        sg.close_over(&g);
        assert!(sg.nodes.contains(&1) && sg.nodes.contains(&2));
    }

    #[test]
    fn verbalize_dedups_and_sorts() {
        let g = tiny_graph();
        let a = prefix_text(&g, &Subgraph::from_parts([2, 0, 2], [1, 0, 1]), None);
        let b = prefix_text(&g, &Subgraph::from_parts([0, 2], [0, 1]), None);
        assert_eq!(a, b);
    }

    #[test]
    fn budget_is_respected_property() {
        prop_check(60, |rng| {
            let g = random_graph(rng, 10, 25);
            let mut sg = Subgraph::from_parts(rng.sample_indices(10, 6),
                                              rng.sample_indices(25, 12));
            sg.close_over(&g);
            let budget = rng.range(2, 60);
            let s = prefix_text(&g, &sg, Some(budget));
            assert!(split_text(&s).len() <= budget.max(2));
            assert!(s.starts_with("graph :"));
        });
    }
}
