//! Word-level tokenizer — byte-for-byte mirror of `python/compile/tokenizer.py`.
//!
//! Rule: lowercase, then emit maximal runs of `[a-z0-9_]` and every other
//! non-whitespace char as its own token. Cross-language equality is pinned
//! by `artifacts/golden/tokenizer.json` (see `tests/golden.rs`).

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::{parse_file, Json};

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;
pub const UNK_ID: i32 = 3;

/// Split text into word tokens (the canonical rule above).
pub fn split_text(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut word = String::new();
    for c in text.chars().flat_map(|c| c.to_lowercase()) {
        if c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' {
            word.push(c);
        } else {
            if !word.is_empty() {
                out.push(std::mem::take(&mut word));
            }
            if !c.is_whitespace() {
                out.push(c.to_string());
            }
        }
    }
    if !word.is_empty() {
        out.push(word);
    }
    out
}

/// Vocabulary-backed tokenizer loaded from `artifacts/vocab.json`.
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    inv: Vec<String>,
}

impl Tokenizer {
    pub fn from_vocab(vocab: HashMap<String, i32>) -> anyhow::Result<Self> {
        for (sp, id) in [("<pad>", PAD_ID), ("<bos>", BOS_ID), ("<eos>", EOS_ID), ("<unk>", UNK_ID)] {
            anyhow::ensure!(vocab.get(sp) == Some(&id), "special {sp} must map to {id}");
        }
        let n = vocab.len();
        let mut inv = vec![String::new(); n];
        for (tok, &id) in &vocab {
            anyhow::ensure!((id as usize) < n, "non-contiguous vocab id {id}");
            inv[id as usize] = tok.clone();
        }
        Ok(Tokenizer { vocab, inv })
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let v = parse_file(path)?;
        let obj = v.as_obj().ok_or_else(|| anyhow::anyhow!("vocab.json: not an object"))?;
        let mut vocab = HashMap::with_capacity(obj.len());
        for (k, id) in obj {
            let id = id.as_i64().ok_or_else(|| anyhow::anyhow!("bad id for {k}"))? as i32;
            vocab.insert(k.clone(), id);
        }
        Self::from_vocab(vocab)
    }

    pub fn len(&self) -> usize {
        self.vocab.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vocab.is_empty()
    }

    /// Vocab size rounded up to a multiple of 64 (matches the lm head).
    pub fn padded_size(&self) -> usize {
        (self.vocab.len() + 63) / 64 * 64
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        split_text(text)
            .iter()
            .map(|t| *self.vocab.get(t).unwrap_or(&UNK_ID))
            .collect()
    }

    /// Append-encode into an existing buffer (hot-path, no realloc churn).
    pub fn encode_into(&self, text: &str, out: &mut Vec<i32>) {
        for t in split_text(text) {
            out.push(*self.vocab.get(&t).unwrap_or(&UNK_ID));
        }
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut words: Vec<&str> = Vec::new();
        for &i in ids {
            if i == EOS_ID {
                break;
            }
            if i == PAD_ID || i == BOS_ID {
                continue;
            }
            words.push(self.inv.get(i as usize).map(|s| s.as_str()).unwrap_or("<unk>"));
        }
        words.join(" ")
    }

    pub fn token(&self, id: i32) -> Option<&str> {
        self.inv.get(id as usize).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        let words = ["<pad>", "<bos>", "<eos>", "<unk>", "?", "blue", "color",
                     "cords", "is", "of", "the", "what"];
        let vocab: HashMap<String, i32> =
            words.iter().enumerate().map(|(i, w)| (w.to_string(), i as i32)).collect();
        Tokenizer::from_vocab(vocab).unwrap()
    }

    #[test]
    fn split_matches_python_rule() {
        assert_eq!(split_text("What is the COLOR, of x_1?"),
                   vec!["what", "is", "the", "color", ",", "of", "x_1", "?"]);
        assert_eq!(split_text(""), Vec::<String>::new());
        assert_eq!(split_text(" \t\n "), Vec::<String>::new());
        assert_eq!(split_text("a-b.c"), vec!["a", "-", "b", ".", "c"]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = tok();
        let ids = t.encode("what is the color of the cords ?");
        assert!(!ids.contains(&UNK_ID));
        assert_eq!(t.decode(&ids), "what is the color of the cords ?");
    }

    #[test]
    fn unknown_words_unk() {
        assert_eq!(tok().encode("zebra"), vec![UNK_ID]);
    }

    #[test]
    fn decode_stops_at_eos_skips_specials() {
        let t = tok();
        let mut ids = vec![BOS_ID];
        ids.extend(t.encode("blue cords"));
        ids.push(EOS_ID);
        ids.extend(t.encode("what"));
        assert_eq!(t.decode(&ids), "blue cords");
    }

    #[test]
    fn rejects_bad_specials() {
        let mut vocab = HashMap::new();
        vocab.insert("<pad>".to_string(), 1);
        assert!(Tokenizer::from_vocab(vocab).is_err());
    }

    #[test]
    fn padded_size_multiple_of_64() {
        let t = tok();
        assert_eq!(t.padded_size() % 64, 0);
        assert!(t.padded_size() >= t.len());
    }

    #[test]
    fn encode_into_appends() {
        let t = tok();
        let mut buf = vec![BOS_ID];
        t.encode_into("what is", &mut buf);
        assert_eq!(buf, vec![BOS_ID, 11, 8]);
    }
}
