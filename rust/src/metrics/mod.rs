//! Serving metrics: the paper's four measures (ACC, RT, TTFT, PFTT) plus the
//! cluster-processing-time breakdown of Fig. 4, with aggregation and table
//! printing used by every table/figure harness.

use std::time::{Duration, Instant};

/// Per-query latency record. All fields in seconds.
///
/// * `rt`   — submit → full answer (paper: Response Time)
/// * `ttft` — submit → first token (includes retrieval, prompt build, the
///            query's *amortized share* of cluster-stage work, and PFTT)
/// * `pftt` — prompt-ready → first token (prefill/extend + first logits;
///            isolates the KV-reuse benefit, per App. A.3)
/// * `cache_hit` — online path only: `Some(true)` if the query's cluster
///            representative KV cache was still resident (warm extend),
///            `Some(false)` if it paid a representative prefill. `None` for
///            the batch paths, where prefills are amortized instead.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueryLatency {
    pub rt: f64,
    pub ttft: f64,
    pub pftt: f64,
    pub correct: bool,
    pub cache_hit: Option<bool>,
}

/// Batch-launch histogram for one lane in one serving run, observed from
/// the per-call [`crate::runtime::BatchInfo`] leader records (exactly one
/// leader per fused device call, so launches are counted once no matter
/// how many members rode them).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchHistogram {
    /// Device launches (fused or solo) this run's calls rode in.
    pub device_calls: u64,
    /// Launches that fused ≥ 2 members into one device call.
    pub fused_calls: u64,
    /// Total members across all launches (= [`LaneTimes::calls`] when every
    /// member of every launch belongs to this run).
    pub members: u64,
    /// Launches whose batch window expired before the batch filled.
    pub window_stalls: u64,
    /// Launch counts by occupancy: slots 0..=7 are batch sizes 1..=8, the
    /// last slot collects 9+.
    pub occupancy: [u64; 9],
}

impl BatchHistogram {
    /// Record one call's batch ride; only leaders mutate the histogram.
    pub fn observe(&mut self, b: &crate::runtime::BatchInfo) {
        if !b.leader {
            return;
        }
        self.device_calls += 1;
        self.members += b.size as u64;
        if b.size > 1 {
            self.fused_calls += 1;
        }
        if b.stalled {
            self.window_stalls += 1;
        }
        let slot = (b.size.max(1) as usize - 1).min(self.occupancy.len() - 1);
        self.occupancy[slot] += 1;
    }

    /// Mean members per device launch (1.0 = batching did nothing).
    pub fn mean_occupancy(&self) -> f64 {
        if self.device_calls == 0 {
            return 0.0;
        }
        self.members as f64 / self.device_calls as f64
    }

    /// Mean occupancy as a fraction of the configured `max_batch`
    /// (0.0 when nothing launched or `max_batch` is 0).
    pub fn fill_ratio(&self, max_batch: usize) -> f64 {
        if max_batch == 0 {
            return 0.0;
        }
        self.mean_occupancy() / max_batch as f64
    }
}

/// Aggregate lane-side timing for one serving run: how long this run's
/// requests sat in one lane's queue and how long the lane spent executing
/// them. Accumulated from the per-call [`crate::runtime::CallTiming`]s, so
/// it stays honest under pipelined submission (both components are measured
/// on the lane worker, never inferred from coordinator wall clocks).
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneTimes {
    /// Calls this run executed on the lane.
    pub calls: u64,
    /// Total submit→pickup seconds (queueing behind earlier lane work).
    /// Excludes time inside an open batch window — that is `window_time` —
    /// so batching never silently inflates cross-stream queue waits.
    pub queue_time: f64,
    /// Total seconds requests sat inside an open batch window waiting for
    /// the fused launch (0 when batching is off).
    pub window_time: f64,
    /// Total lane-side execution seconds, counted once per device launch
    /// (leader members only) so fused calls are never double-counted and
    /// [`BatchMetrics::lane_busy_frac`] stays ≤ 1 relative to wall time.
    pub device_time: f64,
    /// Occupancy/stall histogram over this run's device launches.
    pub batch: BatchHistogram,
    /// Highest queue depth ([`crate::runtime::Backend::queue_depth`])
    /// observed at any sample point during the run.
    pub depth_peak: u64,
    /// Sum of sampled queue depths (mean = `depth_sum / depth_samples`).
    pub depth_sum: u64,
    /// Number of queue-depth samples taken (0 when the serve path never
    /// sampled — e.g. batch paths, which do not poll lane queues).
    pub depth_samples: u64,
}

impl LaneTimes {
    /// Fold one call's timing into the aggregate.
    pub fn add(&mut self, t: &crate::runtime::CallTiming) {
        self.calls += 1;
        self.queue_time += t.queue_secs;
        self.window_time += t.window_secs;
        if t.batch.leader {
            self.device_time += t.device_secs;
        }
        self.batch.observe(&t.batch);
    }

    /// Record one queue-depth gauge reading (sampled by the online serve
    /// paths at admission points, not on a timer, so heavier traffic gets
    /// proportionally more samples).
    pub fn sample_depth(&mut self, depth: usize) {
        let d = depth as u64;
        self.depth_peak = self.depth_peak.max(d);
        self.depth_sum += d;
        self.depth_samples += 1;
    }

    /// Mean sampled queue depth; exactly 0.0 when nothing was sampled.
    pub fn mean_depth(&self) -> f64 {
        if self.depth_samples == 0 {
            return 0.0;
        }
        self.depth_sum as f64 / self.depth_samples as f64
    }

    /// Total lane seconds attributable to this run (queue + window +
    /// execution).
    pub fn total(&self) -> f64 {
        self.queue_time + self.window_time + self.device_time
    }
}

/// Admission-control outcome counters for one serving run: how many
/// queries were admitted versus shed, split by why they were shed. A shed
/// query never touched a lane — shedding happens at admission, before any
/// device work is spent (that is the point).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShedStats {
    /// Queries admitted past the admission controller (served, possibly
    /// degraded). With shedding disabled this equals the offered load.
    pub admitted: u64,
    /// Shed because the admission-time completion estimate already missed
    /// the configured deadline.
    pub shed_deadline: u64,
    /// Shed because the backend reported [`crate::runtime::BackendError::Overloaded`]
    /// (full bounded queue or open circuit breaker) and the retry budget
    /// was exhausted.
    pub shed_overloaded: u64,
    /// Shed by the brownout ladder's deepest step (load shedding as the
    /// last resort past degraded service).
    pub shed_brownout: u64,
}

impl ShedStats {
    /// Total shed queries across all reasons.
    pub fn total_shed(&self) -> u64 {
        self.shed_deadline + self.shed_overloaded + self.shed_brownout
    }

    /// Offered load: everything that arrived at admission.
    pub fn offered(&self) -> u64 {
        self.admitted + self.total_shed()
    }

    /// Fraction of offered load that was shed; exactly 0.0 with no
    /// arrivals (never NaN — these rates land in BENCH_*.json).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            return 0.0;
        }
        self.total_shed() as f64 / offered as f64
    }

    /// Fold another run's counters into this one (fleet aggregation).
    pub fn merge(&mut self, other: &ShedStats) {
        self.admitted += other.admitted;
        self.shed_deadline += other.shed_deadline;
        self.shed_overloaded += other.shed_overloaded;
        self.shed_brownout += other.shed_brownout;
    }
}

/// Fault-tolerance counters for one serving run: what broke, what the
/// recovery machinery did about it, and how long the stream ran degraded.
/// All-zero on a fault-free run — the happy path never touches these
/// beyond the final copy into [`BatchMetrics`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReliabilityStats {
    /// Lane-worker restarts observed by this run (supervisor counter
    /// delta across the run; fleet-wide, not per-stream, when several
    /// streams share one backend).
    pub restarts: u64,
    /// Operations retried or repaid after a retryable backend error
    /// (transient injections and dead-lane recoveries alike).
    pub retries: u64,
    /// Cache entries invalidated because their device KV belonged to a
    /// dead lane incarnation.
    pub quarantined_entries: u64,
    /// Queries whose response time exceeded the configured deadline
    /// (the answer is still served — the deadline bounds *recovery*,
    /// not success).
    pub deadline_hits: u64,
    /// Queries that needed at least one recovery action (a span of
    /// degraded service, however brief).
    pub degraded_spans: u64,
    /// Total seconds spent inside recovery (from first failure detection
    /// to the op's eventual success), summed over degraded spans.
    pub degraded_secs: f64,
    /// Admission-control outcomes (admitted vs shed, by reason). All-zero
    /// with shedding disabled or no overload.
    pub shed: ShedStats,
    /// Times the brownout ladder stepped down at least one level from
    /// healthy (a contiguous degraded-service span; stepping deeper within
    /// one span does not start a new one).
    pub brownout_spans: u64,
    /// Total seconds spent at any brownout level below healthy.
    pub brownout_secs: f64,
    /// Lane circuit-breaker trips observed by this run (backend counter
    /// delta across the run; fleet-wide when streams share a backend).
    pub breaker_trips: u64,
}

impl ReliabilityStats {
    /// True when nothing went wrong and nothing had to recover.
    pub fn is_clean(&self) -> bool {
        *self == ReliabilityStats::default()
    }

    /// Fold another run's counters into this one (fleet aggregation).
    pub fn merge(&mut self, other: &ReliabilityStats) {
        self.restarts += other.restarts;
        self.retries += other.retries;
        self.quarantined_entries += other.quarantined_entries;
        self.deadline_hits += other.deadline_hits;
        self.degraded_spans += other.degraded_spans;
        self.degraded_secs += other.degraded_secs;
        self.shed.merge(&other.shed);
        self.brownout_spans += other.brownout_spans;
        self.brownout_secs += other.brownout_secs;
        self.breaker_trips += other.breaker_trips;
    }
}

/// Batch-level result for one (dataset, method, backbone) cell of a table.
#[derive(Debug, Clone, Default)]
pub struct BatchMetrics {
    pub per_query: Vec<QueryLatency>,
    /// one-time cluster stage (Fig. 4): GNN encoding + clustering +
    /// representative construction, in seconds (0 for the baseline).
    pub cluster_time: f64,
    /// one-time representative prefill total (amortized into ttft/pftt).
    pub shared_prefill_time: f64,
    /// LLM-only time (Fig. 4's blue series).
    pub llm_time: f64,
    /// Wall-clock seconds for the whole workload (set by each serve path).
    /// Under pipelined submission this is where the overlap win shows up:
    /// per-query component times deliberately exclude work done in their
    /// engine shadow, so they stay comparable across serial and pipelined
    /// runs while `wall_time` (and [`BatchMetrics::qps`]) shrink.
    pub wall_time: f64,
    /// Host-side prep seconds that executed in the shadow of an in-flight
    /// engine call. Informational: this work is already charged to its own
    /// query's component times — the field sizes the pipelining headroom.
    pub overlap_time: f64,
    /// Configured scheduler lookahead for this run (1 = serial lookahead,
    /// k ≥ 2 = depth-k prep queue with eager encodes + decoupled decode;
    /// 0 for paths without a pipeline, e.g. the baseline).
    pub pipeline_depth: usize,
    /// LLM-lane (prefill/extend/generate) queue/device totals for this run.
    pub lane_llm: LaneTimes,
    /// GNN-lane (encode) queue/device totals for this run.
    pub lane_gnn: LaneTimes,
    /// Warm hits this stream scored on entries *another* stream installed
    /// in a shared KV-cache pool (subset of the cache hit count; always 0
    /// for single-stream and batch runs). Mirrors
    /// [`crate::cache::CacheStats::shared_hits`] so throughput rows carry
    /// the cross-stream dedup signal without digging into the cache stats.
    pub shared_hits: u64,
    /// Prefill KV bytes this stream did not pay because another stream
    /// already had (sum of entry bytes over `shared_hits`).
    pub dedup_bytes_saved: u64,
    /// Fault-tolerance counters for this run (all-zero when nothing broke).
    pub reliability: ReliabilityStats,
}

impl BatchMetrics {
    pub fn acc(&self) -> f64 {
        if self.per_query.is_empty() {
            return 0.0;
        }
        100.0 * self.per_query.iter().filter(|q| q.correct).count() as f64
            / self.per_query.len() as f64
    }

    fn mean(&self, f: impl Fn(&QueryLatency) -> f64) -> f64 {
        if self.per_query.is_empty() {
            return 0.0;
        }
        self.per_query.iter().map(f).sum::<f64>() / self.per_query.len() as f64
    }

    /// Mean per-query metrics in milliseconds (the units of Tables 2/4/6–8).
    pub fn rt_ms(&self) -> f64 {
        self.mean(|q| q.rt) * 1e3
    }
    pub fn ttft_ms(&self) -> f64 {
        self.mean(|q| q.ttft) * 1e3
    }
    pub fn pftt_ms(&self) -> f64 {
        self.mean(|q| q.pftt) * 1e3
    }

    /// Served queries per wall-clock second (0.0 until `wall_time` is set).
    pub fn qps(&self) -> f64 {
        if self.wall_time > 0.0 {
            self.per_query.len() as f64 / self.wall_time
        } else {
            0.0
        }
    }

    /// Fraction of the run's wall clock one lane spent executing (its
    /// utilization; 0.0 until `wall_time` is set). With two busy lanes the
    /// fractions can sum past 1.0 — that surplus IS the lane-overlap win.
    pub fn lane_busy_frac(&self, lane: crate::runtime::Lane) -> f64 {
        if self.wall_time <= 0.0 {
            return 0.0;
        }
        let lt = match lane {
            crate::runtime::Lane::Llm => &self.lane_llm,
            crate::runtime::Lane::Gnn => &self.lane_gnn,
        };
        lt.device_time / self.wall_time
    }

    // -- online hit/miss split (Table 5) ------------------------------------

    fn mean_where(&self, hit: bool, f: impl Fn(&QueryLatency) -> f64) -> f64 {
        let sel: Vec<f64> = self
            .per_query
            .iter()
            .filter(|q| q.cache_hit == Some(hit))
            .map(f)
            .collect();
        if sel.is_empty() {
            return 0.0;
        }
        sel.iter().sum::<f64>() / sel.len() as f64
    }

    /// Queries served against a warm resident representative cache.
    pub fn hit_count(&self) -> usize {
        self.per_query.iter().filter(|q| q.cache_hit == Some(true)).count()
    }

    /// Queries that paid a representative prefill (new or evicted cluster).
    pub fn miss_count(&self) -> usize {
        self.per_query.iter().filter(|q| q.cache_hit == Some(false)).count()
    }

    /// Mean TTFT (ms) over cache hits; 0.0 when no hits were recorded.
    pub fn ttft_hit_ms(&self) -> f64 {
        self.mean_where(true, |q| q.ttft) * 1e3
    }

    /// Mean TTFT (ms) over cache misses; 0.0 when no misses were recorded.
    pub fn ttft_miss_ms(&self) -> f64 {
        self.mean_where(false, |q| q.ttft) * 1e3
    }

    /// Mean PFTT (ms) over cache hits.
    pub fn pftt_hit_ms(&self) -> f64 {
        self.mean_where(true, |q| q.pftt) * 1e3
    }

    /// Mean PFTT (ms) over cache misses.
    pub fn pftt_miss_ms(&self) -> f64 {
        self.mean_where(false, |q| q.pftt) * 1e3
    }
}

/// Speedup row (the Δ lines in the paper's tables).
#[derive(Debug, Clone, Copy)]
pub struct Delta {
    pub acc_points: f64,
    pub rt_x: f64,
    pub ttft_x: f64,
    pub pftt_x: f64,
}

pub fn delta(base: &BatchMetrics, ours: &BatchMetrics) -> Delta {
    let ratio = |b: f64, o: f64| if o > 0.0 { b / o } else { f64::NAN };
    Delta {
        acc_points: ours.acc() - base.acc(),
        rt_x: ratio(base.rt_ms(), ours.rt_ms()),
        ttft_x: ratio(base.ttft_ms(), ours.ttft_ms()),
        pftt_x: ratio(base.pftt_ms(), ours.pftt_ms()),
    }
}

/// Simple scoped wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn lap(&mut self) -> f64 {
        let d = self.0.elapsed().as_secs_f64();
        self.0 = Instant::now();
        d
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}ms", s * 1e3)
    }
}

// ---------------------------------------------------------------------------
// Table printing
// ---------------------------------------------------------------------------

/// Fixed-width table printer for the paper-style outputs.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Format the method row of a paper table.
pub fn metric_cells(name: &str, m: &BatchMetrics) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{:.2}", m.acc()),
        format!("{:.2}", m.rt_ms()),
        format!("{:.2}", m.ttft_ms()),
        format!("{:.2}", m.pftt_ms()),
    ]
}

/// Format the Δ row of a paper table.
pub fn delta_cells(name: &str, d: &Delta) -> Vec<String> {
    let arrow = |x: f64| {
        if x >= 0.0 {
            format!("↑ {:.2}", x)
        } else {
            format!("↓ {:.2}", -x)
        }
    };
    vec![
        name.to_string(),
        arrow(d.acc_points),
        format!("↑ {:.2}x", d.rt_x),
        format!("↑ {:.2}x", d.ttft_x),
        format!("↑ {:.2}x", d.pftt_x),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bm(rts: &[(f64, bool)]) -> BatchMetrics {
        BatchMetrics {
            per_query: rts
                .iter()
                .map(|&(rt, ok)| QueryLatency {
                    rt, ttft: rt * 0.9, pftt: rt * 0.5, correct: ok,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn acc_and_means() {
        let m = bm(&[(0.1, true), (0.3, false)]);
        assert!((m.acc() - 50.0).abs() < 1e-9);
        assert!((m.rt_ms() - 200.0).abs() < 1e-9);
        assert!((m.ttft_ms() - 180.0).abs() < 1e-6);
        assert!((m.pftt_ms() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = BatchMetrics::default();
        assert_eq!(m.acc(), 0.0);
        assert_eq!(m.rt_ms(), 0.0);
        assert_eq!(m.qps(), 0.0, "no wall_time yet -> no throughput claim");
    }

    #[test]
    fn qps_counts_queries_over_wall_time() {
        let mut m = bm(&[(0.1, true), (0.2, true), (0.3, false), (0.4, true)]);
        m.wall_time = 2.0;
        assert!((m.qps() - 2.0).abs() < 1e-9);
        m.overlap_time = 0.5; // informational only: must not affect qps
        assert!((m.qps() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_wall_runs_never_emit_non_finite_rates() {
        // A populated run whose wall clock never got set (or measured 0 on
        // a coarse timer) must report 0 rates, not inf/NaN — these numbers
        // flow straight into BENCH_*.json, where a bare `inf`/`nan` token
        // poisons every downstream consumer. CI asserts the emitted JSON
        // is inf/NaN-free; this is the unit-level guard.
        let mut m = bm(&[(0.1, true), (0.2, false)]);
        m.lane_llm.add(&crate::runtime::CallTiming {
            queue_secs: 0.1, device_secs: 0.4, ..Default::default()
        });
        m.wall_time = 0.0;
        assert_eq!(m.qps(), 0.0);
        assert_eq!(m.lane_busy_frac(crate::runtime::Lane::Llm), 0.0);
        assert_eq!(m.lane_busy_frac(crate::runtime::Lane::Gnn), 0.0);
        for v in [m.acc(), m.rt_ms(), m.ttft_ms(), m.pftt_ms(), m.qps(),
                  m.ttft_hit_ms(), m.ttft_miss_ms(), m.pftt_hit_ms(),
                  m.pftt_miss_ms()] {
            assert!(v.is_finite(), "zero-wall metric leaked non-finite {v}");
        }
        // the empty run (no queries, no wall, no lane calls) is the
        // degenerate corner every accessor must survive with an exact 0
        let e = BatchMetrics::default();
        for v in [e.acc(), e.rt_ms(), e.ttft_ms(), e.pftt_ms(), e.qps(),
                  e.ttft_hit_ms(), e.ttft_miss_ms(), e.pftt_hit_ms(),
                  e.pftt_miss_ms(),
                  e.lane_busy_frac(crate::runtime::Lane::Llm),
                  e.lane_llm.batch.mean_occupancy(),
                  e.lane_llm.batch.fill_ratio(8)] {
            assert_eq!(v, 0.0, "empty-run metric must be exactly 0");
        }
    }

    #[test]
    fn hit_miss_split() {
        let mut m = BatchMetrics::default();
        for (ttft, hit) in [(0.1, Some(false)), (0.02, Some(true)), (0.04, Some(true))] {
            m.per_query.push(QueryLatency {
                rt: ttft, ttft, pftt: ttft / 2.0, correct: true, cache_hit: hit,
            });
        }
        assert_eq!((m.hit_count(), m.miss_count()), (2, 1));
        assert!((m.ttft_hit_ms() - 30.0).abs() < 1e-9);
        assert!((m.ttft_miss_ms() - 100.0).abs() < 1e-9);
        assert!((m.pftt_miss_ms() - 50.0).abs() < 1e-9);
        // batch-path records (cache_hit: None) stay out of both splits
        m.per_query.push(QueryLatency { rt: 9.0, ttft: 9.0, ..Default::default() });
        assert_eq!((m.hit_count(), m.miss_count()), (2, 1));
        assert!((m.ttft_hit_ms() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn empty_split_is_zero() {
        let m = bm(&[(0.1, true)]);
        assert_eq!(m.hit_count() + m.miss_count(), 0);
        assert_eq!(m.ttft_hit_ms(), 0.0);
        assert_eq!(m.ttft_miss_ms(), 0.0);
    }

    #[test]
    fn lane_times_accumulate_call_timings() {
        let mut lt = LaneTimes::default();
        lt.add(&crate::runtime::CallTiming {
            queue_secs: 0.1, device_secs: 0.4, ..Default::default()
        });
        lt.add(&crate::runtime::CallTiming {
            queue_secs: 0.2, device_secs: 0.3, ..Default::default()
        });
        assert_eq!(lt.calls, 2);
        assert!((lt.queue_time - 0.3).abs() < 1e-12);
        assert!((lt.device_time - 0.7).abs() < 1e-12);
        assert!((lt.total() - 1.0).abs() < 1e-12);
        assert_eq!(lt.batch.device_calls, 2, "solo calls are their own launches");
        assert_eq!(lt.batch.fused_calls, 0);
    }

    #[test]
    fn lane_times_split_window_from_queue_and_count_device_once_per_launch() {
        use crate::runtime::BatchInfo;
        let mut lt = LaneTimes::default();
        // a 3-member fused launch: every member carries the full 0.6 s
        // device span, but only the leader may add it to the aggregate
        for i in 0..3u32 {
            lt.add(&crate::runtime::CallTiming {
                queue_secs: 0.1,
                window_secs: 0.05,
                device_secs: 0.6,
                batch: BatchInfo { size: 3, leader: i == 0, stalled: i == 0 },
            });
        }
        assert_eq!(lt.calls, 3);
        assert!((lt.queue_time - 0.3).abs() < 1e-12, "queue excludes window residency");
        assert!((lt.window_time - 0.15).abs() < 1e-12);
        assert!((lt.device_time - 0.6).abs() < 1e-12, "device counted once per launch");
        assert_eq!(lt.batch.device_calls, 1);
        assert_eq!(lt.batch.fused_calls, 1);
        assert_eq!(lt.batch.members, 3);
        assert_eq!(lt.batch.window_stalls, 1);
        assert_eq!(lt.batch.occupancy[2], 1, "size-3 launch lands in slot 2");
        assert!((lt.batch.mean_occupancy() - 3.0).abs() < 1e-12);
        assert!((lt.batch.fill_ratio(4) - 0.75).abs() < 1e-12);
        assert_eq!(lt.batch.fill_ratio(0), 0.0);
    }

    #[test]
    fn batch_histogram_clamps_oversized_launches_into_last_slot() {
        use crate::runtime::BatchInfo;
        let mut h = BatchHistogram::default();
        h.observe(&BatchInfo { size: 12, leader: true, stalled: false });
        h.observe(&BatchInfo { size: 12, leader: false, stalled: false });
        assert_eq!(h.device_calls, 1, "non-leaders never count");
        assert_eq!(h.occupancy[8], 1);
        assert_eq!(h.mean_occupancy(), 12.0);
    }

    #[test]
    fn lane_busy_frac_needs_wall_time_and_can_sum_past_one() {
        let mut m = BatchMetrics::default();
        m.lane_llm.add(&crate::runtime::CallTiming {
            queue_secs: 0.0, device_secs: 1.5, ..Default::default()
        });
        m.lane_gnn.add(&crate::runtime::CallTiming {
            queue_secs: 0.0, device_secs: 1.0, ..Default::default()
        });
        assert_eq!(m.lane_busy_frac(crate::runtime::Lane::Llm), 0.0, "no wall_time yet");
        m.wall_time = 2.0;
        assert!((m.lane_busy_frac(crate::runtime::Lane::Llm) - 0.75).abs() < 1e-12);
        assert!((m.lane_busy_frac(crate::runtime::Lane::Gnn) - 0.5).abs() < 1e-12);
        // 0.75 + 0.5 > 1.0: both lanes busy at once — the overlap win
        assert!(m.lane_busy_frac(crate::runtime::Lane::Llm)
                + m.lane_busy_frac(crate::runtime::Lane::Gnn) > 1.0);
    }

    #[test]
    fn delta_ratios() {
        let base = bm(&[(1.0, true)]);
        let ours = bm(&[(0.25, true)]);
        let d = delta(&base, &ours);
        assert!((d.rt_x - 4.0).abs() < 1e-9);
        assert_eq!(d.acc_points, 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Model", "ACC"]);
        t.row(&["base".to_string(), "62.00".to_string()]);
        t.row(&["ours+long".to_string(), "64.00".to_string()]);
        let s = t.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Model"));
        assert!(lines[2].contains("62.00"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".to_string()]);
    }

    #[test]
    fn reliability_merge_and_cleanliness() {
        let mut a = ReliabilityStats::default();
        assert!(a.is_clean(), "fresh stats must read as clean");
        let b = ReliabilityStats {
            restarts: 1, retries: 3, quarantined_entries: 2,
            deadline_hits: 1, degraded_spans: 2, degraded_secs: 0.5,
            shed: ShedStats {
                admitted: 10, shed_deadline: 2, shed_overloaded: 1, shed_brownout: 1,
            },
            brownout_spans: 1, brownout_secs: 0.25, breaker_trips: 2,
        };
        a.merge(&b);
        a.merge(&b);
        assert!(!a.is_clean());
        assert_eq!(a.retries, 6);
        assert_eq!(a.restarts, 2);
        assert_eq!(a.degraded_spans, 4);
        assert!((a.degraded_secs - 1.0).abs() < 1e-12);
        assert_eq!(a.shed.admitted, 20);
        assert_eq!(a.shed.total_shed(), 8);
        assert_eq!(a.shed.offered(), 28);
        assert_eq!(a.brownout_spans, 2);
        assert!((a.brownout_secs - 0.5).abs() < 1e-12);
        assert_eq!(a.breaker_trips, 4);
        // a merely-shedding run is NOT clean: shed queries are a service
        // degradation even though nothing crashed
        let only_shed = ReliabilityStats {
            shed: ShedStats { shed_deadline: 1, ..Default::default() },
            ..Default::default()
        };
        assert!(!only_shed.is_clean());
    }

    #[test]
    fn shed_rate_is_finite_for_every_corner() {
        // extends the zero-wall sweep: shed/depth rates flow into
        // BENCH_*.json and must never emit NaN, even with zero arrivals.
        let empty = ShedStats::default();
        assert_eq!(empty.shed_rate(), 0.0);
        assert_eq!(empty.offered(), 0);
        let all_shed = ShedStats { shed_deadline: 4, ..Default::default() };
        assert!((all_shed.shed_rate() - 1.0).abs() < 1e-12);
        let mixed = ShedStats {
            admitted: 6, shed_deadline: 1, shed_overloaded: 2, shed_brownout: 1,
        };
        assert!((mixed.shed_rate() - 0.4).abs() < 1e-12);
        assert!(mixed.shed_rate().is_finite());
        // depth gauge: unsampled means an exact 0.0 mean, never 0/0
        let lt = LaneTimes::default();
        assert_eq!(lt.mean_depth(), 0.0);
        let mut lt = LaneTimes::default();
        lt.sample_depth(3);
        lt.sample_depth(5);
        lt.sample_depth(0);
        assert_eq!(lt.depth_peak, 5);
        assert!((lt.mean_depth() - 8.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn timer_monotone() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let a = t.lap();
        assert!(a >= 0.002);
        assert!(t.secs() < a);
    }
}
