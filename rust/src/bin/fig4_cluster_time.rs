//! Figure 4: cluster processing time (GNN encoding + hierarchical clustering
//! + representative construction) vs LLM response time across cluster
//! counts, per dataset. Reproduces the paper's four observations: minimal
//! overhead (low %), higher cost on the larger graph, non-monotone variation,
//! and LLM time generally rising with c.

use subgcache::harness::{batch_from_env, run_cell, Cell};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let batch = batch_from_env(args.usize_or("batch", 100));
    let backbone = args.get_or("backbone", "llama-3.2-3b-sim");
    let cs: Vec<usize> = args
        .list_or("clusters", "1,2,3,4,5,10,20,30,40,50")
        .iter()
        .map(|s| s.parse().expect("bad --clusters"))
        .collect();

    println!("== Figure 4: cluster processing vs LLM response time (batch = {batch}) ==");
    for dataset in ["scene_graph", "oag"] {
        println!("\n-- dataset: {dataset} --");
        let mut t = Table::new(&["c", "cluster stage (ms)", "LLM time (ms)",
                                 "stage share (%)", "kept to drain", "evictions"]);
        for &c in &cs {
            let mut cell = Cell::new(dataset, "g-retriever", backbone, batch);
            cell.n_clusters = c;
            let r = run_cell(&store, &engine, &cell)?;
            let m = &r.subgcache.metrics;
            let stage_ms = m.cluster_time * 1e3;
            let llm_ms = m.llm_time * 1e3;
            let cache = r.subgcache.cache;
            t.row(&[
                c.to_string(),
                format!("{stage_ms:.1}"),
                format!("{llm_ms:.1}"),
                format!("{:.2}", 100.0 * stage_ms / (stage_ms + llm_ms)),
                // representatives the budget never evicted — they survived
                // until the end-of-batch drain (nothing stays resident
                // across calls; the cache is per-batch).
                format!("{}", cache.prefills - cache.evictions),
                cache.evictions.to_string(),
            ]);
        }
        t.print();
    }
    Ok(())
}
