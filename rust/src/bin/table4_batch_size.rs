//! Table 4: effect of in-batch query size (50/100/150/200) on both datasets
//! with the Llama-3.2-3B-sim backbone. `--cache-entries` bounds how many
//! representative KV caches stay resident (LRU beyond that); the cache
//! summary line under each block shows the resulting hit/eviction picture.
//! `--bench-json [PATH]` additionally emits the wall/qps summaries as
//! `BENCH_serving.json` (same shape as `BENCH_engine.json`) so runs are
//! comparable PR over PR.

use subgcache::harness::{bench_json_from_args, cache_policy_from_args, cache_summary,
                         push_block, run_cell, throughput_summary, Cell, ServingBench,
                         METRIC_HEADER};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let backbone = args.get_or("backbone", "llama-3.2-3b-sim");
    let cache = cache_policy_from_args(&args)?;
    let bench_json = bench_json_from_args(&args);
    let mut bench = ServingBench::new("artifacts");
    let batches: Vec<usize> = args
        .list_or("batches", "50,100,150,200")
        .iter()
        .map(|s| s.parse().expect("bad --batches"))
        .collect();

    println!("== Table 4: in-batch query size sweep (backbone: {backbone}) ==");
    for &batch in &batches {
        for dataset in ["scene_graph", "oag"] {
            println!("\n-- {batch} in-batch queries | dataset: {dataset} --");
            let mut t = Table::new(&METRIC_HEADER);
            let mut summaries = Vec::new();
            for retriever in ["g-retriever", "grag"] {
                let mut cell = Cell::new(dataset, retriever, backbone, batch);
                cell.cache = cache;
                let r = run_cell(&store, &engine, &cell)?;
                let label = if retriever == "g-retriever" { "G-Retriever" } else { "GRAG" };
                push_block(&mut t, label, &r);
                summaries.push(format!("{label}: {} | {}",
                                       cache_summary(&r.subgcache),
                                       throughput_summary(&r.subgcache)));
                bench.push(&format!("table4 {dataset} {label} b={batch} baseline"),
                           &r.baseline);
                bench.push(&format!("table4 {dataset} {label} b={batch} subgcache"),
                           &r.subgcache);
            }
            t.print();
            for s in summaries {
                println!("  {s}");
            }
        }
    }
    if let Some(path) = bench_json {
        bench.emit(&path)?;
        println!("\nwrote {path} ({} rows)", bench.len());
    }
    println!("\nnote: test splits hold 200 queries; batches beyond 200 resample.");
    Ok(())
}
