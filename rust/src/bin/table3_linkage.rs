//! Table 3: linkage-strategy sensitivity — Δ rows (ACC gain and RT/TTFT/PFTT
//! speedups vs the baseline) for all five linkages, both retrievers, both
//! datasets (Llama-3.2-3B-sim backbone, per the paper).

use subgcache::harness::{batch_from_env, run_cell, Cell};
use subgcache::metrics::{delta, Table};
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let batch = batch_from_env(args.usize_or("batch", 100));
    let backbone = args.get_or("backbone", "llama-3.2-3b-sim");

    println!("== Table 3: impact of linkage strategies (batch = {batch}) ==");
    for retriever in ["g-retriever", "grag"] {
        for dataset in ["scene_graph", "oag"] {
            println!("\n-- Δ_{retriever} | dataset: {dataset} --");
            let mut t = Table::new(&["Strategy", "ΔACC", "ΔRT", "ΔTTFT", "ΔPFTT"]);
            for linkage in Linkage::ALL {
                let mut cell = Cell::new(dataset, retriever, backbone, batch);
                cell.linkage = linkage;
                let r = run_cell(&store, &engine, &cell)?;
                let d = delta(&r.baseline.metrics, &r.subgcache.metrics);
                t.row(&[
                    linkage.name().to_string(),
                    format!("{:+.2}", d.acc_points),
                    format!("{:.2}x", d.rt_x),
                    format!("{:.2}x", d.ttft_x),
                    format!("{:.2}x", d.pftt_x),
                ]);
            }
            t.print();
        }
    }
    Ok(())
}
