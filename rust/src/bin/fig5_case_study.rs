//! Figure 5: case study — how a batch of example queries is processed with
//! and without SubGCache: per-query retrieved subgraphs vs clustered
//! representative subgraphs, with the generated answers side by side.

use subgcache::harness::retriever_by_name;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let ds = store.dataset(args.get_or("dataset", "scene_graph"))?;
    let retriever = retriever_by_name(args.get_or("retriever", "g-retriever"))?;
    let n = args.usize_or("n", 6);
    let queries = ds.sample_test(n, args.usize_or("seed", 21) as u64);

    let cfg = ServeConfig { n_clusters: 2, ..Default::default() };
    let coord = Coordinator::new(&store, &engine, cfg)?;

    println!("== Figure 5 case study: {} example queries ==\n", queries.len());
    println!("--- WITHOUT SubGCache: each query processed separately ---");
    let base = coord.serve_baseline(&ds, &queries, retriever.as_ref())?;
    for r in &base.results {
        let (n_nodes, n_edges) = r.retrieved.len();
        println!("q{}: {:?}\n    retrieved subgraph: {} nodes / {} edges\n    \
                  answer: {:?} (gold {:?}) {}",
                 r.id, r.query, n_nodes, n_edges, r.predicted, r.gold,
                 if r.correct { "✓" } else { "✗" });
    }

    println!("\n--- WITH SubGCache: clustered, shared representative subgraphs ---");
    let ours = coord.serve_subgcache(&ds, &queries, retriever.as_ref())?;
    for (cid, size) in ours.cluster_sizes.iter().enumerate() {
        let (rn, re) = ours.representative_sizes[cid];
        println!("cluster {cid}: {size} queries share a representative subgraph \
                  of {rn} nodes / {re} edges");
        for r in ours.results.iter().filter(|r| r.cluster == cid) {
            println!("  q{}: {:?}\n      answer: {:?} (gold {:?}) {}",
                     r.id, r.query, r.predicted, r.gold,
                     if r.correct { "✓" } else { "✗" });
        }
    }
    println!("\nbaseline ACC {:.1}%  |  SubGCache ACC {:.1}%  |  \
              TTFT {:.1} ms → {:.1} ms",
             base.metrics.acc(), ours.metrics.acc(),
             base.metrics.ttft_ms(), ours.metrics.ttft_ms());
    Ok(())
}
