//! Table 1: dataset statistics (nodes / relations / queries + attribute
//! kinds), regenerated from the loaded datasets.

use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    println!("== Table 1: dataset statistics ==\n");
    let mut t = Table::new(&["Dataset", "#Nodes", "#Relations", "#Queries",
                             "Node attr", "Edge attr"]);
    for (name, nattr, eattr) in [
        ("scene_graph", "entity attributes (e.g., color)", "spatial relations"),
        ("oag", "entity name", "relations (e.g., predicates)"),
    ] {
        let ds = store.dataset(name)?;
        t.row(&[
            name.to_string(),
            ds.graph.n_nodes().to_string(),
            ds.graph.n_edges().to_string(),
            ds.queries.len().to_string(),
            nattr.to_string(),
            eattr.to_string(),
        ]);
        // paper check: Table 1 reports 22/147/426 and 1071/2022/3434
        let expect = if name == "scene_graph" { (22, 147, 426) } else { (1071, 2022, 3434) };
        anyhow::ensure!(
            (ds.graph.n_nodes(), ds.graph.n_edges(), ds.queries.len()) == expect,
            "{name}: statistics drifted from the paper's Table 1"
        );
    }
    t.print();
    println!("\nsplits: scene_graph 113/113/200, oag 1617/1617/200 (App. A.1)");
    Ok(())
}
