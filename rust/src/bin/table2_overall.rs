//! Table 2: overall performance — ACC/RT/TTFT/PFTT for {G-Retriever, GRAG}
//! × {baseline, +SubGCache} on both datasets across every LLM backbone.
//!
//! Paper protocol: 100 sampled test queries, Ward linkage, c = 1 (Scene
//! Graph) / 2 (OAG). `SUBGCACHE_BATCH` / `SUBGCACHE_BACKBONES` trim the run.

use subgcache::harness::{batch_from_env, backbones_from_env, push_block, run_cell, Cell,
                         METRIC_HEADER};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let batch = batch_from_env(args.usize_or("batch", 100));
    let backbones = backbones_from_env(&store);

    println!("== Table 2: overall performance (batch = {batch}) ==");
    for backbone in &backbones {
        for dataset in ["scene_graph", "oag"] {
            println!("\n-- backbone: {backbone} | dataset: {dataset} --");
            let mut t = Table::new(&METRIC_HEADER);
            for retriever in ["g-retriever", "grag"] {
                let cell = Cell::new(dataset, retriever, backbone, batch);
                let r = run_cell(&store, &engine, &cell)?;
                let label = if retriever == "g-retriever" { "G-Retriever" } else { "GRAG" };
                push_block(&mut t, label, &r);
            }
            t.print();
        }
    }
    Ok(())
}
