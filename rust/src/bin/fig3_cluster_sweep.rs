//! Figure 3: impact of the cluster number on ACC and TTFT —
//! G-Retriever vs G-Retriever+SubGCache, c ∈ {1..5, 10, 20, 30, 40, 50},
//! both datasets, Llama-3.2-3B-sim. Prints the two series per dataset
//! (the paper's line plots) plus the baseline reference lines.

use subgcache::harness::{batch_from_env, run_cell, Cell};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let batch = batch_from_env(args.usize_or("batch", 100));
    let backbone = args.get_or("backbone", "llama-3.2-3b-sim");
    let cs: Vec<usize> = args
        .list_or("clusters", "1,2,3,4,5,10,20,30,40,50")
        .iter()
        .map(|s| s.parse().expect("bad --clusters"))
        .collect();

    println!("== Figure 3: cluster-number sweep (batch = {batch}, {backbone}) ==");
    for dataset in ["scene_graph", "oag"] {
        println!("\n-- dataset: {dataset} --");
        let mut t = Table::new(&["c", "ACC (%)", "TTFT (s)", "ΔACC vs base", "TTFT speedup"]);
        let mut baseline_acc = 0.0;
        let mut baseline_ttft = 0.0;
        for (i, &c) in cs.iter().enumerate() {
            let mut cell = Cell::new(dataset, "g-retriever", backbone, batch);
            cell.n_clusters = c;
            let r = run_cell(&store, &engine, &cell)?;
            if i == 0 {
                baseline_acc = r.baseline.metrics.acc();
                baseline_ttft = r.baseline.metrics.ttft_ms() / 1e3;
                t.row(&["base".into(), format!("{baseline_acc:.2}"),
                        format!("{baseline_ttft:.3}"), "-".into(), "-".into()]);
            }
            let acc = r.subgcache.metrics.acc();
            let ttft = r.subgcache.metrics.ttft_ms() / 1e3;
            t.row(&[
                c.to_string(),
                format!("{acc:.2}"),
                format!("{ttft:.3}"),
                format!("{:+.2}", acc - baseline_acc),
                format!("{:.2}x", baseline_ttft / ttft),
            ]);
        }
        t.print();
    }
    Ok(())
}
