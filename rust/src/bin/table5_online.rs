//! Table 5 (new scenario, beyond the paper's tables): online (streaming)
//! SubGCache. The batch's queries arrive one at a time; each is matched to
//! the nearest already-seen cluster centroid (within `--threshold`, squared
//! Euclidean over GNN embeddings) and reuses a still-warm representative KV
//! cache when the `--cache-entries`/`--cache-mb` budget kept it resident.
//! `--host-cache-bytes N` adds a host tier under the device budget: an
//! evicted representative demotes to host memory and a later revisit
//! promotes it back with a copy instead of repaying the full prefill.
//! `--disk-cache-bytes N` adds a third tier under that: a host-budget
//! death archives the KV bytes to an on-disk file and a later revisit
//! recalls them disk → host → device — still cheaper than the prefill.
//!
//! The headline columns are the hit/miss TTFT split: a hit pays only the
//! question `extend`, a miss pays the full representative prefill — the
//! online analogue of the paper's baseline-vs-SubGCache gap.
//!
//! Scheduler knobs: `--depth k` sets the pipeline lookahead (k ≥ 2 overlaps
//! query i+1's GNN encode with query i's LLM work and decouples the decode
//! stage), `--ttl N` expires clusters unused for more than N arrivals.
//! `--streams N` (default 1) additionally serves the cell as N concurrent
//! replicated streams over ONE shared KV-cache pool — the cross-stream
//! dedup mode: identical representatives are prefilled once for the whole
//! fleet, and the summary line reports shared hits, dedup bytes saved and
//! pool-lock contention. `--max-batch N --batch-window MS` turn on the
//! LLM-lane micro-batcher (concurrent compatible submissions fuse into one
//! device call; see `runtime` docs) — mostly useful with `--streams > 1`.
//! `--bench-json [PATH]` emits the wall/qps summaries as
//! `BENCH_serving.json` (same shape as `BENCH_engine.json`); rows record
//! the batch config. `--fault-seed/--transient-prob/--spike-prob/--spike-ms`
//! stamp the chaos flags onto every emitted row (see `harness` docs).

use subgcache::harness::{batch_config_from_args, batch_from_env, bench_json_from_args,
                         cache_policy_from_args, cache_summary, fault_flags_present,
                         fault_plan_from_args, multi_serving_row, multi_summary,
                         online_cells, run_multi_online_cell, run_online_cell,
                         throughput_summary, Cell, ServingBench, ONLINE_HEADER};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let batch_cfg = batch_config_from_args(&args)?;
    let engine = Engine::start_with(&store, batch_cfg)?;
    let batch = batch_from_env(args.usize_or("batch", 100));
    let backbone = args.get_or("backbone", "llama-3.2-3b-sim");
    let threshold = args.f64_or("threshold",
                                ServeConfig::default().online_threshold as f64) as f32;
    let cache = cache_policy_from_args(&args)?;
    let depth = args.usize_or("depth", ServeConfig::default().pipeline_depth);
    let ttl: Option<u64> = args.get("ttl").map(|v| v.parse().expect("bad --ttl (arrivals)"));
    let streams = args.usize_or("streams", 1);
    let bench_json = bench_json_from_args(&args);
    let mut bench = ServingBench::new("artifacts");
    bench.set_batch(batch_cfg);
    // `--fault-seed/--transient-prob/--spike-prob/--spike-ms`: stamp the
    // chaos flags onto every emitted row (the PJRT engine itself injects
    // nothing — the stamp keeps row provenance honest when the same flags
    // drive a sim run side by side).
    let fault_plan = fault_plan_from_args(&args)?;
    if fault_flags_present(&args) {
        bench.set_faults(&fault_plan);
    }

    println!("== Table 5: online (streaming) serving \
              (backbone: {backbone}, batch = {batch}, threshold = {threshold}, \
              depth = {depth}, ttl = {ttl:?}, streams = {streams}, \
              max_batch = {}, window = {:.1} ms) ==",
             batch_cfg.max_batch, batch_cfg.max_wait.as_secs_f64() * 1e3);
    for dataset in ["scene_graph", "oag"] {
        println!("\n-- dataset: {dataset} --");
        let mut t = Table::new(&ONLINE_HEADER);
        let mut summaries = Vec::new();
        for retriever in ["g-retriever", "grag"] {
            let mut cell = Cell::new(dataset, retriever, backbone, batch);
            cell.online_threshold = threshold;
            cell.cache = cache;
            cell.pipeline_depth = depth;
            cell.cluster_ttl = ttl;
            let r = run_online_cell(&store, &engine, &cell)?;
            let label = if retriever == "g-retriever" { "G-Retriever" } else { "GRAG" };
            // baseline row: every query is a full prefill, so its TTFT is
            // the natural "all-miss" reference for the online split.
            let m = &r.baseline.metrics;
            t.row(&[
                label.to_string(),
                format!("{:.2}", m.acc()),
                format!("{:.2}", m.rt_ms()),
                format!("{:.2}", m.ttft_ms()),
                "-".into(),
                "-".into(),
                format!("0/{}", m.per_query.len()),
                "-".into(),
            ]);
            t.row(&online_cells(&format!("{label}+SubGCache-online"), &r.online));
            summaries.push(format!(
                "{label}: {} clusters opened ({} expired), {} | {}",
                r.online.cluster_sizes.len(),
                r.online.expired_clusters,
                cache_summary(&r.online),
                throughput_summary(&r.online)
            ));
            bench.push(&format!("table5 {dataset} {label} baseline"), &r.baseline);
            bench.push(&format!("table5 {dataset} {label} online k={depth}"), &r.online);
            if streams > 1 {
                let mr = run_multi_online_cell(&store, &engine, &cell, streams)?;
                summaries.push(format!("{label} {}", multi_summary(&mr.multi)));
                bench.push_row(multi_serving_row(
                    &format!("table5 {dataset} {label} online k={depth} streams={streams}"),
                    &mr.multi,
                ));
            }
        }
        t.print();
        for s in summaries {
            println!("  {s}");
        }
    }
    if let Some(path) = bench_json {
        bench.emit(&path)?;
        println!("\nwrote {path} ({} rows)", bench.len());
    }
    println!("\nnote: misses pay the representative prefill in full (no batch to \
              amortize over); hits extend a warm cache and skip it entirely.");
    Ok(())
}
