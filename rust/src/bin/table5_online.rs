//! Table 5 (new scenario, beyond the paper's tables): online (streaming)
//! SubGCache. The batch's queries arrive one at a time; each is matched to
//! the nearest already-seen cluster centroid (within `--threshold`, squared
//! Euclidean over GNN embeddings) and reuses a still-warm representative KV
//! cache when the `--cache-entries`/`--cache-mb` budget kept it resident.
//!
//! The headline columns are the hit/miss TTFT split: a hit pays only the
//! question `extend`, a miss pays the full representative prefill — the
//! online analogue of the paper's baseline-vs-SubGCache gap.

use subgcache::harness::{batch_from_env, cache_policy_from_args, cache_summary,
                         online_cells, run_online_cell, throughput_summary, Cell,
                         ONLINE_HEADER};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let batch = batch_from_env(args.usize_or("batch", 100));
    let backbone = args.get_or("backbone", "llama-3.2-3b-sim");
    let threshold = args.f64_or("threshold",
                                ServeConfig::default().online_threshold as f64) as f32;
    let cache = cache_policy_from_args(&args)?;

    println!("== Table 5: online (streaming) serving \
              (backbone: {backbone}, batch = {batch}, threshold = {threshold}) ==");
    for dataset in ["scene_graph", "oag"] {
        println!("\n-- dataset: {dataset} --");
        let mut t = Table::new(&ONLINE_HEADER);
        let mut summaries = Vec::new();
        for retriever in ["g-retriever", "grag"] {
            let mut cell = Cell::new(dataset, retriever, backbone, batch);
            cell.online_threshold = threshold;
            cell.cache = cache;
            let r = run_online_cell(&store, &engine, &cell)?;
            let label = if retriever == "g-retriever" { "G-Retriever" } else { "GRAG" };
            // baseline row: every query is a full prefill, so its TTFT is
            // the natural "all-miss" reference for the online split.
            let m = &r.baseline.metrics;
            t.row(&[
                label.to_string(),
                format!("{:.2}", m.acc()),
                format!("{:.2}", m.rt_ms()),
                format!("{:.2}", m.ttft_ms()),
                "-".into(),
                "-".into(),
                format!("0/{}", m.per_query.len()),
                "-".into(),
            ]);
            t.row(&online_cells(&format!("{label}+SubGCache-online"), &r.online));
            summaries.push(format!(
                "{label}: {} clusters opened, {} | {}",
                r.online.cluster_sizes.len(),
                cache_summary(&r.online),
                throughput_summary(&r.online)
            ));
        }
        t.print();
        for s in summaries {
            println!("  {s}");
        }
    }
    println!("\nnote: misses pay the representative prefill in full (no batch to \
              amortize over); hits extend a warm cache and skip it entirely.");
    Ok(())
}
