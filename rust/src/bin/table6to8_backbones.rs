//! Tables 6/7/8 (appendix A.4): the in-batch-size sweep of Table 4 repeated
//! for the other three backbones (Llama-2-7B / Mistral-7B / Falcon-7B sims).

use subgcache::harness::{batch_from_env, push_block, run_cell, Cell, METRIC_HEADER};
use subgcache::metrics::Table;
use subgcache::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = match args.get("artifacts") {
        Some(p) => ArtifactStore::open(p)?,
        None => ArtifactStore::discover()?,
    };
    let engine = Engine::start(&store)?;
    let batches: Vec<usize> = args
        .list_or("batches", "50,150,200")
        .iter()
        .map(|s| s.parse().expect("bad --batches"))
        .collect();
    let _ = batch_from_env(0); // env override documented; batches flag rules here

    for (table, backbone) in
        [("Table 6", "llama-2-7b-sim"), ("Table 7", "mistral-7b-sim"),
         ("Table 8", "falcon-7b-sim")]
    {
        println!("\n==== {table}: batch-size sweep (backbone: {backbone}) ====");
        for &batch in &batches {
            for dataset in ["scene_graph", "oag"] {
                println!("\n-- {batch} in-batch queries | dataset: {dataset} --");
                let mut t = Table::new(&METRIC_HEADER);
                for retriever in ["g-retriever", "grag"] {
                    let cell = Cell::new(dataset, retriever, backbone, batch);
                    let r = run_cell(&store, &engine, &cell)?;
                    let label =
                        if retriever == "g-retriever" { "G-Retriever" } else { "GRAG" };
                    push_block(&mut t, label, &r);
                }
                t.print();
            }
        }
    }
    Ok(())
}
