//! Dataset loading: the synthetic Scene Graph / OAG JSON files produced by
//! `python/compile/datasets.py` (Table 1 statistics), plus query/answer
//! bookkeeping and ACC scoring.

use std::path::Path;

use crate::graph::{Edge, Node, Subgraph, TextualGraph};
use crate::util::json::{parse_file, Json};

/// Data split tags (113/113/200 and 1617/1617/200 per the paper App. A.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

impl Split {
    fn parse(s: &str) -> anyhow::Result<Split> {
        Ok(match s {
            "train" => Split::Train,
            "val" => Split::Val,
            "test" => Split::Test,
            other => anyhow::bail!("unknown split {other}"),
        })
    }
}

/// One benchmark query with its gold answer and answer-bearing support set
/// (support is used by tests/diagnostics only — never by serving).
#[derive(Debug, Clone)]
pub struct Query {
    pub id: usize,
    pub text: String,
    pub answer: String,
    pub split: Split,
    pub support: Subgraph,
}

/// A loaded dataset: the textual graph plus its query set.
pub struct Dataset {
    pub graph: TextualGraph,
    pub queries: Vec<Query>,
}

impl Dataset {
    pub fn load(path: &Path) -> anyhow::Result<Dataset> {
        let v = parse_file(path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Dataset> {
        let name = v.get("name").as_str().unwrap_or("unnamed");
        let nodes = v
            .get("nodes")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing nodes"))?
            .iter()
            .map(|n| {
                Ok(Node {
                    id: n.get("id").as_usize().ok_or_else(|| anyhow::anyhow!("node id"))?,
                    name: n.get("name").as_str().unwrap_or_default().to_string(),
                    text: n.get("text").as_str().unwrap_or_default().to_string(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let edges = v
            .get("edges")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing edges"))?
            .iter()
            .map(|e| {
                Ok(Edge {
                    src: e.get("src").as_usize().ok_or_else(|| anyhow::anyhow!("edge src"))?,
                    dst: e.get("dst").as_usize().ok_or_else(|| anyhow::anyhow!("edge dst"))?,
                    text: e.get("text").as_str().unwrap_or_default().to_string(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let graph = TextualGraph::new(name, nodes, edges)?;
        let queries = v
            .get("queries")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing queries"))?
            .iter()
            .map(|q| {
                let support = Subgraph::from_parts(
                    q.get("support_nodes").as_arr().unwrap_or(&[]).iter()
                        .filter_map(Json::as_usize),
                    q.get("support_edges").as_arr().unwrap_or(&[]).iter()
                        .filter_map(Json::as_usize),
                );
                Ok(Query {
                    id: q.get("id").as_usize().ok_or_else(|| anyhow::anyhow!("query id"))?,
                    text: q.get("text").as_str().unwrap_or_default().to_string(),
                    answer: q.get("answer").as_str().unwrap_or_default().to_string(),
                    split: Split::parse(q.get("split").as_str().unwrap_or("test"))?,
                    support,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Dataset { graph, queries })
    }

    pub fn split(&self, split: Split) -> Vec<&Query> {
        self.queries.iter().filter(|q| q.split == split).collect()
    }

    /// The paper's main-table protocol: the first `n` test queries under a
    /// deterministic seed-shuffled order ("randomly sample 100 test queries").
    pub fn sample_test(&self, n: usize, seed: u64) -> Vec<&Query> {
        let mut test = self.split(Split::Test);
        let mut rng = crate::util::rng::Rng::new(seed);
        rng.shuffle(&mut test);
        test.truncate(n);
        test
    }
}

/// ACC scoring: normalized exact match over word tokens (answers are short
/// relation phrases / attribute words).
pub fn answer_correct(predicted: &str, gold: &str) -> bool {
    crate::tokenizer::split_text(predicted) == crate::tokenizer::split_text(gold)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn ds_json() -> Json {
        parse(
            r#"{"name":"t",
                "nodes":[{"id":0,"name":"a","text":"a x"},{"id":1,"name":"b","text":"b"}],
                "edges":[{"src":0,"dst":1,"text":"rel"}],
                "queries":[
                  {"id":0,"text":"q0 ?","answer":"x","split":"train",
                   "support_nodes":[0],"support_edges":[]},
                  {"id":1,"text":"q1 ?","answer":"rel","split":"test",
                   "support_nodes":[0,1],"support_edges":[0]}
                ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn loads_dataset() {
        let ds = Dataset::from_json(&ds_json()).unwrap();
        assert_eq!(ds.graph.n_nodes(), 2);
        assert_eq!(ds.graph.n_edges(), 1);
        assert_eq!(ds.queries.len(), 2);
        assert_eq!(ds.split(Split::Test).len(), 1);
        assert!(ds.queries[1].support.edges.contains(&0));
    }

    #[test]
    fn sample_test_deterministic() {
        let ds = Dataset::from_json(&ds_json()).unwrap();
        let a: Vec<usize> = ds.sample_test(1, 9).iter().map(|q| q.id).collect();
        let b: Vec<usize> = ds.sample_test(1, 9).iter().map(|q| q.id).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Dataset::from_json(&parse(r#"{"name":"x"}"#).unwrap()).is_err());
        assert!(Dataset::from_json(
            &parse(r#"{"nodes":[{"id":0,"name":"a","text":"a"}],
                       "edges":[{"src":0,"dst":9,"text":"r"}],"queries":[]}"#).unwrap()
        ).is_err());
    }

    #[test]
    fn acc_scoring_is_token_normalized() {
        assert!(answer_correct("Left  of", "left of"));
        assert!(answer_correct("blue", "blue"));
        assert!(!answer_correct("blue", "red"));
        assert!(!answer_correct("left", "left of"));
    }
}
