//! # SubGCache
//!
//! Reproduction of *"SubGCache: Accelerating Graph-based RAG with
//! Subgraph-level KV Cache"* (AAAI 2026), grown into a session-based serving
//! core over a three-layer Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * **L3 (this crate)** — the serving [`coordinator`]: retrieval, query
//!   clustering on GNN subgraph embeddings, representative-subgraph
//!   construction, KV-cache reuse, metrics. Three serving paths share one
//!   per-query session core:
//!   - `serve_baseline` — standard graph-based RAG, full prefill per query;
//!   - `serve_subgcache` — the paper's in-batch pipeline: cluster, prefill
//!     each representative once, `extend` per member;
//!   - `serve_online` — a streaming path: queries arrive one at a time, are
//!     matched to the nearest existing cluster centroid, and reuse a
//!     still-warm representative KV cache when one is resident.
//! * **[`cache`]** — the subgraph-level KV cache grown into a process-wide,
//!   thread-safe pool ([`cache::SharedKvCache`]): a byte-budgeted LRU keyed
//!   by representative *content hash*, with per-stream
//!   [`cache::KvCacheManager`] views, single-flight install coalescing, and
//!   globally-counted pins — so several representatives stay warm, an
//!   admission can never evict any stream's in-flight cluster, and
//!   identical representatives across concurrent streams are prefilled
//!   exactly once (`serve_online_multi`). The index is sharded by content
//!   key, and an optional host tier catches device evictions: demoted
//!   entries promote back with a copy instead of repaying a prefill.
//! * **[`runtime`]** — the execution layer behind the
//!   [`runtime::Backend`] trait: the per-lane PJRT [`runtime::Engine`]
//!   (LLM and GNN lanes on separate worker threads, device-resident KV)
//!   and the deterministic [`runtime::SimBackend`] that makes scheduling
//!   behaviour testable without artifacts.
//! * **L2/L1 (python/compile, build-time only)** — the simulated LLM
//!   backbones + GNN encoders, with the attention hot-spot as a Pallas
//!   kernel; AOT-lowered to HLO text consumed by [`runtime`] via PJRT.
//!
//! Quickstart (after `make artifacts`):
//!
//! ```no_run
//! use subgcache::prelude::*;
//!
//! let art = ArtifactStore::open("artifacts").unwrap();
//! let ds = art.dataset("scene_graph").unwrap();
//! let engine = Engine::start(&art).unwrap();
//! let cfg = ServeConfig { backbone: "llama-3.2-3b-sim".into(), ..Default::default() };
//! let coord = Coordinator::new(&art, &engine, cfg).unwrap();
//! let queries = ds.sample_test(8, 7);
//! // in-batch pipeline:
//! let report = coord.serve_subgcache(&ds, &queries, &GRetriever::default()).unwrap();
//! println!("ACC {:.1}% TTFT {:.1} ms", report.metrics.acc(), report.metrics.ttft_ms());
//! // streaming pipeline (same queries arriving one at a time):
//! let online = coord.serve_online(&ds, queries.iter().copied(),
//!                                 &GRetriever::default()).unwrap();
//! println!("hit TTFT {:.1} ms vs miss TTFT {:.1} ms ({} hits)",
//!          online.metrics.ttft_hit_ms(), online.metrics.ttft_miss_ms(),
//!          online.metrics.hit_count());
//! ```

pub mod cache;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod embed;
pub mod graph;
pub mod harness;
pub mod metrics;
pub mod retrieval;
pub mod runtime;
pub mod tokenizer;
pub mod util;

/// Common imports for examples and binaries.
pub mod prelude {
    pub use crate::cache::{CachePolicy, CacheStats, Demotion, HostSlot,
                           KvCacheManager, LockStats, Lookup, RepKey,
                           SharedKvCache, TieredOut};
    pub use crate::cluster::Linkage;
    pub use crate::coordinator::{ArrivalPlan, ArrivalProcess, BrownoutConfig,
                                 Coordinator, MultiStreamReport, OverloadConfig,
                                 QueryOutcome, ServeConfig, ServeReport, ShedReason,
                                 StreamOutcome};
    pub use crate::data::{Dataset, Split};
    pub use crate::graph::{Subgraph, TextualGraph};
    pub use crate::metrics::{delta, BatchMetrics, ReliabilityStats, Table};
    pub use crate::retrieval::{GRetriever, GragRetriever, GraphFeatures, Retriever};
    pub use crate::runtime::{sim_dataset, sim_store, ArtifactStore, Backend,
                             BackendError, BatchConfig, BreakerConfig, Engine,
                             FaultPlan, FullPolicy, Lane, QueueConfig, SimBackend,
                             SimLatency, SupervisorPolicy};
    pub use crate::util::cli::Args;
}
