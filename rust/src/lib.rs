//! # SubGCache
//!
//! Reproduction of *"SubGCache: Accelerating Graph-based RAG with
//! Subgraph-level KV Cache"* (AAAI 2026) as a three-layer Rust + JAX +
//! Pallas serving stack (see DESIGN.md):
//!
//! * **L3 (this crate)** — the serving coordinator: retrieval, query
//!   clustering on GNN subgraph embeddings, representative-subgraph
//!   construction, cluster-wise KV-cache reuse, metrics.
//! * **L2/L1 (python/compile, build-time only)** — the simulated LLM
//!   backbones + GNN encoders, with the attention hot-spot as a Pallas
//!   kernel; AOT-lowered to HLO text consumed by [`runtime`] via PJRT.
//!
//! Quickstart (after `make artifacts`):
//!
//! ```no_run
//! use subgcache::prelude::*;
//!
//! let art = ArtifactStore::open("artifacts").unwrap();
//! let ds = art.dataset("scene_graph").unwrap();
//! let engine = Engine::start(&art).unwrap();
//! let cfg = ServeConfig { backbone: "llama-3.2-3b-sim".into(), ..Default::default() };
//! let coord = Coordinator::new(&art, &engine, cfg).unwrap();
//! let queries = ds.sample_test(8, 7);
//! let report = coord.serve_subgcache(&ds, &queries, &GRetriever::default()).unwrap();
//! println!("ACC {:.1}% TTFT {:.1} ms", report.metrics.acc(), report.metrics.ttft_ms());
//! ```

pub mod cache;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod embed;
pub mod graph;
pub mod harness;
pub mod metrics;
pub mod retrieval;
pub mod runtime;
pub mod tokenizer;
pub mod util;

/// Common imports for examples and binaries.
pub mod prelude {
    pub use crate::cluster::Linkage;
    pub use crate::coordinator::{Coordinator, ServeConfig, ServeReport};
    pub use crate::data::{Dataset, Split};
    pub use crate::graph::{Subgraph, TextualGraph};
    pub use crate::metrics::{delta, BatchMetrics, Table};
    pub use crate::retrieval::{GRetriever, GragRetriever, GraphFeatures, Retriever};
    pub use crate::runtime::{ArtifactStore, Engine};
    pub use crate::util::cli::Args;
}
