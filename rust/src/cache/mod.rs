//! Subgraph-level KV cache manager (the paper §3.4).
//!
//! Cluster-wise lifecycle: at most one resident representative-subgraph KV
//! cache at a time — computed once per cluster, hit by every member query,
//! released before the next cluster (bounding GPU/host memory for large
//! in-batch workloads). Generic over the handle type so the policy is
//! testable without a PJRT engine; the real handle is
//! [`crate::runtime::KvHandle`].

/// Accounting snapshot (reported in EXPERIMENTS.md and Fig. 4 harness).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub prefills: u64,
    pub hits: u64,
    pub released: u64,
    pub resident_bytes: usize,
    pub peak_bytes: usize,
}

/// One resident cluster cache.
struct Resident<H> {
    cluster_id: usize,
    handle: H,
    bytes: usize,
}

/// The subgraph-level KV cache. `H` is an opaque device-cache handle; the
/// `release` callback passed at construction returns it to the engine.
pub struct KvCacheManager<H> {
    resident: Option<Resident<H>>,
    stats: CacheStats,
}

impl<H> Default for KvCacheManager<H> {
    fn default() -> Self {
        KvCacheManager { resident: None, stats: CacheStats::default() }
    }
}

impl<H> KvCacheManager<H> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install the KV cache of `cluster_id`'s representative subgraph.
    /// Returns the evicted handle (caller must release it on the engine).
    pub fn install(&mut self, cluster_id: usize, handle: H, bytes: usize) -> Option<H> {
        let evicted = self.take_resident();
        self.stats.prefills += 1;
        self.stats.resident_bytes = bytes;
        self.stats.peak_bytes = self.stats.peak_bytes.max(bytes);
        self.resident = Some(Resident { cluster_id, handle, bytes });
        evicted
    }

    /// Look up the resident cache for a cluster (a hit in the paper's terms).
    pub fn lookup(&mut self, cluster_id: usize) -> Option<&H> {
        match &self.resident {
            Some(r) if r.cluster_id == cluster_id => {
                self.stats.hits += 1;
                Some(&r.handle)
            }
            _ => None,
        }
    }

    /// Release the resident cache (end of cluster); returns its handle.
    pub fn release(&mut self) -> Option<H> {
        self.take_resident()
    }

    fn take_resident(&mut self) -> Option<H> {
        self.resident.take().map(|r| {
            self.stats.released += 1;
            self.stats.resident_bytes = 0;
            debug_assert!(r.bytes <= self.stats.peak_bytes);
            r.handle
        })
    }

    pub fn resident_cluster(&self) -> Option<usize> {
        self.resident.as_ref().map(|r| r.cluster_id)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

impl<H> Drop for KvCacheManager<H> {
    fn drop(&mut self) {
        // dropping a still-resident handle is fine for host-owned handles;
        // engine-owned ones should be released explicitly (tested below).
        debug_assert!(
            self.resident.is_none() || !std::thread::panicking(),
            "KV cache dropped while resident"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn install_lookup_release_cycle() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new();
        assert!(m.lookup(0).is_none());
        assert!(m.install(0, 111, 1024).is_none());
        assert_eq!(m.lookup(0), Some(&111));
        assert_eq!(m.lookup(0), Some(&111));
        assert!(m.lookup(1).is_none()); // other cluster: miss, no eviction
        assert_eq!(m.resident_cluster(), Some(0));
        assert_eq!(m.release(), Some(111));
        assert!(m.lookup(0).is_none());
        let s = m.stats();
        assert_eq!((s.prefills, s.hits, s.released), (1, 2, 1));
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.peak_bytes, 1024);
    }

    #[test]
    fn install_evicts_previous() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new();
        m.install(0, 1, 10);
        let evicted = m.install(1, 2, 20);
        assert_eq!(evicted, Some(1));
        assert_eq!(m.resident_cluster(), Some(1));
        assert_eq!(m.stats().peak_bytes, 20);
    }

    #[test]
    fn at_most_one_resident_property() {
        prop_check(100, |rng| {
            let mut m: KvCacheManager<u64> = KvCacheManager::new();
            let mut live: Vec<u64> = Vec::new(); // handles we must get back
            let mut next_handle = 0u64;
            for _ in 0..rng.range(1, 40) {
                match rng.below(3) {
                    0 => {
                        let h = next_handle;
                        next_handle += 1;
                        live.push(h);
                        if let Some(e) = m.install(rng.below(5), h, rng.range(1, 100)) {
                            live.retain(|&x| x != e);
                        }
                    }
                    1 => {
                        let _ = m.lookup(rng.below(5));
                    }
                    _ => {
                        if let Some(h) = m.release() {
                            live.retain(|&x| x != h);
                        }
                    }
                }
                // invariant: exactly the resident handle is outstanding
                assert!(live.len() <= 1, "leaked handles: {live:?}");
                assert_eq!(live.len() == 1, m.resident_cluster().is_some());
            }
            if let Some(h) = m.release() {
                live.retain(|&x| x != h);
            }
            assert!(live.is_empty());
            assert_eq!(m.stats().resident_bytes, 0);
        });
    }

    #[test]
    fn stats_peak_monotone() {
        let mut m: KvCacheManager<()> = KvCacheManager::new();
        m.install(0, (), 100);
        m.release();
        m.install(1, (), 50);
        assert_eq!(m.stats().peak_bytes, 100);
        assert_eq!(m.stats().resident_bytes, 50);
        m.release();
    }
}
